import os
import sys

# Tests see ONE CPU device (dry-run sets its own 512-device env in a
# subprocess); make sure src/ imports resolve when running bare pytest.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
