"""CloseByOne (Kuznetsov), centralized — the paper's comparison baseline.

Implemented breadth-first by levels so that "iterations" means the same
thing as for MRCbo (one MapReduce round per level, Table 9: 14 / 11 / 11).
Each level expands every intent found in the previous level with every
attribute above its generator; the canonicity test

    Z ∩ {bits < a}  ==  Y ∩ {bits < a}

guarantees each concept is produced exactly once, so no global dedupe is
needed (that is CbO's defining trick vs MRGanter+'s hash table).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset, closure, lectic
from repro.core.context import FormalContext
from repro.core.nextclosure import first_closure


@dataclasses.dataclass
class CbOResult:
    intents: list[np.ndarray]
    n_iterations: int
    n_closures_computed: int


def close_by_one(ctx: FormalContext, max_level_batch: int = 1 << 16) -> CbOResult:
    tables = lectic.LecticTables(ctx.n_attrs)
    mask = ctx.attr_mask()
    root = first_closure(ctx)
    intents: list[np.ndarray] = [root]
    # Frontier entries: (intent, generator attribute) — expand with a' > a.
    frontier: list[tuple[np.ndarray, int]] = [(root, -1)]
    n_iter = 0
    n_closures = 0

    while frontier:
        n_iter += 1
        seeds = []
        parents = []
        gens = []
        for Y, g in frontier:
            member = bitset.unpack_bits(Y, ctx.n_attrs)
            for a in range(g + 1, ctx.n_attrs):
                if member[a]:
                    continue
                seeds.append(Y | tables.BIT[a])
                parents.append(Y)
                gens.append(a)
        if not seeds:
            break
        next_frontier: list[tuple[np.ndarray, int]] = []
        for lo in range(0, len(seeds), max_level_batch):
            batch = np.stack(seeds[lo : lo + max_level_batch])
            cands, _ = closure.batched_closure_np(ctx.rows, batch, mask)
            n_closures += batch.shape[0]
            for i in range(batch.shape[0]):
                a = gens[lo + i]
                Y = parents[lo + i]
                Z = cands[i]
                # Canonicity: no new attribute below the generator.
                if np.all(((Z ^ Y) & tables.LOW[a]) == 0):
                    intents.append(Z)
                    next_frontier.append((Z, a))
        frontier = next_frontier

    return CbOResult(intents=intents, n_iterations=n_iter, n_closures_computed=n_closures)
