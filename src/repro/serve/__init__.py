"""Serving under load: continuous admission + open-loop load generation.

``admission`` packs asynchronously arriving queries into the
QueryEngine's fixed-slot micro-batches (deadline-or-full dispatch,
bounded depth); ``loadgen`` drives it open-loop at a target QPS for the
sustained-load benchmark.  The token-decode ``engine`` module is not
imported here — it pulls in ``repro.models`` and is unrelated to the
FCA serving path.
"""

from repro.serve.admission import (
    KINDS,
    AdmissionConfig,
    AdmissionQueue,
    ServeStats,
    Ticket,
)
from repro.serve.loadgen import (
    ARRIVALS,
    DEFAULT_MIX,
    LoadReport,
    burst_arrivals,
    make_workload,
    poisson_arrivals,
    run_load,
)

__all__ = [
    "KINDS",
    "AdmissionConfig",
    "AdmissionQueue",
    "ServeStats",
    "Ticket",
    "ARRIVALS",
    "DEFAULT_MIX",
    "LoadReport",
    "burst_arrivals",
    "make_workload",
    "poisson_arrivals",
    "run_load",
]
