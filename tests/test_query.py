"""repro.query — store/engine results vs host oracles, shard-count
invariance, and streaming-insert equivalence with batch remining."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import all_closures_batched, bitset
from repro.core.closure import closure_np, extent_np
from repro.core.context import FormalContext, paper_context
from repro.core.lattice import build_lattice
from repro.dist.shardplan import ShardPlan
from repro.query import ConceptStore, QueryEngine, StreamUpdater
from repro.query.engine import QueryConfig
from repro.query.store import host_supports

settings.register_profile("query", deadline=None, max_examples=10)
settings.load_profile("query")


def _keys(intents):
    return {bitset.key_bytes(y) for y in np.asarray(intents, np.uint32)}


@pytest.fixture(scope="module")
def served():
    ctx = FormalContext.synthetic(60, 18, 0.3, seed=5)
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(4, block_n=16)
    store = ConceptStore.build(ctx, intents, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=16))
    return ctx, intents, store, qe


def _random_attrsets(ctx, n, seed):
    rng = np.random.default_rng(seed)
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=n)]
    keep = bitset.pack_bool(rng.random((n, ctx.n_attrs)) < 0.4, ctx.W)
    return base & keep


# -- store invariants --------------------------------------------------------


def test_store_snapshot_supports_and_order(served):
    ctx, intents, store, _ = served
    snap = store.snapshot
    assert snap.n_concepts == len(intents)
    assert _keys(snap.intents_np) == _keys(intents)
    np.testing.assert_array_equal(
        snap.supports_np, host_supports(ctx, snap.intents_np)
    )
    # canonical order: ascending two-level bucket key
    from repro.core import hashindex

    keys = hashindex.bucket_key(
        hashindex.batch_heads(snap.intents_np),
        bitset.popcount(snap.intents_np),
        ctx.n_attrs,
    )
    assert np.all(np.diff(keys) >= 0)


def test_store_order_tables_vs_subset_loops(served):
    ctx, _, store, qe = served
    snap = store.snapshot
    arr = snap.intents_np
    C = snap.n_concepts
    ids = np.arange(C, dtype=np.int32)
    supers, subs = qe.supers(ids), qe.subs(ids)
    for c in range(C):
        sup_ref = [
            d for d in range(C)
            if d != c and bool(bitset.is_subset(arr[d], arr[c]))
        ]
        sub_ref = [
            d for d in range(C)
            if d != c and bool(bitset.is_subset(arr[c], arr[d]))
        ]
        assert list(supers[c]) == sup_ref
        assert list(subs[c]) == sub_ref


def test_store_covering_vs_build_lattice(served):
    ctx, intents, store, qe = served
    snap = store.snapshot
    lat = build_lattice(ctx, intents)  # popcount-ordered host artifact
    # map lattice indices -> store ids via intent bytes
    id_of = {bitset.key_bytes(y): i for i, y in enumerate(snap.intents_np)}
    perm = np.array([id_of[bitset.key_bytes(y)] for y in lat.intents])
    children = qe.children(np.arange(snap.n_concepts, dtype=np.int32))
    for i, kids in enumerate(lat.children):
        got = set(children[perm[i]].tolist())
        assert got == {int(perm[j]) for j in kids}


# -- query engine vs host oracles -------------------------------------------


def test_closure_batch_vs_host_oracle(served):
    ctx, _, store, qe = served
    qs = _random_attrsets(ctx, 33, seed=1)  # odd size: exercises padding
    gc, gs, ids = qe.closure_batch(qs)
    mask = ctx.attr_mask()
    snap = store.snapshot
    for q, c, s, i in zip(qs, gc, gs, ids):
        c_ref, s_ref = closure_np(ctx.rows, q, mask)
        assert np.array_equal(c, c_ref)
        assert s == s_ref
        assert i >= 0 and np.array_equal(snap.intents_np[i], c_ref)


def test_lookup_hits_and_misses(served):
    ctx, _, store, qe = served
    snap = store.snapshot
    ids = qe.lookup_batch(snap.intents_np)
    np.testing.assert_array_equal(ids, np.arange(snap.n_concepts))
    # a non-closed attrset must miss
    non_intents = []
    known = _keys(snap.intents_np)
    for y in snap.intents_np:
        for a in range(ctx.n_attrs):
            cand = y | bitset.bit(a, ctx.W)
            if bitset.key_bytes(cand) not in known:
                non_intents.append(cand)
                break
        if len(non_intents) >= 5:
            break
    if non_intents:
        miss = qe.lookup_batch(np.stack(non_intents))
        assert np.all(miss == -1)


def test_topk_vs_host_oracle(served):
    ctx, _, store, qe = served
    snap = store.snapshot
    qs = _random_attrsets(ctx, 9, seed=2)
    ids, vals = qe.topk_batch(qs, k=4)
    mask = ctx.attr_mask()
    for q, idr, valr in zip(qs, ids, vals):
        c, _ = closure_np(ctx.rows, q, mask)
        matches = sorted(
            (
                (int(snap.supports_np[j]), j)
                for j in range(snap.n_concepts)
                if bool(bitset.is_subset(c, snap.intents_np[j]))
            ),
            key=lambda t: (-t[0], t[1]),
        )[:4]
        ref_ids = [j for _, j in matches] + [-1] * (4 - len(matches))
        ref_vals = [s for s, _ in matches] + [-1] * (4 - len(matches))
        assert list(idr) == ref_ids
        assert list(valr) == ref_vals


def test_extents_vs_host_oracle(served):
    ctx, _, store, qe = served
    snap = store.snapshot
    ids = np.arange(snap.n_concepts, dtype=np.int32)
    packed = qe.extents_batch(ids)
    for c in ids:
        ext_ref = extent_np(ctx.rows, snap.intents_np[c])
        got = bitset.unpack_bits(packed[c], store.N_padded)
        assert np.array_equal(got[: ctx.n_objects], ext_ref)
        assert not got[ctx.n_objects :].any()


def test_extents_of_miss_ids_are_empty(served):
    """-1 (miss/pad) ids must yield the empty extent, never another
    concept's objects; empty batches dispatch no SPMD round."""
    ctx, _, store, qe = served
    packed = qe.extents_batch(np.array([-1, 0, store.snapshot.n_concepts]))
    assert not packed[0].any()
    assert not packed[2].any()
    assert packed[1].any()  # concept 0 itself is real
    rounds = qe.stats.collective_rounds
    empty = qe.extents_batch(np.zeros((0,), np.int32))
    assert empty.shape[0] == 0
    assert qe.stats.collective_rounds == rounds
    gc, gs, ids = qe.closure_batch(np.zeros((0, ctx.W), np.uint32))
    assert gc.shape == (0, ctx.W) and gs.shape == (0,) and ids.shape == (0,)


def test_shard_count_invariance():
    """The same workload over 1/2/4 simulated shards — and allgather vs
    rsag vs auto — must be bit-identical (AND-semigroup collectives)."""
    ctx = FormalContext.synthetic(48, 12, 0.35, seed=9)
    intents = all_closures_batched(ctx)
    qs = _random_attrsets(ctx, 21, seed=3)
    ref = None
    for n_parts, impl in [(1, "rsag"), (2, "allgather"), (4, "auto")]:
        plan = ShardPlan.simulated(n_parts, reduce_impl=impl, block_n=16)
        store = ConceptStore.build(ctx, intents, plan=plan)
        qe = QueryEngine(store, QueryConfig(slots=8))
        out = qe.closure_batch(qs) + qe.topk_batch(qs[:5], k=3)
        if ref is None:
            ref = out
        else:
            for a, b in zip(ref, out):
                np.testing.assert_array_equal(a, b)


# -- streaming updates -------------------------------------------------------


@given(
    st.integers(4, 24), st.integers(2, 12), st.floats(0.15, 0.5),
    st.integers(0, 10_000), st.integers(1, 5),
)
def test_stream_insert_equals_batch_remine(n, m, density, seed, k_new):
    full = FormalContext.synthetic(n + k_new, m, density, seed=seed)
    base = FormalContext(rows=full.rows[:n], n_objects=n, n_attrs=m)
    intents = all_closures_batched(base)
    store = ConceptStore.build(base, intents, plan=ShardPlan.simulated(2, block_n=8))
    StreamUpdater(store).apply(full.rows[n:])
    snap = store.snapshot
    assert _keys(snap.intents_np) == _keys(all_closures_batched(full))
    np.testing.assert_array_equal(store.ctx.rows, full.rows)
    np.testing.assert_array_equal(
        snap.supports_np, host_supports(full, snap.intents_np)
    )
    assert snap.version == 1


def test_double_buffered_snapshot_serves_through_stage():
    ctx = paper_context()
    intents = all_closures_batched(ctx)
    store = ConceptStore.build(ctx, intents, plan=ShardPlan.simulated(1))
    qe = QueryEngine(store, QueryConfig(slots=8))
    qs = _random_attrsets(ctx, 6, seed=0)
    before = qe.closure_batch(qs)
    v0 = store.snapshot.version

    upd = StreamUpdater(store)
    new_rows = bitset.pack_bool(
        np.random.default_rng(1).random((2, ctx.n_attrs)) < 0.4, ctx.W
    )
    receipt = upd.stage(new_rows)
    # staged but not committed: the active snapshot (and results) unchanged
    assert store.snapshot.version == v0
    mid = qe.closure_batch(qs)
    for a, b in zip(before, mid):
        np.testing.assert_array_equal(a, b)

    upd.commit()
    assert store.snapshot.version == v0 + 1
    assert store.snapshot.n_concepts == receipt.n_concepts_after
    # after the swap the same queries resolve against the grown context
    gc, gs, ids = qe.closure_batch(qs)
    mask = store.ctx.attr_mask()
    for q, c, s, i in zip(qs, gc, gs, ids):
        c_ref, s_ref = closure_np(store.ctx.rows, q, mask)
        assert np.array_equal(c, c_ref) and s == s_ref and i >= 0
    with pytest.raises(RuntimeError):
        store.commit()


def test_extents_after_stream_commit_match_host_oracle():
    """The device-side extent build (mixed out-spec SPMD region) must stay
    correct for staged snapshots over the grown, re-placed context."""
    ctx = FormalContext.synthetic(30, 10, 0.35, seed=14)
    intents = all_closures_batched(ctx)
    store = ConceptStore.build(
        ctx, intents, plan=ShardPlan.simulated(2, block_n=8)
    )
    qe = QueryEngine(store, QueryConfig(slots=8))
    new_rows = bitset.pack_bool(
        np.random.default_rng(2).random((3, ctx.n_attrs)) < 0.4, ctx.W
    )
    StreamUpdater(store).apply(new_rows)
    snap = store.snapshot
    grown = store.ctx
    ids = np.arange(snap.n_concepts, dtype=np.int32)
    packed = qe.extents_batch(ids)
    for c in ids:
        ref = extent_np(grown.rows, snap.intents_np[c])
        got = bitset.unpack_bits(packed[c], store.N_padded)
        assert np.array_equal(got[: grown.n_objects], ref)
        assert not got[grown.n_objects :].any()


def test_stream_rejects_bad_rows():
    ctx = paper_context()
    store = ConceptStore.build(
        ctx, all_closures_batched(ctx), plan=ShardPlan.simulated(1)
    )
    upd = StreamUpdater(store)
    bad = np.full((1, ctx.W), 0xFFFFFFFF, np.uint32)  # bits above n_attrs
    with pytest.raises(ValueError):
        upd.stage(bad)
