"""Optimizers (AdamW, Adafactor) and LR schedules — no external deps.

AdamW keeps fp32 master params + fp32 (m, v): 14 bytes/param with bf16
compute params.  Adafactor factors the second moment over the last two dims
(row/col statistics): ~4.5 bytes/param — what lets arctic-480b-class models
fit the optimizer state on a 256-chip v5e pod (see EXPERIMENTS.md §Dry-run).
Optimizer state reuses the params' logical axes, so FSDP/TP sharding of the
state falls out of the same partitioning rules.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> opt_state
    apply: Callable  # (grads, opt_state, params) -> (new_params, new_opt_state)
    state_axes: Callable  # params_axes -> opt_state axes tree


def adamw(
    lr_fn: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            # copy=True: fp32 params would otherwise *alias* the master
            # buffer and break donation (same buffer donated twice).
            "master": jax.tree_util.tree_map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
            ),
        }

    def apply(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            update = (m / b1t) / (jnp.sqrt(v / b2t) + eps) + weight_decay * master
            master = master - lr * update
            return m, v, master, master.astype(p.dtype)

        flat = jax.tree_util.tree_map(
            upd, grads, state["m"], state["v"], state["master"], params
        )
        m = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree_util.tree_map(lambda t: t[3], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": m, "v": v, "master": master}

    def state_axes(params_axes):
        return {
            "step": (),
            "m": params_axes,
            "v": params_axes,
            "master": params_axes,
        }

    return Optimizer(init, apply, state_axes)


def _factored_dims(shape) -> tuple[int, int] | None:
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor(
    lr_fn: Callable,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_rms: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        def leaf(p):
            dims = _factored_dims(p.shape)
            if dims is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = dims
            row_shape = tuple(s for i, s in enumerate(p.shape) if i != c)
            col_shape = tuple(s for i, s in enumerate(p.shape) if i != r)
            return {
                "vr": jnp.zeros(row_shape, jnp.float32),
                "vc": jnp.zeros(col_shape, jnp.float32),
            }

        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree_util.tree_map(leaf, params),
        }

    def apply(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            dims = _factored_dims(g.shape)
            if dims is None:
                v_new = {"v": decay * v["v"] + (1 - decay) * g2}
                precond = g * jax.lax.rsqrt(v_new["v"] + eps)
            else:
                r, c = dims
                vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=c)
                vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=r)
                v_new = {"vr": vr, "vc": vc}
                # rank-1 second-moment estimate: V ≈ (vr ⊗ vc) / mean(vr)
                mean_r = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                v_est = (vr / mean_r)[..., :, None] * vc[..., None, :]
                precond = g * jax.lax.rsqrt(v_est + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + eps)
            precond = precond / jnp.maximum(1.0, rms / clip_rms)
            newp = p.astype(jnp.float32) - lr * (precond + weight_decay * p.astype(jnp.float32))
            return v_new, newp.astype(p.dtype)

        out = jax.tree_util.tree_map(
            upd, grads, state["v"], params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) <= {"v", "vr", "vc"},
        )
        split_leaf = lambda x: isinstance(x, tuple) and len(x) == 2
        v = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=split_leaf)
        new_params = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=split_leaf)
        return new_params, {"step": step, "v": v}

    def state_axes(params_axes):
        def leaf(ax):
            if len(ax) < 2:
                return {"v": ax}
            r, c = len(ax) - 2, len(ax) - 1
            return {
                "vr": tuple(a for i, a in enumerate(ax) if i != c),
                "vc": tuple(a for i, a in enumerate(ax) if i != r),
            }

        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )
        return {
            "step": (),
            "v": jax.tree_util.tree_map(leaf, params_axes, is_leaf=is_axes),
        }

    return Optimizer(init, apply, state_axes)


def get_optimizer(name: str, lr_fn: Callable) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn)
    if name == "adafactor":
        return adafactor(lr_fn)
    raise ValueError(f"unknown optimizer {name!r}")
