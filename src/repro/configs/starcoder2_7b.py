"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    rope_kind="standard",
    rope_theta=100_000.0,
    qkv_bias=True,
    mlp_kind="gelu",  # starcoder2 uses a non-gated gelu FFN (4×d)
)
