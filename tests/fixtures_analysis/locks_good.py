"""Clean twin of ``locks_bad.py`` — the checker must stay silent.

Exercises every legitimate escape hatch: full locking in ``drain``, the
``# lock: ok`` annotation for a benign GIL-atomic racy read, and the
assumed-locked fixpoint for a private helper whose only call sites hold
the lock.  Analyzed by path only.
"""

import threading


class GoodQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []
        self._hwm = 0

    def push(self, x):
        with self._lock:
            self._items.append(x)
            self._track()

    def drain(self):
        with self._lock:
            out = list(self._items)
            self._items.clear()
        return out

    def depth_fast(self):
        return len(self._items)  # lock: ok — racy read, re-checked by callers

    def _track(self):
        # every call site holds the lock: the fixpoint analyzes this body
        # as lock-held
        self._hwm = max(self._hwm, len(self._items))
