"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone-only per assignment rules: the vision frontend is a stub —
``input_specs()`` provides precomputed patch/frame embeddings [B, S, d] and
M-RoPE position streams [3, B, S]; for text-only streams the three
positions coincide and M-RoPE degenerates to RoPE.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    mlp_kind="swiglu",
    input_mode="embeds",
)
