"""Table 7 — dataset characteristics (objects, attributes, density)."""

from __future__ import annotations

from benchmarks.common import DEFAULT_SCALES, load_scaled, row
from repro.data.fca_datasets import PAPER_DATASETS


def run() -> list[str]:
    out = []
    for name, (n_obj, n_attr, dens) in PAPER_DATASETS.items():
        ctx, spec = load_scaled(name)
        out.append(row(
            f"table7/{name}",
            0.0,
            f"paper=({n_obj}x{n_attr}@{dens:.4f})|scaled=({spec.n_objects}x"
            f"{spec.n_attrs}@{spec.density:.4f})|scale={DEFAULT_SCALES[name]}",
        ))
    return out
