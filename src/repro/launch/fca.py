"""Distributed FCA launcher — the paper's system as a production CLI.

    python -m repro.launch.fca --dataset mushroom --scale 0.05 \
        --algorithm mrganter+ --parts 8 --reduce rsag

With a real multi-device runtime pass ``--mesh`` to shard the context over
the device mesh (objects over pod×data); otherwise partitions are
simulated on one device with bit-identical arithmetic.
"""

from __future__ import annotations

import argparse
import json

from repro.core import ClosureEngine, bitset, mrcbo, mrganter, mrganter_plus
from repro.data import fca_datasets


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="mushroom",
                   choices=list(fca_datasets.PAPER_DATASETS))
    p.add_argument("--scale", type=float, default=0.05)
    p.add_argument("--algorithm", default="mrganter+",
                   choices=["mrganter", "mrganter+", "mrcbo"])
    p.add_argument("--parts", type=int, default=8)
    p.add_argument("--reduce", default="rsag",
                   choices=["allgather", "rsag", "pmin"])
    p.add_argument("--mesh", action="store_true",
                   help="shard over the jax device mesh (needs >1 device)")
    p.add_argument("--no-kernel", action="store_true")
    p.add_argument("--max-iterations", type=int, default=None)
    p.add_argument("--data-dir", default=None,
                   help="directory with real UCI .data files (else synthetic)")
    args = p.parse_args(argv)

    ctx, spec = fca_datasets.load(args.dataset, scale=args.scale,
                                  data_dir=args.data_dir)
    if args.mesh:
        import jax
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model=1)
        eng = ClosureEngine(ctx, mesh=mesh, axis_names=("data",),
                            reduce_impl=args.reduce,
                            use_kernel=not args.no_kernel)
    else:
        eng = ClosureEngine(ctx, n_parts=args.parts, reduce_impl=args.reduce,
                            use_kernel=not args.no_kernel)

    algo = {"mrganter": mrganter, "mrganter+": mrganter_plus, "mrcbo": mrcbo}[
        args.algorithm
    ]
    res = algo(ctx, eng, max_iterations=args.max_iterations)
    print(json.dumps({
        "dataset": spec.name,
        "objects": spec.n_objects,
        "attributes": spec.n_attrs,
        "density": round(spec.density, 4),
        "synthetic": spec.synthetic,
        "algorithm": res.algorithm,
        "concepts": res.n_concepts,
        "iterations": res.n_iterations,
        "closures_computed": res.n_closures_computed,
        "modeled_comm_bytes": res.modeled_comm_bytes,
        "wall_time_s": round(res.wall_time_s, 3),
    }, indent=2))


if __name__ == "__main__":
    main()
