"""StreamUpdater — batched device-side Godin insertion with double-buffered
snapshots.

The paper's §1.1 motivation ("batch algorithms … require that the entire
lattice is reconstructed from scratch if the database changes") closed on
the serving side: a batch of K new objects becomes a *staged* successor
snapshot while the active one keeps answering queries, then ``commit()``
swaps one reference.

The insertion itself is the device twin of the vectorized host path in
:mod:`repro.core.incremental`:

    P          = subset intersections of the K new rows   (host fold — P is
                 bounded by the K-row subcontext's concept count, tiny)
    candidates = intents ∩ P                              (one device
                 broadcast-AND over the full intent table)
    grown set  = sort-unique(intents ∪ candidates ∪ P)    (the frontier
                 pipeline's lexsort + adjacent-unique dedupe machinery —
                 ``repro.core.frontier._sort_unique`` — on device)

followed by one plan-SPMD psum round over the grown context for the
support recount and two device matmuls for the order tables (both inside
``ConceptStore.make_snapshot``).  Equivalence with per-row Godin insertion
*and* with batch NextClosure remining on the grown context is
property-tested (tests/test_query.py).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incremental
from repro.core.context import FormalContext
from repro.core.frontier import _sort_unique
from repro.kernels.ops import bucket_size
from repro.obs import trace as obs
from repro.query.store import ConceptStore, StoreState


@jax.jit
def _grow_intents_dev(
    intents: jax.Array, n_valid, P: jax.Array, n_p
) -> tuple[jax.Array, jax.Array]:
    """Device Godin pass: ``sort-unique(intents ∪ (intents ∩ P) ∪ P)``.

    ``intents [Cb, W]`` and ``P [Pb, W]`` are bucket-padded (rows past
    ``n_valid`` / ``n_p`` are padding, excluded via the validity mask so
    recompiles stay bounded by the power-of-two buckets).  Returns
    ``(buf [Cb·(Pb+1)+Pb, W], count)`` with the distinct grown intents
    compacted to the front — the count is the one scalar sync the commit
    costs before the support recount.
    """
    Cb, W = intents.shape
    Pb = P.shape[0]
    cand = (intents[:, None, :] & P[None, :, :]).reshape(Cb * Pb, W)
    allc = jnp.concatenate([intents, cand, P], axis=0)
    row_valid = jnp.arange(Cb) < n_valid
    p_valid = jnp.arange(Pb) < n_p
    cand_valid = (row_valid[:, None] & p_valid[None, :]).reshape(Cb * Pb)
    valid = jnp.concatenate([row_valid, cand_valid, p_valid])
    n, uniq = _sort_unique(allc, valid)
    return uniq, n


@dataclasses.dataclass
class UpdateReceipt:
    """What one staged batch did (benchmark/ops telemetry)."""

    n_new_objects: int
    n_intersections: int  # |P|
    n_concepts_before: int
    n_concepts_after: int
    stage_wall_s: float
    version: int


class StreamUpdater:
    def __init__(
        self,
        store: ConceptStore,
        row_slack: int = 64,
        *,
        clock=time.perf_counter,
    ):
        self.store = store
        # Injectable clock: the load generator drives stage/commit under a
        # virtual clock, so the staged-wall measurement must tick on the
        # same timebase as the rest of the run (repro.analysis lints
        # direct wall-clock reads in this path).
        self.clock = clock
        # Round the grown context's row padding up to this quantum (kept a
        # multiple of the plan's row alignment).  The query engine's jitted
        # steps take ``rows [N_padded, W]`` as an argument, so every change
        # of N_padded recompiles them; with slack, a stream of small
        # commits recompiles once per ~``row_slack`` inserted objects
        # instead of once per commit.  Pad rows are the all-ones
        # AND-identity, masked by count everywhere (supports, extents), so
        # results are bit-identical at any quantum; ``row_slack=0``
        # restores exact alignment padding.
        align = store.plan.row_alignment
        self.row_quantum = max(align, ((row_slack + align - 1) // align) * align)

    def stage(self, new_rows: np.ndarray) -> UpdateReceipt:
        """Build the successor snapshot for ``new_rows [K, W]``.

        The active snapshot keeps serving throughout; nothing the query
        engine reads is mutated.  Call :meth:`commit` to swap.
        """
        store = self.store
        state = store.state  # one consistent (ctx, rows, snapshot) view
        snap = state.snapshot
        ctx = state.ctx
        t0 = self.clock()
        with obs.current().span("stream/stage") as sp:
            receipt = self._stage(store, state, snap, ctx, new_rows, t0)
            sp.set(
                n_new_objects=receipt.n_new_objects,
                n_intersections=receipt.n_intersections,
                n_concepts_after=receipt.n_concepts_after,
                version=receipt.version,
            )
        return receipt

    def _stage(self, store, state, snap, ctx, new_rows, t0) -> UpdateReceipt:
        new_rows = np.ascontiguousarray(new_rows, dtype=np.uint32)
        if new_rows.ndim != 2 or new_rows.shape[1] != ctx.W:
            raise ValueError(f"new rows must be [K, {ctx.W}] packed uint32")
        if np.any(new_rows & ~ctx.attr_mask()):
            raise ValueError("new objects have attribute bits above n_attrs")

        # 1. subset intersections of the batch (host fold over tiny P)
        P = incremental.row_intersections(new_rows)

        # 2.+3. broadcast-AND + device sort-unique (frontier dedupe).
        # P pads are all-zero sets; ∅ can be a real intent, so the pad
        # rows are excluded by count, not by value.
        Pb = np.zeros((bucket_size(P.shape[0], minimum=4), ctx.W), np.uint32)
        Pb[: P.shape[0]] = P
        uniq, n_dev = _grow_intents_dev(
            snap.intents,
            jnp.int32(snap.n_concepts),
            jnp.asarray(Pb),
            jnp.int32(P.shape[0]),
        )
        n_grown = int(n_dev)  # the commit's one scalar sync
        grown_np = np.asarray(uniq[:n_grown])

        # 4. grown context + placement, successor snapshot against it
        grown_ctx = FormalContext(
            rows=np.concatenate([ctx.rows, new_rows], axis=0),
            n_objects=ctx.n_objects + new_rows.shape[0],
            n_attrs=ctx.n_attrs,
            attr_names=ctx.attr_names,
        )
        rows_padded, n_pad = grown_ctx.padded_rows(self.row_quantum)
        rows_dev = store.plan.place_rows(rows_padded)
        next_snap = store.make_snapshot(
            grown_np,
            version=snap.version + 1,
            rows_dev=rows_dev,
            ctx=grown_ctx,
        )
        store.stage(
            StoreState(
                ctx=grown_ctx,
                rows=rows_dev,
                n_pad=n_pad,
                N_padded=rows_padded.shape[0],
                snapshot=next_snap,
            )
        )
        return UpdateReceipt(
            n_new_objects=new_rows.shape[0],
            n_intersections=P.shape[0],
            n_concepts_before=snap.n_concepts,
            n_concepts_after=next_snap.n_concepts,
            stage_wall_s=self.clock() - t0,
            version=next_snap.version,
        )

    def commit(self):
        """Swap the staged snapshot in (one reference assignment)."""
        with obs.current().span("stream/commit"):
            return self.store.commit()

    def apply(self, new_rows: np.ndarray) -> UpdateReceipt:
        """stage + commit in one call (the synchronous convenience path)."""
        receipt = self.stage(new_rows)
        self.commit()
        return receipt
