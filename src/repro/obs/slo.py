"""SLO objectives, burn rates, and the bench-regression gate.

Two jobs, one module:

* **Serving objectives** — an :class:`SLO` states the latency/shed
  objectives the serving tier promises (e.g. "99.5% of requests finish
  under 250 ms; shed rate under 1%").  :func:`evaluate` scores one load
  report against it, including the **burn rate** — the ratio of the
  observed error rate to the error budget ``1 - target`` (burn 1.0 =
  exactly consuming the budget; >> 1 = the alerting signal SRE
  multiwindow alerts are built on).  The serve-load benchmark stamps an
  evaluation onto every grid point of BENCH_serve_load.json.

* **Regression gate** — :func:`check_baselines` compares the headline
  metrics of committed bench artifacts (BENCH_query.json,
  BENCH_serve_load.json) against `benchmarks/slo_baselines.json` with a
  tolerance band: latency metrics fail above ``baseline ×
  tolerance_ratio`` (wide enough for runner noise, tight enough that an
  injected 10× regression trips), rate metrics fail above ``baseline +
  rate_slack``, and boolean invariants (bit-identity flags) must hold
  exactly.  ``python -m repro.obs.slo --baselines ... ARTIFACT...`` is
  the CI job: exit 1 on any violation.
"""

from __future__ import annotations

import dataclasses
import json
import sys

DEFAULT_TOLERANCE_RATIO = 4.0  # latency: CI runners are ~this much noisier
DEFAULT_RATE_SLACK = 0.02  # absolute slack for rate metrics (shed fraction)


def get_path(obj, dotted: str):
    """``get_path({"a": {"b": 1}}, "a.b") == 1``; KeyError names the path."""
    cur = obj
    for part in dotted.split("."):
        try:
            cur = cur[part]
        except (KeyError, TypeError):
            raise KeyError(f"no {dotted!r} in artifact (stopped at {part!r})")
    return cur


def burn_rate(compliance: float, target: float) -> float:
    """Error budget consumption rate: ``(1 - compliance) / (1 - target)``.

    1.0 = consuming exactly the budget; below 1 is sustainable; a target
    of 1.0 (zero budget) burns infinitely on any error.
    """
    budget = 1.0 - target
    err = max(0.0, 1.0 - compliance)
    if budget <= 0.0:
        return 0.0 if err == 0.0 else float("inf")
    return err / budget


@dataclasses.dataclass
class SLO:
    """The serving tier's promises (seconds / fractions)."""

    latency_objective_s: float = 0.25  # e2e objective each request must meet
    latency_target: float = 0.995  # fraction of requests meeting it
    max_shed_rate: float = 0.01  # admission shed fraction
    max_p99_s: float | None = None  # optional hard p99 ceiling

    def describe(self) -> dict:
        return dataclasses.asdict(self)


def evaluate(
    slo: SLO, *, compliance: float, shed_rate: float, p99_s: float | None = None
) -> dict:
    """Score one load measurement against the SLO.

    ``compliance`` is the fraction of requests under
    ``latency_objective_s`` (from ``Histogram.fraction_below``);
    ``shed_rate`` the shed fraction.  Returns objective verdicts plus
    the latency burn rate.
    """
    rate = burn_rate(compliance, slo.latency_target)
    out = {
        "latency_objective_s": slo.latency_objective_s,
        "compliance": round(compliance, 6),
        "latency_target": slo.latency_target,
        "burn_rate": round(rate, 3) if rate != float("inf") else "inf",
        "latency_ok": compliance >= slo.latency_target,
        "shed_rate": round(shed_rate, 6),
        "shed_ok": shed_rate <= slo.max_shed_rate,
    }
    if slo.max_p99_s is not None and p99_s is not None:
        out["p99_s"] = round(p99_s, 6)
        out["p99_ok"] = p99_s <= slo.max_p99_s
    out["ok"] = all(v for k, v in out.items() if k.endswith("_ok"))
    return out


# ---------------------------------------------------------------------------
# bench-regression gate
# ---------------------------------------------------------------------------


def check_baselines(
    artifact: dict,
    baseline: dict,
    *,
    tolerance_ratio: float = DEFAULT_TOLERANCE_RATIO,
    rate_slack: float = DEFAULT_RATE_SLACK,
) -> list[str]:
    """Violations of one artifact against its committed baseline entry.

    ``baseline`` groups dotted metric paths by class::

        {"latency_s": {"headline.e2e.p99": 0.011},   # fail > base × ratio
         "rate":      {"headline.shed_rate": 0.0},   # fail > base + slack
         "exact":     {"headline.bit_identical": true}}  # fail != base

    Returns human-readable violation strings (empty = green).  A missing
    metric path is itself a violation — a gate that silently skips what
    it was told to check is no gate.
    """
    violations = []
    for path, base in baseline.get("latency_s", {}).items():
        try:
            cur = float(get_path(artifact, path))
        except KeyError as e:
            violations.append(str(e))
            continue
        ceiling = float(base) * tolerance_ratio
        if cur > ceiling:
            violations.append(
                f"latency regression: {path} = {cur:.6f}s exceeds baseline "
                f"{base:.6f}s × {tolerance_ratio:g} tolerance "
                f"(ceiling {ceiling:.6f}s)"
            )
    for path, base in baseline.get("rate", {}).items():
        try:
            cur = float(get_path(artifact, path))
        except KeyError as e:
            violations.append(str(e))
            continue
        if cur > float(base) + rate_slack:
            violations.append(
                f"rate regression: {path} = {cur:.6f} exceeds baseline "
                f"{base:.6f} + {rate_slack:g} slack"
            )
    for path, base in baseline.get("exact", {}).items():
        try:
            cur = get_path(artifact, path)
        except KeyError as e:
            violations.append(str(e))
            continue
        if cur != base:
            violations.append(f"invariant broken: {path} = {cur!r} != {base!r}")
    return violations


def run_gate(
    artifact_paths: list[str],
    baselines_path: str,
    *,
    tolerance_ratio: float | None = None,
    rate_slack: float | None = None,
    out=sys.stdout,
) -> int:
    """The CI gate body: check each artifact against the baselines file.

    The baselines file carries the default tolerances (overridable per
    invocation) and one entry per artifact basename::

        {"tolerance_ratio": 4.0, "rate_slack": 0.02,
         "artifacts": {"BENCH_query.json": {...}, ...}}
    """
    import os

    with open(baselines_path) as f:
        baselines = json.load(f)
    ratio = (
        tolerance_ratio
        if tolerance_ratio is not None
        else baselines.get("tolerance_ratio", DEFAULT_TOLERANCE_RATIO)
    )
    slack = (
        rate_slack
        if rate_slack is not None
        else baselines.get("rate_slack", DEFAULT_RATE_SLACK)
    )
    failures = 0
    for path in artifact_paths:
        name = os.path.basename(path)
        entry = baselines.get("artifacts", {}).get(name)
        if entry is None:
            print(f"FAIL {name}: no baseline entry in {baselines_path}",
                  file=out)
            failures += 1
            continue
        with open(path) as f:
            artifact = json.load(f)
        violations = check_baselines(
            artifact, entry, tolerance_ratio=ratio, rate_slack=slack
        )
        if violations:
            failures += 1
            for v in violations:
                print(f"FAIL {name}: {v}", file=out)
        else:
            checked = sum(
                len(entry.get(k, {})) for k in ("latency_s", "rate", "exact")
            )
            print(
                f"OK   {name}: {checked} metrics within tolerance "
                f"(latency ×{ratio:g}, rate +{slack:g})",
                file=out,
            )
    return 1 if failures else 0


def main(argv=None):  # pragma: no cover — exercised by the CI gate job
    """``python -m repro.obs.slo --baselines B.json ARTIFACT [ARTIFACT...]``"""
    import argparse

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("artifacts", nargs="+",
                   help="bench JSON artifacts to gate (BENCH_query.json, "
                        "BENCH_serve_load.json)")
    p.add_argument("--baselines", required=True,
                   help="committed baselines file "
                        "(benchmarks/slo_baselines.json)")
    p.add_argument("--tolerance-ratio", type=float, default=None,
                   help="override the latency tolerance multiplier")
    p.add_argument("--rate-slack", type=float, default=None,
                   help="override the absolute rate slack")
    args = p.parse_args(argv)
    return run_gate(
        args.artifacts,
        args.baselines,
        tolerance_ratio=args.tolerance_ratio,
        rate_slack=args.rate_slack,
    )


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
