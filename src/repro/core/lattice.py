"""Concept lattice construction from a mined intent set.

FCA's main theorem guarantees the complete set of intents forms a lattice
under set inclusion; this module materializes the covering relation (Hasse
diagram) used by the examples, the paper-example tests (Table 2) and the
query subsystem (:mod:`repro.query.store`).

Two interchangeable covering builders:
  * ``matmul`` (default) — the subset relation as one popcount matmul over
    unpacked bit-planes (``|y_i ∩ y_j| == |y_i|``), and the transitive
    reduction as a second boolean matmul (``strict & ~(strict ∘ strict)``).
    O(C²·m + C³) BLAS work instead of O(C²) interpreted Python; the same
    arithmetic runs device-side in the concept store.
  * ``host`` — the original per-pair Python loop, kept as the equivalence
    oracle (tests/test_lattice.py property-tests the two against each other
    and against a brute-force transitive-reduction oracle).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset, closure
from repro.core.context import FormalContext

METHODS = ("matmul", "host")


@dataclasses.dataclass
class ConceptLattice:
    intents: np.ndarray  # [C, W] uint32, sorted by popcount ascending
    extents: np.ndarray  # [C, N] bool
    children: list[list[int]]  # covering relation: i covers j (j's intent ⊂ i's)

    @property
    def n_concepts(self) -> int:
        return self.intents.shape[0]

    def top(self) -> int:
        """Index of ⟨O, ∅''⟩ — the concept with the smallest intent."""
        return 0

    def bottom(self) -> int:
        return self.n_concepts - 1


def subset_matrix(intents: np.ndarray, n_attrs: int) -> np.ndarray:
    """``leq[i, j] = intent_i ⊆ intent_j`` for packed intents [C, W].

    One popcount matmul over the unpacked {0,1} bit-planes: with
    ``B = bits(intents)``, ``(B @ B.T)[i, j] = |y_i ∩ y_j|``, and
    ``y_i ⊆ y_j ⟺ |y_i ∩ y_j| == |y_i|``.  fp32 accumulation is exact
    (counts ≤ m ≪ 2²⁴).
    """
    bits = bitset.unpack_bits(intents, n_attrs).astype(np.float32)
    inter = bits @ bits.T  # [C, C] — |y_i ∩ y_j|
    sizes = bits.sum(axis=1)
    return inter == sizes[:, None]


def covering_matmul(leq: np.ndarray) -> np.ndarray:
    """Transitive reduction of a strict containment order as a matmul.

    ``strict[i, j] = y_i ⊂ y_j``; ``i`` is covered by ``j`` iff no ``k``
    lies strictly between, i.e. ``(strict ∘ strict)[i, j] == 0``.
    """
    strict = leq & ~np.eye(leq.shape[0], dtype=bool)
    s = strict.astype(np.float32)
    via = (s @ s) > 0  # [i, j]: ∃k with i ⊂ k ⊂ j
    return strict & ~via


def build_lattice(
    ctx: FormalContext, intents: list[np.ndarray], *, method: str = "matmul"
) -> ConceptLattice:
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; choose {METHODS}")
    arr = np.stack(intents)
    sizes = bitset.popcount(arr)
    order = np.argsort(sizes, kind="stable")
    arr = arr[order]
    sizes = sizes[order]
    extents = np.stack([closure.extent_np(ctx.rows, y) for y in arr])

    C = arr.shape[0]
    if method == "matmul":
        cover = covering_matmul(subset_matrix(arr, ctx.n_attrs))
        children = [list(np.nonzero(cover[:, i])[0]) for i in range(C)]
        return ConceptLattice(intents=arr, extents=extents, children=children)

    children = [[] for _ in range(C)]
    # i covers j  ⟺  intent[j] ⊂ intent[i] and no k with j ⊂ k ⊂ i.
    for i in range(C):
        subs = [
            j
            for j in range(i)
            if sizes[j] < sizes[i] and bool(bitset.is_subset(arr[j], arr[i]))
        ]
        sub_set = set(subs)
        for j in subs:
            if not any(
                k in sub_set and bool(bitset.is_subset(arr[j], arr[k])) and k != j
                for k in subs
                if sizes[k] > sizes[j]
            ):
                children[i].append(j)
    return ConceptLattice(intents=arr, extents=extents, children=children)
