"""repro.analysis — static & schedule analysis for the SPMD/serve stack.

Three passes over the mining engines and the serving tier, run by
``python -m repro.analysis`` (``--strict`` is the CI gate):

1. :mod:`repro.analysis.spmd_audit` — traces every cached SPMD step
   (frontier variants, their fused-kernel twins, the QueryEngine batch
   steps, the rules/basis device passes) at the jaxpr level under
   multiple partition geometries and verifies collective axis binding,
   schedule order, the wire-byte census against the analytic model, and
   region hygiene (no callbacks/d2h inside SPMD regions).

2. :mod:`repro.analysis.lint` — AST rules: no host syncs in the async
   round loops, no wall-clock reads in clock-injectable serve code, no
   mutable defaults / jit-in-loop recompile hazards, no bare excepts.

3. :mod:`repro.analysis.locks` + :mod:`repro.analysis.fuzz` — static
   lock-discipline inference over the serve-tier classes, plus a
   deterministic schedule-fuzzing harness that replays seeded
   submit/poll/stage/commit interleavings under a virtual clock and
   checks happens-before invariants on snapshot versions.

:mod:`repro.analysis.inventory` additionally emits the import-graph
dead-code census (``ANALYSIS_inventory.json``).
"""

from repro.analysis.findings import Finding, Report

PASSES = ("spmd", "lint", "locks", "fuzz")


def run_all(passes=PASSES, *, quick: bool = False, root=None) -> Report:
    """Run the selected passes into one :class:`Report`.

    Pass modules import lazily: the linter and lock checker are pure-AST
    and must stay runnable even when jax is mid-upgrade or the kernels
    fail to import.
    """
    report = Report()
    if "lint" in passes:
        from repro.analysis import lint

        report.extend(lint.run(report, root=root))
    if "locks" in passes:
        from repro.analysis import locks

        report.extend(locks.run(report, root=root))
    if "fuzz" in passes:
        from repro.analysis import fuzz

        report.extend(fuzz.run(report))
    if "spmd" in passes:
        from repro.analysis import spmd_audit

        report.extend(spmd_audit.run(report, quick=quick))
    return report


__all__ = ["Finding", "Report", "PASSES", "run_all"]
