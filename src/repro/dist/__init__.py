"""Distribution substrate: the ShardPlan SPMD layer, collectives,
logical-axis partitioning, pipeline parallelism, and gradient compression.

``shardplan`` is the partition-aware execution layer every MR* round runs
through (one plan abstraction covering real meshes and simulated
partitions); ``collectives`` is its reduce phase (paper Theorem 2: global
closure = bitwise-AND of per-partition local closures); the rest serves
the LM training/serving half of the system.
"""

from repro.dist.shardplan import ShardPlan

__all__ = ["ShardPlan"]
