"""RecurrentGemma / Griffin recurrent blocks: RG-LRU + temporal conv.

The recurrent block runs two branches from the block input:
  * gate branch:       linear(d→w) → GeLU
  * recurrence branch: linear(d→w) → causal conv1d(K=4) → RG-LRU
merged multiplicatively and projected back (w→d).

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = σ(x_t W_a + b_a)          recurrence gate
    i_t = σ(x_t W_x + b_x)          input gate
    a_t = exp(−c · softplus(Λ) · r_t)          (c = 8)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Train/prefill evaluates the recurrence with ``lax.associative_scan``
(log-depth — TPU-friendly); decode keeps an explicit [B, w] state, giving
O(1) memory per token (why recurrentgemma is eligible for long_500k).

Simplification vs the official model: gate projections are dense [w, w]
rather than block-diagonal-by-head (noted in DESIGN.md; capacity superset).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

_C = 8.0


class RecCache(NamedTuple):
    conv: jax.Array  # [B, K-1, w]
    h: jax.Array  # [B, w] fp32


def _width(cfg: ModelConfig) -> int:
    return cfg.griffin.lru_width or cfg.d_model


def init_recurrent(pb: layers.ParamBuilder, cfg: ModelConfig):
    d, w = cfg.d_model, _width(cfg)
    K = cfg.griffin.conv_width
    return {
        "proj_rec": pb.dense((d, w), ("embed", "lru")),
        "proj_gate": pb.dense((d, w), ("embed", "lru")),
        "conv_w": pb.dense((K, w), ("conv", "lru"), fan_in=K),
        "conv_b": pb.zeros((w,), ("lru",)),
        "w_a": pb.dense((w, w), ("lru", "lru")),
        "b_a": pb.zeros((w,), ("lru",)),
        "w_x": pb.dense((w, w), ("lru", "lru")),
        "b_x": pb.zeros((w,), ("lru",)),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 — standard griffin init.
        "lam": pb.value(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)),
            ("lru",),
        ),
        "proj_out": pb.dense((w, d), ("lru", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out + b


def _gates(params, x: jax.Array):
    """x [..., w] fp32 → (a, gated input) per RG-LRU equations."""
    r = jax.nn.sigmoid(x @ params["w_a"].astype(jnp.float32) + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x @ params["w_x"].astype(jnp.float32) + params["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)
    return a, b


def rec_block_full(params, xin: jax.Array, cfg: ModelConfig):
    """Train/prefill.  xin [B, L, d] → (y [B, L, d], final RecCache)."""
    gate = jax.nn.gelu(xin @ params["proj_gate"], approximate=True)
    xr_raw = xin @ params["proj_rec"]
    xr = _causal_conv(xr_raw, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xr.astype(jnp.float32))

    # h_t = a_t h_{t-1} + b_t  via associative scan over time.
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h_all = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h_all.astype(xin.dtype) * gate) @ params["proj_out"]

    K = cfg.griffin.conv_width
    conv_state = xr_raw[:, -(K - 1):, :]
    pad = K - 1 - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    return y, RecCache(conv=conv_state, h=h_all[:, -1].astype(jnp.float32))


def init_rec_cache(cfg: ModelConfig, batch: int, dtype) -> RecCache:
    w, K = _width(cfg), cfg.griffin.conv_width
    return RecCache(
        conv=jnp.zeros((batch, K - 1, w), dtype),
        h=jnp.zeros((batch, w), jnp.float32),
    )


def rec_block_decode(params, xin: jax.Array, cfg: ModelConfig, cache: RecCache):
    """One token.  xin [B, 1, d] → (y [B, 1, d], new cache)."""
    gate = jax.nn.gelu(xin @ params["proj_gate"], approximate=True)  # [B,1,w]
    xr_raw = xin @ params["proj_rec"]  # [B, 1, w]
    window = jnp.concatenate([cache.conv, xr_raw], axis=1)  # [B, K, w]
    xr = jnp.einsum("bkw,kw->bw", window, params["conv_w"]) + params["conv_b"]
    a, b = _gates(params, xr.astype(jnp.float32))  # [B, w]
    h = a * cache.h + b
    y = (h[:, None, :].astype(xin.dtype) * gate) @ params["proj_out"]
    return y, RecCache(conv=window[:, 1:, :], h=h)
