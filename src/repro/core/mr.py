"""The MR* miners: MRGanter, MRGanter+ and MRCbo (paper §3), as iterative
drivers over a :class:`repro.core.engine.ClosureEngine`.

Each driver is the Twister control loop: the engine holds the static data
(context sharded by its :class:`repro.dist.ShardPlan`); the *dynamic data*
— the frontier of previous intents — crosses the host/device boundary once
per iteration, exactly like Twister re-configuring its long-running map
tasks with the previous iteration's closures.  Every closure round the
drivers issue executes through the engine's plan — one partitioned path
whether the partitions are a real device mesh or simulated on one chip.

Two frontier substrates (``pipeline=``):

  * ``"device"`` (default) — the device-resident pipeline of
    :mod:`repro.core.frontier`: seed expansion, dedupe/canonicity and
    feasibility all run as jitted bucket-shaped device ops; the host loop
    is convergence control plus the global registry.  O(1) bulk transfers
    per iteration.
  * ``"host"`` — the paper-literal host loop (per-intent Python seed
    building, per-row hash inserts).  Kept as the equivalence oracle and
    the baseline for EXPERIMENTS.md §Perf.

Both substrates produce bit-identical concept sets
(tests/test_frontier_pipeline.py); MRGanter additionally preserves exact
lectic emission order on both.

Iteration counts follow the paper's convention (Table 9): every map/reduce
round over the full context counts as one iteration, including the round
that computes ``∅''`` and, for MRGanter+/MRCbo, the final round that proves
the frontier is exhausted.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import bitset, lectic
from repro.core.engine import ClosureEngine
from repro.core.frontier import DeviceFrontier
from repro.core.hashindex import TwoLevelHash

PIPELINES = ("device", "host")


@dataclasses.dataclass
class MRResult:
    intents: list[np.ndarray]
    n_iterations: int
    n_closures_computed: int
    modeled_comm_bytes: int
    wall_time_s: float
    algorithm: str

    @property
    def n_concepts(self) -> int:
        return len(self.intents)


def _seeds_for(Y: np.ndarray, tables: lectic.LecticTables) -> np.ndarray:
    seeds, valid = lectic.oplus_seeds_all(Y, tables)
    return seeds[valid]


def _check_pipeline(pipeline: str):
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; choose {PIPELINES}")


def _result(engine: ClosureEngine, intents, n_iter, t0, algorithm) -> MRResult:
    return MRResult(
        intents=intents,
        n_iterations=n_iter,
        n_closures_computed=engine.stats.closures_computed,
        modeled_comm_bytes=engine.stats.modeled_comm_bytes,
        wall_time_s=time.perf_counter() - t0,
        algorithm=algorithm,
    )


# ---------------------------------------------------------------------------
# MRGanter (Algorithms 4 + 5): strict lectic order, one concept/iteration.
# ---------------------------------------------------------------------------


def mrganter(
    ctx,
    engine: ClosureEngine,
    max_iterations: int | None = None,
    *,
    pipeline: str = "device",
) -> MRResult:
    _check_pipeline(pipeline)
    t0 = time.perf_counter()
    full = ctx.attr_mask()
    Y, _ = engine.first_closure()
    intents = [Y]
    n_iter = 1

    if pipeline == "device":
        fr = DeviceFrontier(engine)
        fr.set_frontier(Y[None, :])
        done = np.array_equal(Y, full)
        while not done:
            if max_iterations is not None and n_iter >= max_iterations:
                break
            Y, done = fr.step_ganter()
            intents.append(Y)
            n_iter += 1
        return _result(engine, intents, n_iter, t0, "mrganter")

    tables = lectic.LecticTables(ctx.n_attrs)
    while not np.array_equal(Y, full):
        if max_iterations is not None and n_iter >= max_iterations:
            break
        # Map: local closures for every attribute p_i ∉ d (Alg. 4).
        seeds, valid = lectic.oplus_seeds_all(Y, tables)
        closures, _ = engine.closure(seeds)  # Reduce: Theorem-2 intersection
        # Feasibility ≤_{p_i} (Alg. 5): first success scanning p_m → p_1.
        ok = lectic.feasible_batch(closures, Y, tables) & valid
        idx = np.nonzero(ok)[0]
        assert idx.size, "NextClosure invariant: a feasible successor exists"
        Y = closures[int(idx.max())]
        intents.append(Y)
        n_iter += 1
    return _result(engine, intents, n_iter, t0, "mrganter")


# ---------------------------------------------------------------------------
# MRGanter+ (Algorithms 4 + 6): keep all new closures, dedupe via the
# two-level hash; iterations collapse to ~lattice depth.
# ---------------------------------------------------------------------------


def mrganter_plus(
    ctx,
    engine: ClosureEngine,
    *,
    dedupe_candidates: bool = False,
    dedupe_closures: bool = False,
    local_prune: bool | None = None,
    max_iterations: int | None = None,
    pipeline: str = "device",
) -> MRResult:
    """``dedupe_candidates=False`` is the paper-literal map phase (every
    frontier intent emits a candidate for every absent attribute).  ``True``
    drops duplicate *seeds* before the closure — the paper's per-partition
    local pruning: on the device pipeline the dedupe is the on-device
    lexsort+adjacent-unique stage, run partition-locally *before* the
    AND-allreduce is sized, so pruned candidates never cross the wire
    (EXPERIMENTS.md §Dist quantifies the reduce-byte savings); on the host
    loop it is ``np.unique``.  Same output either way.  ``local_prune`` is
    the paper-facing alias for the same switch (it wins when both are
    given).
    """
    _check_pipeline(pipeline)
    if local_prune is not None:
        dedupe_candidates = local_prune
    t0 = time.perf_counter()
    H = TwoLevelHash()
    Y0, _ = engine.first_closure()
    H.add(Y0)
    intents = [Y0]
    n_iter = 1

    if pipeline == "device":
        fr = DeviceFrontier(engine, dedupe_closures=dedupe_closures)
        fr.set_frontier(Y0[None, :])
        while len(fr):
            if max_iterations is not None and n_iter >= max_iterations:
                break
            uniq = fr.step_oplus(dedupe=dedupe_candidates)
            if uniq.shape[0] == 0:
                break
            n_iter += 1
            new_idx = H.add_batch(uniq)  # global registry (vectorized)
            new = uniq[new_idx]
            intents.extend(new)
            if new.shape[0]:
                fr.set_frontier(new)  # the Twister dynamic delta, one upload
            else:
                fr.set_frontier(np.zeros((0, ctx.W), np.uint32))
        return _result(engine, intents, n_iter, t0, "mrganter+")

    tables = lectic.LecticTables(ctx.n_attrs)
    frontier = [Y0]
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seed_list = [_seeds_for(Y, tables) for Y in frontier]
        seeds = (
            np.concatenate(seed_list, axis=0)
            if seed_list
            else np.zeros((0, ctx.W), np.uint32)
        )
        if seeds.shape[0] == 0:
            break
        if dedupe_candidates:
            seeds = np.unique(seeds, axis=0)
        n_iter += 1
        closures, _ = engine.closure(seeds)
        new_idx = H.add_batch(closures)
        frontier = [closures[i] for i in new_idx]
        intents.extend(frontier)
    return _result(engine, intents, n_iter, t0, "mrganter+")


# ---------------------------------------------------------------------------
# MRCbo: distributed CloseByOne under the same engine (paper §5 baseline).
# ---------------------------------------------------------------------------


def mrcbo(
    ctx,
    engine: ClosureEngine,
    max_iterations: int | None = None,
    *,
    pipeline: str = "device",
) -> MRResult:
    _check_pipeline(pipeline)
    t0 = time.perf_counter()
    root, _ = engine.first_closure()
    intents = [root]
    n_iter = 1

    if pipeline == "device":
        fr = DeviceFrontier(engine)
        fr.set_frontier(root[None, :], gens=np.array([-1], np.int32))
        while len(fr):
            if max_iterations is not None and n_iter >= max_iterations:
                break
            new, n_seeds, _ = fr.step_cbo()  # canonicity filter IS the dedupe
            if n_seeds == 0:  # frontier exhausted before any closure round
                break
            n_iter += 1
            intents.extend(new)
        return _result(engine, intents, n_iter, t0, "mrcbo")

    tables = lectic.LecticTables(ctx.n_attrs)
    frontier: list[tuple[np.ndarray, int]] = [(root, -1)]
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seeds, parents, gens = [], [], []
        for Y, g in frontier:
            member = bitset.unpack_bits(Y, ctx.n_attrs)
            for a in range(g + 1, ctx.n_attrs):
                if not member[a]:
                    seeds.append(Y | tables.BIT[a])
                    parents.append(Y)
                    gens.append(a)
        if not seeds:
            break
        n_iter += 1
        closures, _ = engine.closure(np.stack(seeds))
        next_frontier = []
        for i in range(closures.shape[0]):
            a, Y, Z = gens[i], parents[i], closures[i]
            if np.all(((Z ^ Y) & tables.LOW[a]) == 0):  # CbO canonicity
                intents.append(Z)
                next_frontier.append((Z, a))
        frontier = next_frontier
    return _result(engine, intents, n_iter, t0, "mrcbo")
