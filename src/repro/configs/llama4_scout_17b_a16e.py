"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Multimodal early
fusion is frontend-side and stubbed per assignment rules (text tokens)."""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    rope_kind="standard",
    rope_theta=500_000.0,
    mlp_kind="swiglu",
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8192,
        shared_expert=True,
    ),
)
