"""ShardPlan — the one partition-aware SPMD execution layer (paper §3).

Every MR* round is the same program: per-shard local closure over the
object-partitioned context, then a bitwise-AND all-reduce (Theorem 2) plus
whatever per-round filter rides along (dedupe, canonicity, feasibility).
Historically the engine kept two divergent code paths for this — a
``shard_map`` path over a real jax Mesh and a hand-rolled reshape-and-vmap
path for simulated partitions on one device.  ``ShardPlan`` collapses both
behind one abstraction that owns

  * **partition geometry** — object-axis shard count for the context
    (``n_parts``), block alignment (``block_n``) and the frontier-batch
    chunk cap for candidates (``max_batch``);
  * **device placement** — ``place_rows`` shards the context over the
    plan's axes, ``replicate`` pins frontier/table state to every shard;
  * **the collective schedule** — which AND-allreduce implementation
    (``allgather`` / ``rsag`` / ``pmin``, see :mod:`repro.dist.collectives`)
    the reduce phase runs, and its analytic wire-byte model.  With
    ``reduce_impl="auto"`` the plan autotunes: ``resolve_impl`` picks
    allgather-vs-rsag per round by minimizing the α-β cost model
    (wire volume + ring-step latency) for that round's padded batch.

The plan is 2-D capable: besides the object axes it can block the
*candidate/frontier* axis over ``cand_parts`` devices (a ``"cand"`` mesh
axis) or simulated lanes — the Spark FCA reproduction's row-block ×
column-block decomposition.  ``spmd_cand`` is the 2-D execution
primitive: candidate operands are blocked along ``cand``, the
AND-allreduce runs over the object axes only (inside each block, at the
block batch size), driver filters run block-locally, and only the
filtered survivors are all-gathered along ``cand``.

``spmd(body, n_rep)`` is the 1-D execution primitive: ``body`` receives
the local context shard plus replicated operands and may call collectives
over ``plan.reduce_axes``.  On a mesh plan it lowers through
``shard_map``; on a simulated plan the *same body* runs under ``jax.vmap``
with a named axis over the reshaped ``[k, N/k, W]`` rows — jax's batched
collective rules make ``all_gather`` / ``all_to_all`` / ``pmin`` /
``psum`` execute the identical arithmetic, so the two modes are
bit-identical by construction (asserted in tests/test_shardplan.py and the
8-device harness).  The AND semigroup is associative, commutative and
idempotent over uint32 words, so every schedule agrees bit-for-bit too.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.dist import collectives
from repro.dist.partition import object_axes

# vmap axis name carrying the simulated object partition. Collectives in a
# shard body reference ``plan.reduce_axes`` and never this name directly.
SIM_AXIS = "objpart"

# vmap axis name carrying the simulated *candidate* partition (the frontier
# axis of the 2-D decomposition).  On a mesh the candidate axis is the mesh
# axis named "cand"; bodies reference ``plan.cand_axes``.
SIM_CAND_AXIS = "candpart"

# Mesh axis name carrying the candidate partition on real meshes.
CAND_AXIS = "cand"

# Schedules the autotuner arbitrates between. ``pmin`` is excluded: its
# unpacked-lane volume is strictly dominated for every batch size.
AUTO_IMPLS = ("allgather", "rsag")


def _attach_audit(runner, spec: dict):
    """Attach the static-analysis contract to an SPMD runner.

    ``repro.analysis.spmd_audit`` traces ``spec["shard_fn"]`` — the
    canonical per-shard function, *before* shard_map/vmap lowering — under
    an extended axis environment to verify the collective schedule and the
    wire-byte census against the plan's analytic model.  The attribute
    survives ``jax.jit`` (the jit wrapper forwards attribute access), so
    the auditor can introspect the exact jitted steps the engine caches.
    """
    try:
        runner.audit_spec = spec
    except (AttributeError, TypeError):  # exotic callables: skip, don't break
        pass
    return runner


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Partition geometry + placement + collective schedule for one run."""

    mesh: Mesh | None
    axis_names: tuple[str, ...]
    n_parts: int
    reduce_impl: str = "rsag"
    block_n: int = 256
    max_batch: int = 8192
    # 2-D decomposition: the candidate/frontier axis is blocked over
    # ``cand_parts`` devices (mesh axes ``cand_axis_names``) or simulated
    # lanes.  Objects stay sharded over ``axis_names`` as before; the
    # AND-allreduce runs inside each candidate block (over the object axes
    # only) and survivors are all-gathered along ``cand`` after the fused
    # post-reduce filters — see :meth:`spmd_cand`.
    cand_parts: int = 1
    cand_axis_names: tuple[str, ...] = ()
    # latency term of the "auto" schedule model: bandwidth-equivalent byte
    # cost of one ring step per device (collectives.modeled_cost_bytes).
    # The 4096 B default is replaced by a measured value when the plan is
    # built with ``calibrate_hops=True`` (see :func:`probe_hop_bytes`).
    auto_hop_bytes: int = 4096
    hop_calibrated: bool = False

    def __post_init__(self):
        if (
            self.reduce_impl != "auto"
            and self.reduce_impl not in collectives.IMPLS
        ):
            raise ValueError(
                f"unknown reduce schedule {self.reduce_impl!r}; "
                f"choose {collectives.IMPLS + ('auto',)}"
            )
        if self.n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {self.n_parts}")
        if self.cand_parts < 1:
            raise ValueError(
                f"cand_parts must be >= 1, got {self.cand_parts}"
            )
        if self.mesh is not None and self.cand_parts > 1:
            k = 1
            for a in self.cand_axis_names:
                k *= self.mesh.shape[a]
            if k != self.cand_parts:
                raise ValueError(
                    f"cand_parts ({self.cand_parts}) does not match the "
                    f"mesh's candidate axes {self.cand_axis_names} ({k})"
                )

    # -- constructors ------------------------------------------------------

    @classmethod
    def simulated(
        cls,
        n_parts: int = 1,
        *,
        cand_parts: int = 1,
        reduce_impl: str = "rsag",
        block_n: int = 256,
        max_batch: int = 8192,
        calibrate_hops: bool = False,
    ) -> "ShardPlan":
        """``n_parts`` object shards on one device (reshape + named vmap);
        ``cand_parts`` > 1 adds simulated candidate-axis lanes."""
        plan = cls(
            mesh=None,
            axis_names=(SIM_AXIS,),
            n_parts=n_parts,
            reduce_impl=reduce_impl,
            block_n=block_n,
            max_batch=max_batch,
            cand_parts=cand_parts,
            cand_axis_names=(SIM_CAND_AXIS,) if cand_parts > 1 else (),
        )
        return plan.calibrate_hops() if calibrate_hops else plan

    @classmethod
    def over_mesh(
        cls,
        mesh: Mesh,
        *,
        axis_names: tuple[str, ...] | None = None,
        cand_axis_names: tuple[str, ...] | None = None,
        reduce_impl: str = "rsag",
        block_n: int = 256,
        max_batch: int = 8192,
        calibrate_hops: bool = False,
    ) -> "ShardPlan":
        """Real SPMD over ``mesh``; object rows sharded over ``axis_names``
        (default: whichever of the pod×data axes the mesh carries).  A mesh
        axis named ``"cand"`` (or explicit ``cand_axis_names``) blocks the
        candidate/frontier axis across devices — the 2-D decomposition."""
        if cand_axis_names is None:
            cand_axis_names = (CAND_AXIS,) if CAND_AXIS in mesh.shape else ()
        if axis_names is None:
            axis_names = object_axes(mesh)
        axis_names = tuple(a for a in axis_names if a not in cand_axis_names)
        if not axis_names:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has none of the object axes"
            )
        k = 1
        for a in axis_names:
            k *= mesh.shape[a]
        c = 1
        for a in cand_axis_names:
            c *= mesh.shape[a]
        plan = cls(
            mesh=mesh,
            axis_names=tuple(axis_names),
            n_parts=k,
            reduce_impl=reduce_impl,
            block_n=block_n,
            max_batch=max_batch,
            cand_parts=c,
            cand_axis_names=tuple(cand_axis_names) if c > 1 else (),
        )
        return plan.calibrate_hops() if calibrate_hops else plan

    @classmethod
    def auto(
        cls, n_parts: int = 8, *, reduce_impl: str = "rsag", **kw
    ) -> "ShardPlan":
        """Mesh plan over all local devices when there are >1, else a
        simulated ``n_parts``-way plan on the single device."""
        devices = jax.devices()
        if len(devices) > 1:
            mesh = Mesh(np.asarray(devices), ("data",))
            return cls.over_mesh(mesh, reduce_impl=reduce_impl, **kw)
        return cls.simulated(n_parts, reduce_impl=reduce_impl, **kw)

    def calibrate_hops(self) -> "ShardPlan":
        """This plan with ``auto_hop_bytes`` measured, not defaulted.

        Runs :func:`probe_hop_bytes` (one-shot per interconnect, cached at
        module level) and records the result — the "auto" schedule's
        latency term then reflects the actual allgather step cost of the
        devices under the plan instead of the 4096 B guess.
        ``hop_calibrated`` stays False when the probe hit its noise floor
        (no measurable per-byte slope) and fell back to the default —
        the stats never claim a measurement that didn't happen.
        """
        hop, measured = probe_hop_bytes(self)
        return dataclasses.replace(
            self, auto_hop_bytes=hop, hop_calibrated=measured
        )

    # -- geometry ----------------------------------------------------------

    @property
    def is_simulated(self) -> bool:
        return self.mesh is None

    @property
    def reduce_axes(self):
        """Axis name(s) the shard body's collectives reduce over."""
        if self.mesh is None:
            return SIM_AXIS
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]

    @property
    def cand_axes(self):
        """Axis name(s) carrying the candidate partition (2-D plans only)."""
        if self.cand_parts <= 1:
            return None
        if self.mesh is None:
            return SIM_CAND_AXIS
        return (
            self.cand_axis_names
            if len(self.cand_axis_names) > 1
            else self.cand_axis_names[0]
        )

    @property
    def row_alignment(self) -> int:
        """Context rows must pad to a multiple of this (shards block-align)."""
        return self.n_parts * self.block_n

    def shard_index(self):
        """This shard's position along the object partition, traced.

        Only meaningful inside an ``spmd`` body.  Multi-axis meshes fold
        major-to-minor in ``axis_names`` order — the same order
        ``place_rows``'s ``PartitionSpec`` splits the row axis, so
        ``shard_index() * rows_local.shape[0]`` is the global offset of the
        shard's first row.
        """
        if self.mesh is None:
            return lax.axis_index(SIM_AXIS)
        idx = lax.axis_index(self.axis_names[0])
        for a in self.axis_names[1:]:
            idx = idx * self.mesh.shape[a] + lax.axis_index(a)
        return idx

    def cand_index(self):
        """This shard's position along the candidate partition, traced.

        Only meaningful inside an ``spmd_cand`` body; 0 on 1-D plans.
        Folds multi-axis candidate meshes major-to-minor exactly as
        ``shard_index`` folds the object axes."""
        if self.cand_parts <= 1:
            return jnp.int32(0)
        if self.mesh is None:
            return lax.axis_index(SIM_CAND_AXIS)
        idx = lax.axis_index(self.cand_axis_names[0])
        for a in self.cand_axis_names[1:]:
            idx = idx * self.mesh.shape[a] + lax.axis_index(a)
        return idx

    # -- placement ---------------------------------------------------------

    def place_rows(self, rows: np.ndarray) -> jax.Array:
        """Shard padded context rows ``[N, W]`` over the object axes.

        Mesh plan: ``NamedSharding`` over ``axis_names``.  Simulated plan:
        reshape to ``[k, N/k, W]`` so the named-vmap axis is the partition.
        """
        if rows.shape[0] % self.n_parts:
            raise ValueError(
                f"rows ({rows.shape[0]}) not divisible by n_parts ({self.n_parts})"
            )
        if self.mesh is not None:
            sharding = NamedSharding(self.mesh, P(self.axis_names, None))
            return jax.device_put(jnp.asarray(rows), sharding)
        return jnp.asarray(rows).reshape(
            self.n_parts, rows.shape[0] // self.n_parts, *rows.shape[1:]
        )

    def replicate(self, arr) -> jax.Array:
        """Pin dynamic per-round state (frontier, tables) to every shard, so
        expansion/pruning compute runs partition-locally instead of on one
        device followed by a broadcast at the SPMD region boundary."""
        if self.mesh is not None:
            return jax.device_put(jnp.asarray(arr), NamedSharding(self.mesh, P()))
        return jnp.asarray(arr)

    # -- execution ---------------------------------------------------------

    def spmd(
        self,
        body,
        *,
        n_rep: int,
        post=None,
        n_post_rep: int = 0,
        out_shard: tuple[bool, ...] | None = None,
    ):
        """Wrap ``body(rows_local, *replicated)`` for per-shard execution.

        The first argument is the object-sharded context; the following
        ``n_rep`` arguments are replicated.  ``body`` may call collectives
        over ``self.reduce_axes``; outputs must be shard-invariant (i.e.
        globally reduced or computed from replicated operands) and come
        back replicated — unless ``out_shard`` marks them otherwise.

        ``out_shard`` gives one region *mixed* output placement: a tuple of
        booleans, one per ``body`` output, where True means the output stays
        object-sharded (its leading axis is this shard's row slice — the
        same layout ``place_rows`` produces) and False means replicated /
        shard-invariant.  This is how the concept store builds the extent
        table on device: one region emits the sharded packed extent columns
        *and* the psum-reduced supports without a host round-trip.
        Incompatible with ``post`` (which by definition consumes
        shard-invariant inputs).

        ``post(*body_outputs, *post_replicated)`` is an optional fused
        stage consuming the shard-invariant reduced outputs (canonicity,
        feasibility, dedupe).  Because its input is identical on every
        shard, the plan owns its placement: on a mesh it runs inside the
        same SPMD region (each partition filters locally — the whole round
        is one ``shard_map``); on a simulated plan it runs once after the
        vmapped map+reduce, instead of k redundant lane copies on the one
        device.  Bit-identical either way.  The returned callable takes
        ``(rows, *replicated, *post_replicated)``; callers normally wrap
        it in ``jax.jit``.

        ``body`` may itself be a Pallas kernel call — the fused frontier
        steps (``repro.kernels.frontier``) run their ``pallas_call``
        inside this region: on a single-part plan the whole step (closure
        → support → filter) is one kernel; on multi-part plans the map
        kernel runs per shard here and the filter kernel rides in
        ``post`` after the cross-shard AND-allreduce.
        """
        if out_shard is not None and post is not None:
            raise ValueError("out_shard= and post= are mutually exclusive")

        # Canonical shard-level function — what one device runs inside the
        # SPMD region.  The mesh branch lowers exactly this through
        # shard_map; the simulated branch is its vmap twin.  The auditor
        # traces it (via ``audit_spec``) under an extended axis env, so
        # both branches expose identical collective structure.
        def fused(rows_local, *rep):
            out = body(rows_local, *rep[:n_rep])
            if post is None:
                return out
            out = out if isinstance(out, tuple) else (out,)
            return post(*out, *rep[n_rep:])

        spec = {
            "kind": "spmd",
            "plan": self,
            "shard_fn": fused,
            "n_rep": n_rep,
            "n_post_rep": n_post_rep,
            "has_post": post is not None,
        }
        if self.mesh is not None:
            in_specs = (P(self.axis_names, None),) + (P(),) * (n_rep + n_post_rep)
            if out_shard is None:
                out_specs = P()
            else:
                out_specs = tuple(
                    P(self.axis_names) if s else P() for s in out_shard
                )
            return _attach_audit(
                compat.shard_map(
                    fused,
                    mesh=self.mesh,
                    in_specs=in_specs,
                    out_specs=out_specs,
                    check_vma=False,  # pallas_call outputs carry no vma info
                ),
                spec,
            )

        vbody = jax.vmap(
            body,
            in_axes=(0,) + (None,) * n_rep,
            out_axes=0,
            axis_name=SIM_AXIS,
        )

        def run(rows, *rep):
            outs = vbody(rows, *rep[:n_rep])
            if out_shard is not None:
                # Sharded outputs keep the [k, rows/k, ...] lane-major
                # layout (the simulated twin of place_rows); replicated
                # ones collapse to lane 0 as usual.
                return tuple(
                    o if s else jax.tree_util.tree_map(lambda x: x[0], o)
                    for o, s in zip(outs, out_shard)
                )
            # Outputs are identical on every simulated shard (same invariant
            # the mesh path's ``out_specs=P()`` asserts); keep shard 0.
            outs = jax.tree_util.tree_map(lambda o: o[0], outs)
            if post is None:
                return outs
            outs = outs if isinstance(outs, tuple) else (outs,)
            return post(*outs, *rep[n_rep:])

        return _attach_audit(run, spec)

    def spmd_cand(
        self,
        body,
        *,
        n_cand: int = 1,
        n_rep: int = 0,
        post=None,
        n_post_rep: int = 0,
        merge=None,
        n_merge_rep: int = 0,
    ):
        """2-D (candidate × object) twin of :meth:`spmd`.

        The returned callable takes ``(rows, *cand_ops, *replicated,
        *post_replicated, *merge_replicated)``.  The first ``n_cand``
        operands after ``rows`` are *candidate-sharded*: their leading axis
        (a multiple of ``cand_parts``) is blocked over the candidate axis,
        so each device materializes only its ``1/cand_parts`` block of the
        frontier chunk.  ``body(rows_local, *cand_blocks, *replicated)``
        computes the per-(object-shard × candidate-block) map and may call
        collectives over ``reduce_axes`` — the AND-allreduce runs *inside*
        each candidate block, over the object axes only, at the block's
        batch size.

        ``post(cand_idx, *body_outputs, *post_replicated)`` is the fused
        block-local filter (canonicity / dedupe / iceberg cut): its inputs
        are object-shard-invariant but *differ per candidate block*, so it
        runs once per block (every object shard of a block computes it
        redundantly on a mesh — the same placement rule as ``spmd``'s
        post).  ``cand_idx`` is the block's position, letting the filter
        reconstruct global row validity from a replicated scalar count.

        Only after ``post`` are the blocks' survivors all-gathered along
        the candidate axis — pruned candidates never replicate across
        ``cand`` — giving every output a leading ``[cand_parts, ...]``
        block axis.  ``merge(*gathered, *merge_replicated)`` (optional)
        consumes the gathered stacks; its inputs are fully shard-invariant
        so the plan places it exactly like ``spmd``'s post: in-region on a
        mesh, once past the vmaps on a simulated plan.

        Degenerates gracefully at ``cand_parts == 1``: one block, the
        gather is a length-1 stack, and the arithmetic is bit-identical to
        the 1-D path (asserted in tests/test_cand_sharding.py).
        """
        cp = self.cand_parts
        split = n_cand + n_rep
        split_post = split + n_post_rep

        def _tup(x):
            return x if isinstance(x, tuple) else (x,)

        cand_axes = self.cand_axes

        # Canonical shard-level function (see ``spmd``): the mesh branch
        # lowers exactly this; the simulated branch's nested vmaps compute
        # the same arithmetic with the cand gather as a free array axis.
        # ``cand_axes`` resolves to the simulated axis name on simulated
        # plans, so the auditor traces the identical collective schedule
        # either way.
        def fused(rows_local, *ops):
            out = _tup(body(rows_local, *ops[:split]))
            if post is not None:
                out = _tup(
                    post(self.cand_index(), *out, *ops[split:split_post])
                )
            if cp > 1:
                gathered = tuple(
                    lax.all_gather(o, cand_axes) for o in out
                )
            else:
                gathered = tuple(o[None] for o in out)
            if merge is None:
                return gathered
            return merge(*gathered, *ops[split_post:])

        spec = {
            "kind": "spmd_cand",
            "plan": self,
            "shard_fn": fused,
            "n_cand": n_cand,
            "n_rep": n_rep,
            "n_post_rep": n_post_rep,
            "n_merge_rep": n_merge_rep,
            "has_post": post is not None,
            "has_merge": merge is not None,
        }

        if self.mesh is not None:

            def run(rows, *ops):
                cand_specs = tuple(
                    P(self.cand_axis_names or None, *([None] * (op.ndim - 1)))
                    if cp > 1
                    else P()
                    for op in ops[:n_cand]
                )
                in_specs = (
                    (P(self.axis_names, None),)
                    + cand_specs
                    + (P(),) * (len(ops) - n_cand)
                )
                return compat.shard_map(
                    fused,
                    mesh=self.mesh,
                    in_specs=in_specs,
                    out_specs=P(),
                    check_vma=False,
                )(rows, *ops)

            return _attach_audit(run, spec)

        # Simulated plan: nested named-axis vmaps — inner over the object
        # partition (collectives in ``body`` reduce over it), outer over
        # the candidate blocks.  The cand "all-gather" is free: after the
        # outer vmap the block axis IS a real array axis.
        inner = jax.vmap(
            body,
            in_axes=(0,) + (None,) * split,
            out_axes=0,
            axis_name=SIM_AXIS,
        )
        outer = jax.vmap(
            inner,
            in_axes=(None,) + (0,) * n_cand + (None,) * n_rep,
            out_axes=0,
            axis_name=SIM_CAND_AXIS,
        )

        def run(rows, *ops):
            blocks = tuple(
                op.reshape(cp, op.shape[0] // cp, *op.shape[1:])
                for op in ops[:n_cand]
            )
            outs = _tup(outer(rows, *blocks, *ops[n_cand:split]))
            # [cand, obj, ...] — object-shard-invariant, keep obj lane 0
            outs = tuple(o[:, 0] for o in outs)
            if post is not None:
                post_rep = ops[split:split_post]
                outs = _tup(
                    jax.vmap(lambda idx, *o: _tup(post(idx, *o, *post_rep)))(
                        jnp.arange(cp, dtype=jnp.int32), *outs
                    )
                )
            if merge is None:
                return outs
            return merge(*outs, *ops[split_post:])

        return _attach_audit(run, spec)

    # -- accounting --------------------------------------------------------

    def resolve_impl(
        self, batch: int, W: int, n_attrs: int | None = None
    ) -> str:
        """The schedule one reduce round of ``batch`` candidates runs.

        A fixed ``reduce_impl`` is returned as-is; ``"auto"`` picks the
        α-β-cheapest of :data:`AUTO_IMPLS` for this round's measured batch
        (``collectives.modeled_cost_bytes``: allgather's single ring pass
        wins latency-bound small batches, rsag's 2(k-1)/k volume wins
        bandwidth-bound large ones).  Deterministic in the padded batch
        size, so the per-bucket jit caches see a stable choice.
        """
        if self.reduce_impl != "auto":
            return self.reduce_impl
        return min(
            AUTO_IMPLS,
            key=lambda impl: collectives.modeled_cost_bytes(
                impl, self.n_parts, batch, W, n_attrs,
                hop_bytes=self.auto_hop_bytes,
            ),
        )

    def modeled_reduce_bytes(
        self, batch: int, W: int, n_attrs: int | None = None
    ) -> int:
        """Analytic wire bytes one reduce round of ``batch`` candidates
        costs under this plan's schedule (see collectives.modeled_comm_bytes)."""
        return collectives.modeled_comm_bytes(
            self.resolve_impl(batch, W, n_attrs), self.n_parts, batch, W, n_attrs
        )

    def modeled_round_bytes_cand(
        self, block_batch: int, W: int, n_attrs: int | None = None
    ) -> int:
        """Analytic wire bytes for one 2-D round of ``cand_parts`` blocks
        of ``block_batch`` candidates each.

        Two terms: the AND-allreduce runs in ``cand_parts`` independent
        object-axis rings, each at the *block* batch size (this is the 2-D
        win — the reduce a device participates in is sized by its block,
        not the full chunk), plus the survivor all-gather along the
        candidate axis (``n_parts`` rings of ``cand_parts`` devices, one
        allgather pass over the block-sized survivor buffer each).
        """
        obj = self.cand_parts * collectives.modeled_comm_bytes(
            self.resolve_impl(block_batch, W, n_attrs),
            self.n_parts,
            block_batch,
            W,
            n_attrs,
        )
        gather = (
            self.n_parts
            * self.cand_parts
            * (self.cand_parts - 1)
            * block_batch
            * W
            * 4
        )
        return obj + gather

    def modeled_latency_split(
        self, batch: int, W: int, n_attrs: int | None = None
    ) -> tuple[int, int]:
        """``(dispatch_bytes, collective_bytes)`` — the α-β split of one
        reduce round's modeled cost for a 1-D plan.

        The *dispatch* term is the per-hop latency charged in bandwidth-
        equivalent bytes (``n_parts × ring_steps × auto_hop_bytes`` — what
        speculative async rounds overlap with the next dispatch), the
        *collective* term the actual wire volume (what the schedule moves
        regardless of overlap).  Their sum is exactly
        ``collectives.modeled_cost_bytes`` for the resolved schedule; the
        collective term alone is what ``modeled_reduce_bytes`` reports.
        """
        impl = self.resolve_impl(batch, W, n_attrs)
        vol = collectives.modeled_comm_bytes(
            impl, self.n_parts, batch, W, n_attrs
        )
        hops = (
            self.n_parts
            * collectives.ring_steps(impl, self.n_parts)
            * self.auto_hop_bytes
        )
        return hops, vol

    def modeled_latency_split_cand(
        self, block_batch: int, W: int, n_attrs: int | None = None
    ) -> tuple[int, int]:
        """``(dispatch_bytes, collective_bytes)`` for one 2-D round.

        Volume terms mirror :meth:`modeled_round_bytes_cand` (per-block
        object reduces + the cand-axis survivor gather); the hop term adds
        the two ring schedules' latency steps — ``cand_parts`` independent
        object rings at the resolved impl plus ``n_parts`` cand-axis
        allgather rings — priced at ``auto_hop_bytes`` each.
        """
        impl = self.resolve_impl(block_batch, W, n_attrs)
        obj_vol = self.cand_parts * collectives.modeled_comm_bytes(
            impl, self.n_parts, block_batch, W, n_attrs
        )
        gather_vol = (
            self.n_parts
            * self.cand_parts
            * (self.cand_parts - 1)
            * block_batch
            * W
            * 4
        )
        obj_hops = (
            self.cand_parts
            * self.n_parts
            * collectives.ring_steps(impl, self.n_parts)
            * self.auto_hop_bytes
        )
        gather_hops = (
            self.n_parts
            * self.cand_parts
            * collectives.ring_steps("allgather", self.cand_parts)
            * self.auto_hop_bytes
        )
        return obj_hops + gather_hops, obj_vol + gather_vol

    def describe(self) -> dict:
        """JSON-friendly summary for launcher output and benchmark records."""
        return {
            "mode": "simulated" if self.mesh is None else "mesh",
            "n_parts": self.n_parts,
            "axes": list(self.axis_names),
            "cand_parts": self.cand_parts,
            "cand_axes": list(self.cand_axis_names),
            "mesh_shape": None if self.mesh is None else dict(self.mesh.shape),
            "reduce_impl": self.reduce_impl,
            "block_n": self.block_n,
            "max_batch": self.max_batch,
            "auto_hop_bytes": self.auto_hop_bytes,
            "hop_calibrated": self.hop_calibrated,
        }

    def trace_tags(self) -> dict:
        """The geometry tags every round span carries (repro.obs): the
        subset of :meth:`describe` that identifies the plan in a timeline
        without bloating per-event args."""
        return {
            "plan": "simulated" if self.mesh is None else "mesh",
            "n_parts": self.n_parts,
            "cand_parts": self.cand_parts,
            "reduce_impl": self.reduce_impl,
        }


# ---------------------------------------------------------------------------
# interconnect probe (auto_hop_bytes calibration)
# ---------------------------------------------------------------------------

# One-shot per *plan geometry*: plans over the same devices with the same
# axis structure (object shard count + mesh axis shape + candidate blocks)
# share a measurement (the probe is geometry-, not schedule-, shaped).
# Keying on the full geometry — not just the interconnect — matters: an
# 8-shard ring pays different per-step latency than a 2-shard one, a
# pod×data mesh hops differently than a flat data mesh over the same
# devices, and a 2-D plan's object rings span a subset of the mesh; a
# calibrated value must never leak between them.  Values are
# (hop_bytes, measured) — measured=False marks a noise-floor fallback to
# the default.
_HOP_PROBE_CACHE: dict[tuple, tuple[int, bool]] = {}


def _probe_cache_key(plan: ShardPlan) -> tuple:
    """Cache key covering the plan geometry the probe actually measures."""
    if plan.mesh is None:
        mesh_axes = None
        devices = None
    else:
        mesh_axes = tuple((a, plan.mesh.shape[a]) for a in plan.mesh.shape)
        devices = tuple(str(d) for d in plan.mesh.devices.flat)
    return (
        plan.n_parts,
        plan.axis_names,
        plan.cand_parts,
        plan.cand_axis_names,
        mesh_axes,
        devices,
    )

_PROBE_W = 4  # packed words per probe row — scale-free, cancels in the ratio


def probe_hop_bytes(plan: ShardPlan) -> tuple[int, bool]:
    """Measure the plan's per-ring-step latency as equivalent wire bytes.

    Times the plan's own allgather AND-reduce (the exact collective the
    "auto" schedule arbitrates) at a tiny and a large batch:
    ``t(B) ≈ α + β·B`` separates the per-round fixed cost α (ring-step
    latency, dispatch) from the per-row cost β.  The model charges
    ``k·steps·hop_bytes`` latency bytes against ``k·(k-1)·B·W·4`` volume
    bytes for allgather, so the bandwidth-equivalent hop cost is
    ``hop_bytes = (α/β) · W · 4`` — independent of the probe's row width.
    Best-of-3 timings; returns ``(hop_bytes, measured)`` and caches it per
    plan geometry (:func:`_probe_cache_key` — device set × axis structure
    × shard counts on both axes).  ``measured=False`` means the probe saw no
    per-byte slope (noise floor) and fell back to the 4096 B default.
    """
    key = _probe_cache_key(plan)
    cached = _HOP_PROBE_CACHE.get(key)
    if cached is not None:
        return cached

    axes = plan.reduce_axes

    def body(rows_local, cands):
        lc = rows_local[:1] & cands  # touch the sharded operand
        return collectives.and_allreduce(
            lc, axes, impl="allgather", n_attrs=_PROBE_W * 32
        )

    fn = jax.jit(plan.spmd(body, n_rep=1))
    rows = plan.place_rows(np.ones((plan.n_parts, _PROBE_W), np.uint32))

    def timed(batch: int) -> float:
        cands = jnp.ones((batch, _PROBE_W), jnp.uint32)
        fn(rows, cands).block_until_ready()  # warm (compile excluded)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fn(rows, cands).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    b_small, b_large = 8, 4096
    t_small, t_large = timed(b_small), timed(b_large)
    slope = t_large - t_small
    if slope <= 0:
        # Noise floor: the large batch measured no slower than the tiny
        # one, so the per-byte term is unobservable here — keep the
        # documented default rather than caching a nonsense ratio, and
        # report the measurement as failed.
        result = (4096, False)
    else:
        beta = slope / (b_large - b_small)
        alpha = max(t_small - beta * b_small, 0.0)
        # bound at 16 MiB: beyond that the "latency term" would just
        # mean the probe was swamped by noise
        hop = min(1 << 24, max(1, int(round(alpha / beta * _PROBE_W * 4))))
        result = (hop, True)
    _HOP_PROBE_CACHE[key] = result
    return result
