"""Mamba-2 SSD (state-space duality) blocks — chunked train/prefill path and
O(1)-state decode path.

The chunked algorithm (arXiv:2405.21060 §6) splits the sequence into chunks
of Q tokens: within a chunk the output is an attention-like quadratic term
(`Y_diag`), across chunks a linear recurrence over per-chunk states carries
the long-range contribution (`Y_off`).  Decode keeps the recurrent view:
``h ← exp(dt·A)·h + dt·(B ⊗ x)``; ``y = C·h + D·x`` — O(state) per token,
which is what makes mamba2 eligible for the long_500k shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


class SSMCache(NamedTuple):
    conv: jax.Array  # [B, conv_w-1, conv_dim] — ring of past conv inputs
    h: jax.Array  # [B, H, P, N] — SSD state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_size
    return s, d_in, H, conv_dim


def init_ssd(pb: layers.ParamBuilder, cfg: ModelConfig):
    s, d_in, H, conv_dim = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.n_groups * s.state_size + H  # z, xBC, dt
    return {
        "in_proj": pb.dense((d, proj_out), ("embed", "inner")),
        "conv_w": pb.dense((s.conv_width, conv_dim), ("conv", "inner"), fan_in=s.conv_width),
        "conv_b": pb.zeros((conv_dim,), ("inner",)),
        "A_log": pb.value(jnp.log(jnp.linspace(1.0, 16.0, H)), ("heads",)),
        "D": pb.value(jnp.ones((H,)), ("heads",)),
        "dt_bias": pb.value(jnp.log(jnp.expm1(jnp.full((H,), 0.01))), ("heads",)),
        "norm": pb.zeros((d_in,), ("inner",), dtype=jnp.float32),
        "out_proj": pb.dense((d_in, d), ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x [B, L, C], w [K, C]."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu(out + b)


def _segsum(a: jax.Array) -> jax.Array:
    """a [..., Q] → [..., Q, Q]: s[i,j] = Σ_{j<k<=i} a_k (−inf for i<j)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, s, -jnp.inf)


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    s, d_in, H, _ = _dims(cfg)
    gn = s.n_groups * s.state_size
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC: jax.Array):
    s, d_in, H, _ = _dims(cfg)
    gn = s.n_groups * s.state_size
    x, Bm, Cm = jnp.split(xBC, [d_in, d_in + gn], axis=-1)
    lead = x.shape[:-1]
    x = x.reshape(*lead, H, s.head_dim)
    Bm = Bm.reshape(*lead, s.n_groups, s.state_size)
    Cm = Cm.reshape(*lead, s.n_groups, s.state_size)
    return x, Bm, Cm


def ssd_chunked(
    x: jax.Array,  # [B, L, H, P]
    dt: jax.Array,  # [B, L, H] (post-softplus)
    A: jax.Array,  # [H] (negative)
    Bm: jax.Array,  # [B, L, G, N]
    Cm: jax.Array,  # [B, L, G, N]
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, L, H, P], final state [B, H, P, N])."""
    B_, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    r = H // G
    if L % chunk:
        raise ValueError(f"L={L} must be divisible by chunk={chunk}")
    nc = L // chunk

    f32 = jnp.float32
    u = (x * dt[..., None]).astype(f32)  # discretized input
    dA = (dt * A).astype(f32)  # [B, L, H]

    # chunked views
    uc = u.reshape(B_, nc, chunk, H, P)
    dAc = dA.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, G, N).astype(f32)
    Cc = Cm.reshape(B_, nc, chunk, G, N).astype(f32)
    # expand groups → heads
    Bh = jnp.repeat(Bc, r, axis=3)  # [B, nc, Q, H, N]
    Ch = jnp.repeat(Cc, r, axis=3)

    # 1. intra-chunk (attention-like with decay kernel)
    Lk = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))  # [B, nc, H, Q, Q]
    scores = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)  # [B, nc, H, Q, Q]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores * Lk, uc)

    # 2. per-chunk states: S_c = Σ_j exp(Σ_{k>j} dA) B_j ⊗ u_j
    cums = jnp.cumsum(dAc, axis=2)  # [B, nc, Q, H]
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)  # [B, nc, Q, H]
    S = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", decay_to_end, Bh, uc)

    # 3. inter-chunk recurrence over states
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # [B, nc, H]

    def scan_fn(h, inp):
        S_c, g_c = inp
        h_new = h * g_c[..., None, None] + S_c
        return h_new, h  # emit state *before* this chunk

    h_init = (
        jnp.zeros((B_, H, P, N), f32) if h0 is None else h0.astype(f32)
    )
    h_last, h_prev = jax.lax.scan(
        scan_fn,
        h_init,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B, nc, H, P, N]

    # 4. chunk-start state contribution
    state_decay = jnp.exp(cums)  # [B, nc, Q, H]
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, h_prev, state_decay)

    y = (y_diag + y_off).reshape(B_, L, H, P)
    return y, h_last


def ssd_block_full(params, xin: jax.Array, cfg: ModelConfig):
    """Train/prefill forward.  xin [B, L, d] → (y [B, L, d], final SSMCache)."""
    s, d_in, H, conv_dim = _dims(cfg)
    proj = xin @ params["in_proj"]
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    x, Bm, Cm = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, min(cfg.ssm.chunk_size, xin.shape[1]))
    y = y + params["D"].astype(jnp.float32)[:, None] * x.astype(jnp.float32)
    y = y.reshape(*xin.shape[:2], d_in)
    y = layers.rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(xin.dtype), params["norm"]
    )
    out = y @ params["out_proj"]
    conv_state = xBC_raw[:, -(s.conv_width - 1):, :]
    pad = s.conv_width - 1 - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    return out, SSMCache(conv=conv_state.astype(xin.dtype), h=h.astype(jnp.float32))


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    s, d_in, H, conv_dim = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        h=jnp.zeros((batch, H, s.head_dim, s.state_size), jnp.float32),
    )


def ssd_block_decode(params, xin: jax.Array, cfg: ModelConfig, cache: SSMCache):
    """One-token decode.  xin [B, 1, d] → (y [B, 1, d], new cache)."""
    s, d_in, H, conv_dim = _dims(cfg)
    proj = xin @ params["in_proj"]
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)

    # conv over ring state ++ current input
    window = jnp.concatenate([cache.conv, xBC_raw], axis=1)  # [B, K, C]
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]  # [B, 1, C]
    new_conv = window[:, 1:, :]

    x, Bm, Cm = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,1,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    r = H // s.n_groups

    x1 = x[:, 0].astype(jnp.float32)  # [B, H, P]
    B1 = jnp.repeat(Bm[:, 0].astype(jnp.float32), r, axis=1)  # [B, H, N]
    C1 = jnp.repeat(Cm[:, 0].astype(jnp.float32), r, axis=1)
    dt1 = dt[:, 0]  # [B, H]

    g = jnp.exp(dt1 * A)  # [B, H]
    h = cache.h * g[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt1, B1, x1
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, C1) + params["D"].astype(jnp.float32)[:, None] * x1
    y = y.reshape(xin.shape[0], 1, d_in)
    y = layers.rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(xin.dtype), params["norm"]
    )
    out = y @ params["out_proj"]
    return out, SSMCache(conv=new_conv, h=h)
