"""Open-loop load generator — sustained-QPS serving measurement.

The paper evaluates by single-batch wall clock; a serving tier lives or
dies by its behavior under *sustained* load.  This module drives the
admission queue the way traffic actually arrives:

* **Arrival processes** — :func:`poisson_arrivals` (exponential gaps at
  a target QPS) and :func:`burst_arrivals` (a square-wave–modulated
  Poisson process: ``factor``× the base rate during the duty window,
  renormalized so the mean offered rate stays the target — the
  worst-case pattern for a deadline-or-full batcher).
* **Workload mix** — :func:`make_workload` draws a per-arrival kind from
  a weighted mix of ``closure`` / ``topk`` / ``lookup`` / ``rules``
  queries (payloads sampled to hit populated lattice regions) and
  ``update`` events (streamed object batches through
  ``StreamUpdater.stage``+``commit`` — snapshot swaps land *between*
  micro-batches while queries keep serving).
* **Open loop** — :func:`run_load` submits each request at its scheduled
  time regardless of how the server is doing.  When the host falls
  behind, arrivals are submitted late with their arrival time backdated
  to the schedule, so queueing delay is charged to the measured latency
  (no coordinated omission) and the bounded queue sheds exactly as it
  would under real overload.

The measurement is wall-clock by default but fully clock-injectable:
tests drive a virtual clock through the same code path the benchmark
times for real.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import bitset
from repro.obs import trace as obs
from repro.serve.admission import AdmissionQueue

QUERY_KINDS = ("closure", "topk", "lookup", "rules")
DEFAULT_MIX = {"closure": 0.6, "topk": 0.3, "lookup": 0.1}


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(qps: float, duration_s: float, rng) -> np.ndarray:
    """Sorted arrival offsets (seconds) of a Poisson process at ``qps``
    over ``duration_s`` — exponential inter-arrival gaps."""
    if qps <= 0 or duration_s <= 0:
        return np.zeros((0,), np.float64)
    n_est = int(qps * duration_s * 1.5) + 16
    gaps = rng.exponential(1.0 / qps, size=n_est)
    times = np.cumsum(gaps)
    while times.size and times[-1] < duration_s:  # tail top-up (rare)
        more = np.cumsum(rng.exponential(1.0 / qps, size=n_est)) + times[-1]
        times = np.concatenate([times, more])
    return times[times < duration_s]


def burst_arrivals(
    qps: float,
    duration_s: float,
    rng,
    *,
    period_s: float = 1.0,
    duty: float = 0.25,
    factor: float = 4.0,
) -> np.ndarray:
    """Bursty arrivals: a Poisson process whose rate alternates between
    ``hi`` (for ``duty`` of each period) and ``lo``, with the mean held
    at ``qps`` (``duty·hi + (1-duty)·lo = qps``, ``hi = factor·lo``).
    ``factor ≥ 1``; ``factor=1`` degenerates to plain Poisson."""
    if factor < 1.0:
        raise ValueError("burst factor must be ≥ 1")
    lo = qps / (duty * factor + (1.0 - duty))
    hi = factor * lo
    # thinning: draw at the peak rate, keep with p = rate(t)/hi
    cand = poisson_arrivals(hi, duration_s, rng)
    phase = (cand / period_s) % 1.0
    rate = np.where(phase < duty, hi, lo)
    keep = rng.random(cand.size) < rate / hi
    return cand[keep]


ARRIVALS = {"poisson": poisson_arrivals, "burst": burst_arrivals}


# ---------------------------------------------------------------------------
# workload mix
# ---------------------------------------------------------------------------


def make_workload(
    ctx,
    n: int,
    rng,
    *,
    mix: dict[str, float] | None = None,
    update_rows: int = 2,
    density: float | None = None,
) -> list[tuple[str, np.ndarray]]:
    """``n`` ``(kind, payload)`` events drawn from the weighted ``mix``.

    Query payloads are thinned real context rows (~25% of bits kept — the
    same populated-region sampling every serving bench uses); ``update``
    payloads are ``update_rows`` synthetic objects at the context's
    density.  ``lookup`` uses raw thinned rows, so cache misses (a
    legitimate part of real traffic) are measured alongside hits.
    """
    mix = dict(mix or DEFAULT_MIX)
    bad = set(mix) - set(QUERY_KINDS) - {"update"}
    if bad:
        raise ValueError(f"unknown workload kinds {sorted(bad)}")
    kinds = sorted(mix)
    weights = np.array([mix[k] for k in kinds], np.float64)
    if weights.sum() <= 0:
        raise ValueError("workload mix weights must sum > 0")
    weights /= weights.sum()
    draws = rng.choice(len(kinds), size=n, p=weights)
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=n)]
    keep = bitset.pack_bool(rng.random((n, ctx.n_attrs)) < 0.25, ctx.W)
    queries = base & keep
    dens = 0.3 if density is None else max(0.05, density)
    events = []
    for i, d in enumerate(draws):
        kind = kinds[d]
        if kind == "update":
            rows = bitset.pack_bool(
                rng.random((update_rows, ctx.n_attrs)) < dens, ctx.W
            )
            events.append((kind, rows))
        else:
            events.append((kind, queries[i]))
    return events


# ---------------------------------------------------------------------------
# the open-loop driver
# ---------------------------------------------------------------------------


@dataclass
class LoadReport:
    """One sustained-load measurement, JSON-ready via ``describe()``."""

    offered_qps: float
    duration_s: float
    wall_s: float
    submitted: int = 0
    admitted: int = 0
    shed: int = 0
    completed: int = 0
    achieved_qps: float = 0.0
    e2e: dict = field(default_factory=dict)
    admission_wait: dict = field(default_factory=dict)
    occupancy_mean: float = 0.0
    dispatches: int = 0
    dispatch_causes: dict = field(default_factory=dict)
    by_kind: dict = field(default_factory=dict)
    updates: int = 0
    update_latency: dict = field(default_factory=dict)
    max_lag_s: float = 0.0  # worst (now - scheduled arrival) at submit
    slo: dict = field(default_factory=dict)

    @property
    def shed_rate(self) -> float:
        return self.shed / self.submitted if self.submitted else 0.0

    def describe(self) -> dict:
        d = dataclasses.asdict(self)
        d["shed_rate"] = round(self.shed_rate, 6)
        return d


def run_load(
    queue: AdmissionQueue,
    arrivals: np.ndarray,
    events: list[tuple[str, np.ndarray]],
    *,
    updater=None,
    clock=time.monotonic,
    sleep=time.sleep,
    slo=None,
) -> LoadReport:
    """Drive ``events`` through ``queue`` at their scheduled ``arrivals``.

    Single-threaded and open-loop: each pass submits every arrival whose
    time has come (backdating ``arrival_s`` to the schedule), polls the
    queue for due deadlines, then sleeps to the next event edge.  While
    a dispatch blocks on the engine, time keeps passing — the next pass
    submits the backlog late, exactly like a saturated server.  Ends
    with a :meth:`~AdmissionQueue.flush`.

    ``update`` events call ``updater.stage``+``commit`` inline (snapshot
    swap between micro-batches); with no ``updater`` they are skipped
    and not counted as offered queries.  With ``slo`` (an
    :class:`repro.obs.slo.SLO`), the report gains an SLO evaluation.
    """
    if len(arrivals) != len(events):
        raise ValueError("one arrival time per event")
    duration = float(arrivals[-1]) if len(arrivals) else 0.0
    rep = LoadReport(
        offered_qps=len(arrivals) / duration if duration else 0.0,
        duration_s=duration,
        wall_s=0.0,
    )
    st = queue.stats
    base = (st.submitted, st.admitted, st.shed, st.completed, st.dispatches)
    t0 = clock()
    i = 0
    with obs.current().span(
        "serve/load", offered=len(arrivals), duration_s=round(duration, 3)
    ):
        while i < len(arrivals):
            now = clock() - t0
            while i < len(arrivals) and arrivals[i] <= now:
                kind, payload = events[i]
                sched = float(arrivals[i])
                rep.max_lag_s = max(rep.max_lag_s, now - sched)
                if kind == "update":
                    if updater is not None:
                        tu = clock()
                        receipt = updater.stage(payload)
                        updater.commit()
                        queue.registry.observe(
                            "serve_update_commit_s", clock() - tu
                        )
                        queue.registry.gauge(
                            "serve_snapshot_version", receipt.version
                        )
                        rep.updates += 1
                else:
                    queue.submit(kind, payload, arrival_s=t0 + sched)
                i += 1
            queue.poll()
            if i < len(arrivals):
                now = clock() - t0
                wait = min(
                    arrivals[i] - now,
                    queue.next_deadline_in(clock()),
                )
                if wait > 0:
                    # floor the sleep: next_deadline_in computes
                    # (t + max_wait) - now while poll tests
                    # now - t >= max_wait, and the two round differently,
                    # so wait can be a positive ~1e-17 whose sleep never
                    # advances an injected virtual clock (livelock) and
                    # busy-spins a real one
                    sleep(min(max(wait, 1e-5), 0.002))
        queue.poll()
        queue.flush()
    rep.wall_s = clock() - t0

    # -- roll the queue's ledgers into the report --------------------------
    rep.submitted = st.submitted - base[0]
    rep.admitted = st.admitted - base[1]
    rep.shed = st.shed - base[2]
    rep.completed = st.completed - base[3]
    rep.dispatches = st.dispatches - base[4]
    rep.dispatch_causes = dict(st.dispatch_causes)
    rep.occupancy_mean = round(st.occupancy_mean, 4)
    rep.by_kind = dict(st.by_kind)
    rep.achieved_qps = (
        round(rep.completed / rep.wall_s, 1) if rep.wall_s > 0 else 0.0
    )
    rep.e2e = _hist_view(st, "e2e")
    rep.admission_wait = _hist_view(st, "admission_wait")
    if rep.updates:
        uh = queue.registry.histogram("serve_update_commit_s")
        rep.update_latency = {
            "count": uh.count,
            **{k: round(v, 6) for k, v in uh.percentiles().items()},
        }
    if slo is not None:
        from repro.obs import slo as slo_mod

        e2e_h = st.registry.histogram("latency_s", kind="e2e")
        rep.slo = slo_mod.evaluate(
            slo,
            compliance=e2e_h.fraction_below(slo.latency_objective_s),
            shed_rate=rep.shed_rate,
            p99_s=rep.e2e.get("p99"),
        )
    return rep


def _hist_view(st, kind: str) -> dict:
    h = st.registry.histogram("latency_s", kind=kind)
    if h.count == 0:
        return {}
    return {
        "count": h.count,
        "mean": round(h.sum / h.count, 6),
        "max": round(h.max, 6),
        **{k: round(v, 6) for k, v in h.percentiles().items()},
    }
