"""Table 9 — MapReduce iteration counts per algorithm.

The paper's headline structural result: MRGanter needs one round per
concept; CloseByOne/MRCbo need one round per lattice level; MRGanter+
needs the fewest.  Unlike Table 8 this is hardware-independent, so the
scaled datasets reproduce the *shape* of the paper's numbers exactly.
"""

from __future__ import annotations

from benchmarks.common import load_scaled, make_engine, row
from repro.core import all_closures_batched, close_by_one, mrcbo, mrganter_plus


def run(n_parts: int = 4, datasets=("mushroom", "anon-web", "census-income")) -> list[str]:
    out = []
    for name in datasets:
        ctx, _ = load_scaled(name)
        n_concepts = len(all_closures_batched(ctx))

        cbo = close_by_one(ctx)
        r1 = mrcbo(ctx, make_engine(ctx, n_parts))
        r2 = mrganter_plus(ctx, make_engine(ctx, n_parts), dedupe_candidates=True)

        out.append(row(
            f"table9/{name}", 0.0,
            f"concepts={n_concepts}|nextclosure={n_concepts}|mrganter={n_concepts}"
            f"|closebyone={cbo.n_iterations}|mrcbo={r1.n_iterations}"
            f"|mrganter+={r2.n_iterations}",
        ))
    return out
