"""ShardPlan — the one partitioned execution path: geometry, placement,
legacy-kwarg routing, schedule equivalence, and the local-pruning wire
model.  The real multi-device mesh path is exercised in
tests/test_distributed_8dev.py; here every mesh is the single CPU device,
which must be bit-identical to the simulated plan by construction."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import ClosureEngine, all_closures_batched, bitset, mrcbo, mrganter_plus
from repro.core.context import FormalContext
from repro.dist.collectives import IMPLS
from repro.dist.shardplan import SIM_AXIS, ShardPlan


def _keys(intents):
    return {bitset.key_bytes(y) for y in intents}


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(90, 21, 0.25, seed=4)


@pytest.fixture(scope="module")
def ref(ctx):
    return _keys(all_closures_batched(ctx))


def _one_device_mesh():
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))


# -- geometry / placement ----------------------------------------------------


def test_simulated_geometry():
    plan = ShardPlan.simulated(4, reduce_impl="allgather", block_n=64)
    assert plan.is_simulated
    assert plan.n_parts == 4
    assert plan.reduce_axes == SIM_AXIS
    assert plan.row_alignment == 4 * 64
    rows = np.arange(4 * 64 * 2 * 3, dtype=np.uint32).reshape(-1, 3)
    placed = plan.place_rows(rows)
    assert placed.shape == (4, 2 * 64, 3)
    np.testing.assert_array_equal(
        np.asarray(placed).reshape(-1, 3), rows
    )


def test_mesh_geometry_picks_object_axes():
    plan = ShardPlan.over_mesh(_one_device_mesh())
    assert not plan.is_simulated
    assert plan.axis_names == ("data",)
    assert plan.n_parts == 1
    assert plan.describe()["mode"] == "mesh"


def test_plan_validation():
    with pytest.raises(ValueError, match="reduce schedule"):
        ShardPlan.simulated(2, reduce_impl="morse-code")
    with pytest.raises(ValueError, match="n_parts"):
        ShardPlan.simulated(0)
    plan = ShardPlan.simulated(3)
    with pytest.raises(ValueError, match="divisible"):
        plan.place_rows(np.zeros((7, 2), np.uint32))


def test_auto_plan_single_device():
    # one CPU device in the main pytest process → simulated fallback
    plan = ShardPlan.auto(n_parts=5)
    assert plan.is_simulated and plan.n_parts == 5


# -- engine routes every spelling to one plan --------------------------------


def test_legacy_kwargs_build_plans(ctx):
    e_parts = ClosureEngine(ctx, n_parts=3, reduce_impl="pmin", block_n=64)
    assert isinstance(e_parts.plan, ShardPlan)
    assert e_parts.plan.is_simulated
    assert e_parts.plan.n_parts == 3 == e_parts.n_parts
    assert e_parts.plan.reduce_impl == "pmin"
    assert e_parts.plan.block_n == 64  # engine kwarg overrides plan default

    e_mesh = ClosureEngine(ctx, mesh=_one_device_mesh(), block_n=64)
    assert not e_mesh.plan.is_simulated
    assert e_mesh.plan.axis_names == ("data",)


def test_plan_conflicts_with_legacy_geometry(ctx):
    with pytest.raises(ValueError, match="not both"):
        ClosureEngine(ctx, plan=ShardPlan.simulated(2), n_parts=3)
    with pytest.raises(ValueError, match="not both"):
        ClosureEngine(ctx, plan=ShardPlan.simulated(2), mesh=_one_device_mesh())
    # scalar knobs override the plan uniformly (same as block_n/max_batch)
    eng = ClosureEngine(ctx, plan=ShardPlan.simulated(2), reduce_impl="allgather")
    assert eng.reduce_impl == "allgather" == eng.plan.reduce_impl


def test_engine_accepts_plan_directly(ctx, ref):
    plan = ShardPlan.simulated(2, reduce_impl="rsag", block_n=64, max_batch=512)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    assert eng.max_batch == 512
    res = mrganter_plus(ctx, eng, local_prune=True)
    assert _keys(res.intents) == ref


# -- equivalence across geometry and schedule --------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_one_device_mesh_bitidentical_to_simulated(ctx, impl):
    """The same shard body runs under shard_map (mesh) and named-axis vmap
    (simulated); on one device with k=1 both must produce identical bits."""
    e_sim = ClosureEngine(
        ctx, plan=ShardPlan.simulated(1, reduce_impl=impl, block_n=64),
        backend="jnp",
    )
    e_mesh = ClosureEngine(
        ctx, plan=ShardPlan.over_mesh(_one_device_mesh(), reduce_impl=impl,
                                      block_n=64),
        backend="jnp",
    )
    cands = FormalContext.synthetic(17, ctx.n_attrs, 0.3, seed=8).rows
    c_sim, s_sim = e_sim.closure(cands)
    c_mesh, s_mesh = e_mesh.closure(cands)
    np.testing.assert_array_equal(c_sim, c_mesh)
    np.testing.assert_array_equal(s_sim, s_mesh)


@pytest.mark.parametrize("impl", IMPLS)
def test_schedules_agree_through_plan(ctx, ref, impl):
    plan = ShardPlan.simulated(4, reduce_impl=impl, block_n=64)
    res = mrcbo(ctx, ClosureEngine(ctx, plan=plan, backend="jnp"))
    assert _keys(res.intents) == ref


# -- local pruning: the reduce is sized by the pruned bucket -----------------


def test_local_pruning_reduces_wire_bytes(ctx, ref):
    plan = ShardPlan.simulated(8, reduce_impl="rsag", block_n=64)
    e_off = ClosureEngine(ctx, plan=plan, backend="jnp")
    e_on = ClosureEngine(ctx, plan=plan, backend="jnp")
    r_off = mrganter_plus(ctx, e_off, local_prune=False)
    r_on = mrganter_plus(ctx, e_on, local_prune=True)
    assert _keys(r_off.intents) == _keys(r_on.intents) == ref
    # pruned candidates never enter the AND-allreduce
    assert e_on.stats.modeled_comm_bytes < e_off.stats.modeled_comm_bytes
    assert e_on.stats.closures_computed < e_off.stats.closures_computed


def test_modeled_reduce_bytes_matches_collectives_model():
    plan = ShardPlan.simulated(4, reduce_impl="rsag")
    from repro.dist import collectives

    assert plan.modeled_reduce_bytes(128, 3) == collectives.modeled_comm_bytes(
        "rsag", 4, 128, 3
    )
    assert dataclasses.replace(plan, n_parts=1).modeled_reduce_bytes(128, 3) == 0
    # pmin charges one uint32 per unpacked lane, bounded by n_attrs like the impl
    pmin = ShardPlan.simulated(4, reduce_impl="pmin")
    assert pmin.modeled_reduce_bytes(128, 3, n_attrs=70) == 4 * 3 * 128 * 70 * 4
    assert pmin.modeled_reduce_bytes(128, 3) == 4 * 3 * 128 * (3 * 32) * 4


# -- schedule autotuning (reduce_impl="auto") --------------------------------


def test_auto_resolves_by_batch_size():
    plan = ShardPlan.simulated(8, reduce_impl="auto")
    W, m = 5, 133
    # latency-bound small batch → allgather's single ring pass
    assert plan.resolve_impl(8, W, m) == "allgather"
    # bandwidth-bound large batch → rsag's 2(k-1)/k volume
    assert plan.resolve_impl(8192, W, m) == "rsag"
    # monotone: once rsag wins it keeps winning as batches grow
    impls = [plan.resolve_impl(b, W, m) for b in (8, 64, 512, 4096, 32768)]
    assert impls == sorted(impls, key=("allgather", "rsag").index)
    # a fixed schedule resolves to itself regardless of batch
    fixed = ShardPlan.simulated(8, reduce_impl="pmin")
    assert fixed.resolve_impl(8, W, m) == "pmin"


def test_auto_modeled_bytes_follow_the_choice():
    from repro.dist import collectives

    plan = ShardPlan.simulated(8, reduce_impl="auto")
    for batch in (8, 256, 8192):
        impl = plan.resolve_impl(batch, 5, 133)
        assert plan.modeled_reduce_bytes(batch, 5, 133) == (
            collectives.modeled_comm_bytes(impl, 8, batch, 5, 133)
        )


def test_auto_cost_model_components():
    from repro.dist import collectives

    # one ring pass vs two: rsag pays twice the hops of allgather
    assert collectives.ring_steps("rsag", 8) == 2 * collectives.ring_steps(
        "allgather", 8
    )
    assert collectives.ring_steps("allgather", 1) == 0
    # with the latency term zeroed, auto degenerates to pure volume (rsag
    # for every k > 2)
    plan = dataclasses.replace(
        ShardPlan.simulated(8, reduce_impl="auto"), auto_hop_bytes=0
    )
    assert plan.resolve_impl(1, 5, 133) == "rsag"


def test_auto_engine_mines_identically_and_records_choices(ctx, ref):
    plan = ShardPlan.simulated(4, reduce_impl="auto", block_n=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    assert _keys(res.intents) == ref
    # every dispatched round recorded a concrete schedule
    assert sum(eng.stats.reduce_rounds.values()) == eng.stats.closure_calls
    assert set(eng.stats.reduce_rounds) <= {"allgather", "rsag"}


def test_auto_rejects_unknown_schedule():
    with pytest.raises(ValueError):
        ShardPlan.simulated(4, reduce_impl="autotune")


# -- mixed out-specs (sharded + replicated outputs from one region) ----------


def test_spmd_mixed_out_specs_simulated():
    import jax.numpy as jnp
    from jax import lax

    plan = ShardPlan.simulated(4, block_n=2)
    rows = np.arange(4 * 2 * 2 * 3, dtype=np.uint32).reshape(-1, 3)
    placed = plan.place_rows(rows)

    def body(rows_local, delta):
        total = lax.psum(
            rows_local.sum(dtype=jnp.int32), plan.reduce_axes
        )
        start = plan.shard_index() * rows_local.shape[0]
        gidx = start + jnp.arange(rows_local.shape[0], dtype=jnp.int32)
        return rows_local + delta, total, gidx

    fn = jax.jit(plan.spmd(body, n_rep=1, out_shard=(True, False, True)))
    shifted, total, gidx = fn(placed, jnp.uint32(1))
    # sharded outputs keep the plan's placement layout (== place_rows)
    assert shifted.shape == placed.shape
    np.testing.assert_array_equal(
        np.asarray(shifted).reshape(-1, 3), rows + 1
    )
    # shard_index orders shards exactly as place_rows splits the rows
    np.testing.assert_array_equal(
        np.asarray(gidx).reshape(-1), np.arange(rows.shape[0])
    )
    assert int(total) == rows.sum()


def test_spmd_mixed_out_specs_mesh_matches_simulated():
    import jax.numpy as jnp
    from jax import lax

    rows = np.arange(6 * 3, dtype=np.uint32).reshape(-1, 3)
    outs = []
    for plan in (
        ShardPlan.simulated(1, block_n=2),
        ShardPlan.over_mesh(_one_device_mesh(), block_n=2),
    ):
        placed = plan.place_rows(rows)

        def body(rows_local, delta):
            total = lax.psum(
                rows_local.sum(dtype=jnp.int32), plan.reduce_axes
            )
            return rows_local + delta, total

        fn = jax.jit(plan.spmd(body, n_rep=1, out_shard=(True, False)))
        shifted, total = fn(placed, jnp.uint32(3))
        outs.append((np.asarray(shifted).reshape(-1, 3), int(total)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]


def test_spmd_out_shard_rejects_post():
    plan = ShardPlan.simulated(2)
    with pytest.raises(ValueError, match="mutually exclusive"):
        plan.spmd(lambda r: r, n_rep=0, post=lambda x: x, out_shard=(True,))


# -- hop-bytes calibration ---------------------------------------------------


def test_hop_probe_measures_and_caches():
    from repro.dist import shardplan as sp

    sp._HOP_PROBE_CACHE.clear()
    plan = ShardPlan.simulated(4, calibrate_hops=True)
    # a real measurement yields a positive hop cost; a noise-floor probe
    # keeps the default and must NOT claim calibration
    if plan.hop_calibrated:
        assert 1 <= plan.auto_hop_bytes <= 1 << 24
    else:
        assert plan.auto_hop_bytes == 4096
    assert plan.describe()["hop_calibrated"] == plan.hop_calibrated
    assert plan.describe()["auto_hop_bytes"] == plan.auto_hop_bytes
    # second calibration hits the cache: same value, no re-measurement
    key = next(iter(sp._HOP_PROBE_CACHE))
    sp._HOP_PROBE_CACHE[key] = (12345, True)
    cached = ShardPlan.simulated(4, calibrate_hops=True)
    assert cached.auto_hop_bytes == 12345 and cached.hop_calibrated
    sp._HOP_PROBE_CACHE.clear()
    # uncalibrated plans keep the documented default
    assert ShardPlan.simulated(4).auto_hop_bytes == 4096
    assert not ShardPlan.simulated(4).hop_calibrated


def test_calibrated_hop_bytes_flow_into_stats_and_auto(ctx):
    import dataclasses as dc

    from repro.query import ConceptStore, QueryEngine

    plan = dc.replace(
        ShardPlan.simulated(4, reduce_impl="auto"),
        auto_hop_bytes=1 << 20, hop_calibrated=True,
    )
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    assert eng.stats.auto_hop_bytes == 1 << 20
    assert eng.stats.hop_calibrated
    # a huge measured hop cost makes the single-pass schedule win even at
    # large batches — the calibration actually steers the autotuner
    assert plan.resolve_impl(8192, 5, 133) == "allgather"
    store = ConceptStore.build(ctx, all_closures_batched(ctx), plan=plan)
    qe = QueryEngine(store)
    assert qe.stats.auto_hop_bytes == 1 << 20
    assert qe.stats.hop_calibrated
