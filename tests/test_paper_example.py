"""The paper's worked example (Tables 1–3, 5–6) as executable assertions."""

import numpy as np
import pytest

from repro.core import (
    ClosureEngine,
    all_closures,
    close_by_one,
    mrcbo,
    mrganter,
    mrganter_plus,
    paper_context,
)
from repro.core import bitset, closure
from repro.core.context import FormalContext

NAMES = "abcdefg"


def _as_set(row):
    return {NAMES[a] for a in range(7) if bitset.unpack_bits(row, 7)[a]}


# Table 2 — all 21 formal concepts (intents).
TABLE2_INTENTS = [
    set(), {"f"}, {"e"}, {"d"}, {"d", "f"}, {"d", "e"}, {"c", "g"},
    {"b"}, {"b", "f"}, {"b", "d"}, {"b", "d", "f"}, {"b", "d", "e"},
    {"b", "c", "f", "g"}, {"b", "c", "d", "f", "g"}, {"a"}, {"a", "e"},
    {"a", "d", "f"}, {"a", "d", "e", "f"}, {"a", "c", "e", "g"},
    {"a", "b", "d", "f"}, {"a", "b", "c", "d", "e", "f", "g"},
]


def test_table1_context():
    ctx = paper_context()
    assert ctx.n_objects == 6 and ctx.n_attrs == 7
    # object 2 has attributes {a, c, e, g} (paper §2)
    assert _as_set(ctx.rows[1]) == {"a", "c", "e", "g"}


def test_table2_all_21_concepts():
    ctx = paper_context()
    intents = all_closures(ctx)
    assert len(intents) == 21
    got = [_as_set(y) for y in intents]
    assert {frozenset(s) for s in got} == {frozenset(s) for s in TABLE2_INTENTS}


def test_example1_oplus():
    """Y={a,d,f}: Y⊕e = {a,d,e,f}; Y⊕c = {a,c,e}; lectic check keeps {a,c,e}."""
    ctx = paper_context()
    mask = ctx.attr_mask()
    Y = bitset.from_indices([0, 3, 5], 7)  # {a,d,f}
    # ⊕ e (index 4): (Y ∩ {a,b,c,d}) ∪ {e} = {a,d,e} → closure {a,d,e,f}
    seed = (Y & bitset.low_mask(4, 1)) | bitset.bit(4, 1)
    c, _ = closure.closure_np(ctx.rows, seed, mask)
    assert _as_set(c) == {"a", "d", "e", "f"}
    # ⊕ c (index 2): seed {a,c} → extent {2} → closure {a,c,e,g}.
    # (The paper's Example 1 prints "{a,c,e}" — a typo: its own Table 2
    # lists F_19 = ⟨{2}, {a,c,e,g}⟩, consistent with Table 1.)
    seed = (Y & bitset.low_mask(2, 1)) | bitset.bit(2, 1)
    c2, _ = closure.closure_np(ctx.rows, seed, mask)
    assert _as_set(c2) == {"a", "c", "e", "g"}


def test_example2_partition_property2():
    """Y={b,d}: Y''_{S1}={b,d,f}, Y''_{S2}={b,d,e}, intersection {b,d}."""
    ctx = paper_context()
    s1, s2 = ctx.partition(2)
    Y = bitset.from_indices([1, 3], 7)
    c1, _ = closure.closure_np(s1.rows, Y, ctx.attr_mask())
    c2, _ = closure.closure_np(s2.rows, Y, ctx.attr_mask())
    cs, _ = closure.closure_np(ctx.rows, Y, ctx.attr_mask())
    assert _as_set(c1) == {"b", "d", "f"}
    assert _as_set(c2) == {"b", "d", "e"}
    assert _as_set(cs) == {"b", "d"}
    assert np.array_equal(c1 & c2, cs)  # Theorem 1


@pytest.mark.parametrize("algo,kw", [
    (mrganter, {}),
    (mrganter_plus, {}),
    (mrganter_plus, {"dedupe_candidates": True}),
    (mrcbo, {}),
])
@pytest.mark.parametrize("n_parts", [1, 2, 3])
def test_mr_algorithms_match_table2(algo, kw, n_parts):
    ctx = paper_context()
    eng = ClosureEngine(ctx, n_parts=n_parts, block_n=64)
    res = algo(ctx, eng, **kw)
    got = {frozenset(_as_set(y)) for y in res.intents}
    assert got == {frozenset(s) for s in TABLE2_INTENTS}


def test_mrganter_one_concept_per_iteration():
    """Paper §3.1: MRGanter needs one MapReduce round per concept."""
    ctx = paper_context()
    res = mrganter(ctx, ClosureEngine(ctx, n_parts=2, block_n=64))
    assert res.n_iterations == 21  # == number of concepts (Table 9 convention)


def test_mrganter_plus_few_iterations():
    """Paper §3.2: MRGanter+ collapses iterations to ~lattice depth."""
    ctx = paper_context()
    res = mrganter_plus(ctx, ClosureEngine(ctx, n_parts=2, block_n=64))
    assert res.n_iterations <= 6  # ≪ 21; paper's worked example needs 3
