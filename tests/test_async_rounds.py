"""Speculative double-buffered round scheduler (``rounds="async"``).

The async scheduler dispatches round r+1's expansion against the
*unreconciled* round-r survivor buffer while round r's AND-allreduce and
psum are in flight, then reconciles on adoption: over-expanded rows are
masked, under-coverage falls back to a synchronous re-dispatch of the
uncovered seed tail.  The sync path stays the bit-exact oracle, so every
test here is an identity check against it — concept sets AND iteration
counts — plus the reconciliation edge cases: exact ``round_budget``
boundaries, an empty true frontier discovered after the speculative
dispatch, and the ``_adopt`` refuse-to-drop guard under async state.
The real-mesh twin lives in tests/test_distributed_8dev.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClosureEngine,
    all_closures_batched,
    bitset,
    lectic,
    mrcbo,
    mrganter,
    mrganter_plus,
)
from repro.core.context import FormalContext
from repro.core.frontier import DeviceFrontier, bucket_size
from repro.dist.shardplan import ShardPlan

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

settings.register_profile("async", deadline=None, max_examples=16)
settings.load_profile("async")

DRIVERS = {
    "mrganter+": (mrganter_plus, {"local_prune": True}),
    "mrcbo": (mrcbo, {}),
    "mrganter": (mrganter, {}),
}


def _keys(intents):
    return {bitset.key_bytes(y) for y in intents}


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(90, 21, 0.25, seed=7)


@pytest.fixture(scope="module")
def ref(ctx):
    return _keys(all_closures_batched(ctx))


@pytest.fixture(scope="module")
def small_ctx():
    # small enough for MRGanter's one-concept-per-round chain to finish
    return FormalContext.synthetic(60, 12, 0.3, seed=3)


def _plan(geom, **kw):
    n_obj, n_cand = geom
    return ShardPlan.simulated(n_obj, cand_parts=n_cand, block_n=64, **kw)


def _pair(ctx, name, plan_kw_pairs, **kw):
    """Run (sync, async) on fresh engines of identical geometry."""
    algo, akw = DRIVERS[name]
    out = []
    for mode, plan in zip(("sync", "async"), plan_kw_pairs):
        eng = ClosureEngine(ctx, plan=plan, backend="jnp")
        out.append((eng, algo(ctx, eng, rounds=mode, **akw, **kw)))
    return out


# -- identity: every driver × plan geometry × iceberg threshold --------------


@pytest.mark.parametrize("geom", [(1, 1), (3, 1), (2, 2)])
@pytest.mark.parametrize("name", list(DRIVERS))
@pytest.mark.parametrize("min_support", [None, 4])
def test_async_matches_sync(ctx, name, geom, min_support):
    # cap MRGanter's one-concept-per-round chain (repo convention)
    kw = {"max_iterations": 40} if name == "mrganter" else {}
    (es, rs), (ea, ra) = _pair(
        ctx, name, (_plan(geom), _plan(geom)), min_support=min_support, **kw
    )
    assert _keys(ra.intents) == _keys(rs.intents)
    assert ra.n_iterations == rs.n_iterations
    assert ea.stats.spec_rounds > 0
    if not kw:
        # an uncapped run's terminal speculative round is always discarded
        # (capped runs stop speculating one round before the cap instead)
        assert ea.stats.spec_discarded >= 1
    assert es.stats.spec_rounds == 0


def test_async_mrganter_exact_lectic_order(small_ctx):
    """MRGanter's async chain must emit the FULL lattice in the identical
    lectic order, not just the identical set — the chain IS the order."""
    (_, rs), (_, ra) = _pair(
        small_ctx, "mrganter", (_plan((2, 1)), _plan((2, 1)))
    )
    assert rs.n_concepts == ra.n_concepts
    np.testing.assert_array_equal(
        np.stack(rs.intents), np.stack(ra.intents)
    )
    assert _keys(ra.intents) == _keys(all_closures_batched(small_ctx))


def test_async_full_set_vs_batched_oracle(ctx, ref):
    for name in ("mrganter+", "mrcbo"):
        algo, akw = DRIVERS[name]
        eng = ClosureEngine(ctx, plan=_plan((2, 1)), backend="jnp")
        res = algo(ctx, eng, rounds="async", **akw)
        assert _keys(res.intents) == ref, name


# -- round_budget boundaries -------------------------------------------------


def _first_round_seeds(ctx, plan) -> int:
    """True (post-dedupe) seed count of the root frontier's expansion."""
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    fr = DeviceFrontier(eng, dedupe_closures=True)
    fr.set_frontier(np.zeros((1, ctx.W), np.uint32))
    rec = fr.reconcile_oplus(fr.spec_oplus(dedupe=True), min_support=None)
    return rec.n_seeds


@pytest.mark.parametrize("cand_parts", [1, 2])
def test_spec_covered_at_exact_budget_boundary(ctx, cand_parts):
    """A speculative chunk whose padded cap lands exactly on the true seed
    count must adopt without a fallback — and its closures must equal the
    sync step's bit for bit."""
    n_seeds = _first_round_seeds(ctx, _plan((2, cand_parts), max_batch=4096))
    budget = bucket_size(n_seeds)  # cap == bucket(n_seeds) ≥ n_seeds
    plan = _plan((2, cand_parts), max_batch=-(-budget // cand_parts))
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    fr = DeviceFrontier(eng, dedupe_closures=True)
    fr.set_frontier(np.zeros((1, ctx.W), np.uint32))
    rec = fr.reconcile_oplus(fr.spec_oplus(dedupe=True), min_support=None)
    assert rec.n_seeds == n_seeds
    assert not rec.under_covered and eng.stats.spec_fallbacks == 0

    e2 = ClosureEngine(ctx, plan=plan, backend="jnp")
    f2 = DeviceFrontier(e2, dedupe_closures=True)
    f2.set_frontier(np.zeros((1, ctx.W), np.uint32))
    sync_cl = f2.step_oplus(dedupe=True)
    assert _keys(rec.closures) == _keys(sync_cl)


@pytest.mark.parametrize("cand_parts", [1, 2])
def test_spec_over_expansion_falls_back(ctx, cand_parts):
    """One seed past the budget: the speculative chunk under-covers, the
    reconcile re-dispatches the tail synchronously, and nothing is lost."""
    n_seeds = _first_round_seeds(ctx, _plan((2, cand_parts), max_batch=4096))
    p2 = 1 << ((n_seeds - 1).bit_length() - 1)  # largest power of two < n
    assert p2 < n_seeds
    plan = _plan((2, cand_parts), max_batch=max(1, p2 // cand_parts))
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    fr = DeviceFrontier(eng, dedupe_closures=True)
    fr.set_frontier(np.zeros((1, ctx.W), np.uint32))
    rec = fr.reconcile_oplus(fr.spec_oplus(dedupe=True), min_support=None)
    assert rec.under_covered and eng.stats.spec_fallbacks == 1
    assert rec.n_seeds == n_seeds

    e2 = ClosureEngine(ctx, plan=plan, backend="jnp")
    f2 = DeviceFrontier(e2, dedupe_closures=True)
    f2.set_frontier(np.zeros((1, ctx.W), np.uint32))
    assert _keys(rec.closures) == _keys(f2.step_oplus(dedupe=True))


def test_driver_identity_under_tiny_budget(ctx):
    """End-to-end: a round budget far below the peak frontier forces the
    fallback path repeatedly; the mined set must not change."""
    for geom in ((2, 1), (2, 2)):
        for name in ("mrganter+", "mrcbo"):
            (es, rs), (ea, ra) = _pair(
                ctx, name,
                (_plan(geom, max_batch=16), _plan(geom, max_batch=16)),
            )
            assert _keys(ra.intents) == _keys(rs.intents), (name, geom)
            assert ra.n_iterations == rs.n_iterations
            assert ea.stats.spec_fallbacks >= 1, (name, geom)


# -- empty true frontier after speculative dispatch --------------------------


def test_empty_frontier_after_spec_iceberg(ctx):
    """An iceberg threshold that prunes an entire round: the in-flight
    speculative round built on those survivors must be discarded, and the
    result must match sync."""
    s = int(0.6 * ctx.n_objects)  # prunes everything below the top layer
    for name in ("mrganter+", "mrcbo"):
        (es, rs), (ea, ra) = _pair(
            ctx, name, (_plan((2, 1)), _plan((2, 1))), min_support=s
        )
        assert _keys(ra.intents) == _keys(rs.intents), name
        assert ra.n_iterations == rs.n_iterations, name
        assert ea.stats.spec_discarded >= 1, name


def test_degenerate_all_ones_context():
    """|B(ctx)| = 1: the very first speculation is garbage and must be
    discarded without an extra counted iteration."""
    fc = FormalContext.synthetic(20, 6, 1.0, seed=0)
    for name in DRIVERS:
        algo, akw = DRIVERS[name]
        es = ClosureEngine(fc, plan=ShardPlan.simulated(2), backend="jnp")
        ea = ClosureEngine(fc, plan=ShardPlan.simulated(2), backend="jnp")
        rs = algo(fc, es, rounds="sync", **akw)
        ra = algo(fc, ea, rounds="async", **akw)
        assert _keys(ra.intents) == _keys(rs.intents), name
        assert ra.n_iterations == rs.n_iterations, name
        assert ra.n_concepts == 1


# -- adoption guards under async state ---------------------------------------


def test_len_raises_while_speculative(ctx):
    eng = ClosureEngine(ctx, plan=_plan((2, 1)), backend="jnp")
    fr = DeviceFrontier(eng)
    fr.set_frontier(
        np.zeros((1, ctx.W), np.uint32), gens=np.full(1, -1, np.int32)
    )
    fr.spec_cbo()
    with pytest.raises(RuntimeError, match="speculative"):
        len(fr)


def test_adopt_refuses_to_drop_rows_under_async(ctx):
    """The PR-5 truncation guard must keep firing when the frontier count
    lives on device: adopting more rows than the slot holds raises."""
    eng = ClosureEngine(ctx, plan=_plan((2, 1)), backend="jnp")
    fr = DeviceFrontier(eng)
    fr.set_frontier(
        np.zeros((1, ctx.W), np.uint32), gens=np.full(1, -1, np.int32)
    )
    spec = fr.spec_cbo()
    with pytest.raises(RuntimeError, match="cand-shards"):
        fr._adopt(jnp.zeros((4, ctx.W), jnp.uint32), None, 9)
    fr.discard_spec(spec)


def test_max_iterations_parity(ctx):
    for name in DRIVERS:
        for cap in (1, 2, 4):
            (_, rs), (_, ra) = _pair(
                ctx, name, (_plan((2, 1)), _plan((2, 1))),
                max_iterations=cap,
            )
            assert _keys(ra.intents) == _keys(rs.intents), (name, cap)
            assert ra.n_iterations == rs.n_iterations == cap, (name, cap)


# -- on-device lectic selection (Alg. 5 line 6) ------------------------------


@given(
    st.integers(1, 40), st.integers(0, 2**31 - 1), st.floats(0.0, 1.0)
)
def test_select_lectic_matches_host_oracle(n_attrs, seed, p_ok):
    """argmax + dynamic-slice gather ≡ the host's closures[idx.max()]."""
    rng = np.random.default_rng(seed)
    W = bitset.n_words(n_attrs)
    closures = rng.integers(0, 2**32, size=(n_attrs, W), dtype=np.uint32)
    ok = rng.random(n_attrs) < p_ok
    Y_dev, found = lectic.select_lectic_jnp(
        jnp.asarray(closures), jnp.asarray(ok)
    )
    if not ok.any():
        assert not bool(found)
    else:
        assert bool(found)
        want = closures[int(np.nonzero(ok)[0].max())]
        np.testing.assert_array_equal(np.asarray(Y_dev), want)
