"""Basis extraction: Duquenne–Guigues implications + Luxenburger rules.

Both bases are computed *from the mined concept family* (a full lattice or
an iceberg) rather than from raw transactions — the FCA route to
association rules: the family of (frequent) closed intents is closed under
intersection, so

    φ(X) = ⋂ { Y ∈ family : X ⊆ Y }          (⋂ ∅ = M, the full attr set)

is a closure operator whose closed sets are exactly the family (+ M).  For
the full lattice φ coincides with the context's ``''`` closure; for an
iceberg it is the iceberg closure system of Stumme's frequent-closed-set
framework.

  * **Duquenne–Guigues base** — the minimal implication cover
    ``{P → φ(P)\\P : P pseudo-closed}``, enumerated with Ganter's
    attribute-exploration loop: NextClosure over the *implication closure*
    (L-saturation) visits every φ-closed and pseudo-closed set in lectic
    order; each visited set that φ grows is a pseudo-intent.  The two
    inner kernels — L-saturation of all m candidate seeds and the φ pass —
    are batched device ops over the store's intent table (popcount-free
    subset tests + monoid ``lax.reduce`` folds); the host loop is just the
    sequential NextClosure control flow.  ``dg_basis_host`` is the pure
    numpy brute-force oracle (same definition, independent code path).

  * **Luxenburger base** — the minimal cover of the partial (conf < 1)
    association rules: one rule per *covering* pair Y₁ ≺ Y₂ of the family
    (premise Y₁, added attrs Y₂\\Y₁, confidence supp(Y₂)/supp(Y₁)).  The
    covering relation is read from the store snapshot's device-matmul
    order tables; confidences/lifts are vectorized over all edges at once.
    ``luxenburger_host`` recomputes the covering with O(C²) subset loops —
    the brute-force oracle.

Both paths emit rules in the same canonical order (lexsort over packed
premise then added words), so oracle comparisons are bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitset, lectic
from repro.kernels.ops import bucket_size


# ---------------------------------------------------------------------------
# device kernels (batched passes over the intent table)
# ---------------------------------------------------------------------------


def _or_fold(x: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR monoid fold (lax.reduce — XLA input-fuses the select)."""
    return lax.reduce(x, jnp.uint32(0), lambda a, b: a | b, (axis,))


def _and_fold(x: jax.Array, axis: int) -> jax.Array:
    return lax.reduce(
        x, jnp.uint32(0xFFFFFFFF), lambda a, b: a & b, (axis,)
    )


@jax.jit
def family_closure_jnp(
    X: jax.Array, intents: jax.Array, n_concepts, mask: jax.Array
) -> jax.Array:
    """φ(X) for a batch [B, W]: AND-fold of the family intents ⊇ X.

    ``intents`` is a padded [Cb, W] table (pads masked by ``n_concepts``);
    a batch row covered by no intent closes to ``mask`` (= M).
    """
    covers = jnp.all((X[:, None, :] & ~intents[None, :, :]) == 0, axis=-1)
    covers = covers & (jnp.arange(intents.shape[0]) < n_concepts)[None, :]
    phi = _and_fold(
        jnp.where(covers[:, :, None], intents[None], jnp.uint32(0xFFFFFFFF)),
        axis=1,
    )
    return phi & mask


@jax.jit
def family_support_jnp(
    X: jax.Array, intents: jax.Array, supports: jax.Array, n_concepts
) -> jax.Array:
    """Support of each batch row *as a family member* (0 when absent —
    callers pass φ-closed rows, so absent ⟺ infrequent/M)."""
    eq = jnp.all(X[:, None, :] == intents[None, :, :], axis=-1)
    eq = eq & (jnp.arange(intents.shape[0]) < n_concepts)[None, :]
    return jnp.max(
        jnp.where(eq, supports[None, :].astype(jnp.int32), 0), axis=1
    )


@jax.jit
def lclosure_jnp(
    X: jax.Array, premises: jax.Array, added: jax.Array, n_rules
) -> jax.Array:
    """Implication saturation of a batch [B, W] to the L-closure fixpoint.

    One pass ORs every applicable conclusion in; the while_loop runs to
    stability (≤ |L| passes, in practice a handful).
    """
    rvalid = jnp.arange(premises.shape[0]) < n_rules

    def one_pass(x):
        app = jnp.all(
            (premises[None, :, :] & ~x[:, None, :]) == 0, axis=-1
        ) & rvalid[None, :]
        grow = _or_fold(
            jnp.where(app[:, :, None], added[None], jnp.uint32(0)), axis=1
        )
        return x | grow

    def cond(carry):
        prev, cur = carry
        return jnp.any(prev != cur)

    def body(carry):
        _, cur = carry
        return cur, one_pass(cur)

    _, out = lax.while_loop(cond, body, (X, one_pass(X)))
    return out


@functools.partial(jax.jit, static_argnames=("n_attrs",))
def _dg_next_jnp(
    A: jax.Array,
    premises: jax.Array,
    added: jax.Array,
    n_rules,
    LOW: jax.Array,
    BIT: jax.Array,
    *,
    n_attrs: int,
) -> jax.Array:
    """NextClosure step for the L-closure operator: the lectic-next
    L-closed set after ``A``.  All m candidate seeds saturate in one
    batched pass; the largest feasible generator wins (Alg.-5 shape —
    the same scan the miners fuse after their reduce)."""
    seeds = (A[None, :] & LOW) | BIT  # [m, W]
    closed = lclosure_jnp(seeds, premises, added, n_rules)
    member = lectic.member_bits_jnp(A[None, :], n_attrs)[0]
    gens = jnp.arange(n_attrs, dtype=jnp.int32)
    ok = lectic.feasible_jnp(closed, A[None, :], gens, LOW) & ~member
    score = jnp.where(ok, gens, -1)
    return closed[jnp.argmax(score)]


# ---------------------------------------------------------------------------
# rule containers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RuleSet:
    """A batch of rules premise → premise ∪ added, canonical order."""

    premise: np.ndarray  # [R, W] uint32
    added: np.ndarray  # [R, W] uint32 (disjoint from premise)
    support: np.ndarray  # [R] int32 — objects matching premise ∪ added
    confidence: np.ndarray  # [R] float32
    lift: np.ndarray  # [R] float32 (0 when the consequent leaves the family)

    def __len__(self) -> int:
        return self.premise.shape[0]

    @staticmethod
    def empty(W: int) -> "RuleSet":
        z = np.zeros((0, W), np.uint32)
        return RuleSet(
            premise=z,
            added=z.copy(),
            support=np.zeros((0,), np.int32),
            confidence=np.zeros((0,), np.float32),
            lift=np.zeros((0,), np.float32),
        )

    @staticmethod
    def concat(a: "RuleSet", b: "RuleSet") -> "RuleSet":
        return RuleSet(
            premise=np.concatenate([a.premise, b.premise]),
            added=np.concatenate([a.added, b.added]),
            support=np.concatenate([a.support, b.support]),
            confidence=np.concatenate([a.confidence, b.confidence]),
            lift=np.concatenate([a.lift, b.lift]),
        )


@dataclasses.dataclass(frozen=True)
class RuleBasis:
    """The two-part basis of the mined family: exact rules (DG) + partial
    rules (Luxenburger), per the classic decomposition."""

    n_objects: int
    n_attrs: int
    min_conf: float
    implications: RuleSet  # confidence ≡ 1
    partial: RuleSet  # confidence < 1

    @property
    def n_implications(self) -> int:
        return len(self.implications)

    @property
    def n_partial(self) -> int:
        return len(self.partial)

    def combined(self) -> RuleSet:
        return RuleSet.concat(self.implications, self.partial)

    def describe(self) -> dict:
        return {
            "implications": self.n_implications,
            "partial_rules": self.n_partial,
            "min_conf": self.min_conf,
            "n_objects": self.n_objects,
            "n_attrs": self.n_attrs,
        }


def _canonical_rule_order(premise: np.ndarray, added: np.ndarray) -> np.ndarray:
    keys = tuple(added[:, w] for w in reversed(range(added.shape[1])))
    keys += tuple(premise[:, w] for w in reversed(range(premise.shape[1])))
    return np.lexsort(keys)


def _padded_family(
    intents_np: np.ndarray, W: int
) -> tuple[jax.Array, int]:
    C = intents_np.shape[0]
    cap = bucket_size(max(1, C), minimum=8)
    buf = np.full((cap, W), 0xFFFFFFFF, np.uint32)
    buf[:C] = intents_np
    return jnp.asarray(buf), C


def _consequent_lift(
    added: np.ndarray,
    confidence: np.ndarray,
    intents_dev: jax.Array,
    supports_dev: jax.Array,
    n_concepts: int,
    n_objects: int,
    mask: jax.Array,
) -> np.ndarray:
    """lift = conf · |O| / supp(φ(added)), batched; 0 when φ(added) has
    left the family (infrequent consequent in an iceberg store)."""
    if added.shape[0] == 0:
        return np.zeros((0,), np.float32)
    out = np.zeros((added.shape[0],), np.float32)
    step = 4096
    for lo in range(0, added.shape[0], step):
        chunk = jnp.asarray(added[lo : lo + step])
        phi = family_closure_jnp(chunk, intents_dev, n_concepts, mask)
        s = np.asarray(
            family_support_jnp(phi, intents_dev, supports_dev, n_concepts)
        ).astype(np.float32)
        conf = confidence[lo : lo + step]
        out[lo : lo + step] = np.where(
            s > 0, conf * n_objects / np.maximum(s, 1), 0.0
        )
    return out


# ---------------------------------------------------------------------------
# Duquenne–Guigues base
# ---------------------------------------------------------------------------


def dg_basis(
    intents_np: np.ndarray,
    supports_np: np.ndarray,
    n_attrs: int,
    *,
    n_objects: int | None = None,
) -> RuleSet:
    """DG implication base of the family, device-batched Ganter loop.

    Every iteration runs two device passes — L-saturation of the m
    candidate seeds (``_dg_next_jnp``) and the φ pass over the intent
    table — while the host only sequences NextClosure and collects
    pseudo-intents.  Premises come out in lectic order.
    """
    W = bitset.n_words(n_attrs)
    mask_np = bitset.attr_mask(n_attrs, W)
    mask = jnp.asarray(mask_np)
    t = lectic.LecticTables(n_attrs)
    LOW, BIT = jnp.asarray(t.LOW), jnp.asarray(t.BIT)
    intents_dev, C = _padded_family(intents_np, W)
    supports_dev = jnp.zeros((intents_dev.shape[0],), jnp.int32)
    if C:
        supports_dev = supports_dev.at[:C].set(
            jnp.asarray(supports_np.astype(np.int32))
        )

    premises: list[np.ndarray] = []
    conclusions: list[np.ndarray] = []  # full φ(P), for the saturation
    # device twin of the growing L, bucket-padded (rebuilt on growth —
    # one tiny upload per pseudo-intent)
    rcap = 8
    prem_dev = jnp.full((rcap, W), 0xFFFFFFFF, jnp.uint32)
    concl_dev = jnp.zeros((rcap, W), jnp.uint32)

    A = np.zeros((W,), np.uint32)
    while True:
        phi = np.asarray(
            family_closure_jnp(
                jnp.asarray(A[None, :]), intents_dev, C, mask
            )
        )[0]
        if not np.array_equal(phi, A):  # A is pseudo-closed
            premises.append(A.copy())
            conclusions.append(phi)
            if len(premises) > rcap:
                rcap = bucket_size(len(premises), minimum=8)
            buf_p = np.full((rcap, W), 0xFFFFFFFF, np.uint32)
            buf_c = np.zeros((rcap, W), np.uint32)
            buf_p[: len(premises)] = np.stack(premises)
            buf_c[: len(premises)] = np.stack(conclusions)
            prem_dev, concl_dev = jnp.asarray(buf_p), jnp.asarray(buf_c)
        if np.array_equal(A, mask_np):
            break
        A = np.asarray(
            _dg_next_jnp(
                jnp.asarray(A), prem_dev, concl_dev,
                jnp.int32(len(premises)), LOW, BIT, n_attrs=n_attrs,
            )
        )

    if not premises:
        return RuleSet.empty(W)
    prem = np.stack(premises)
    concl = np.stack(conclusions)
    added = concl & ~prem
    support = np.asarray(
        family_support_jnp(
            jnp.asarray(concl), intents_dev, supports_dev, C
        )
    ).astype(np.int32)
    confidence = np.ones((prem.shape[0],), np.float32)
    # |O| defaults to the top concept's support (extent of ∅'' is O)
    n_obj = (
        n_objects
        if n_objects is not None
        else (int(supports_np.max()) if C else 0)
    )
    lift = _consequent_lift(
        added, confidence, intents_dev, supports_dev, C, n_obj, mask
    )
    return RuleSet(
        premise=prem, added=added, support=support,
        confidence=confidence, lift=lift,
    )


def dg_basis_host(intents_np: np.ndarray, n_attrs: int) -> RuleSet:
    """Pure-numpy brute-force oracle for :func:`dg_basis` (supports and
    lifts zeroed — oracle comparisons cover premises/conclusions)."""
    W = bitset.n_words(n_attrs)
    mask = bitset.attr_mask(n_attrs, W)
    t = lectic.LecticTables(n_attrs)

    def phi(X):
        out = mask.copy()
        for Y in intents_np:
            if bool(bitset.is_subset(X, Y)):
                out &= Y
        return out

    def lclose(X, L):
        X = X.copy()
        changed = True
        while changed:
            changed = False
            for p, c in L:
                if bool(bitset.is_subset(p, X)) and not bool(
                    bitset.is_subset(c, X)
                ):
                    X |= c
                    changed = True
        return X

    L: list[tuple[np.ndarray, np.ndarray]] = []
    A = np.zeros((W,), np.uint32)
    while True:
        p = phi(A)
        if not np.array_equal(p, A):
            L.append((A.copy(), p))
        if np.array_equal(A, mask):
            break
        for i in reversed(range(n_attrs)):
            if bitset.unpack_bits(A, n_attrs)[i]:
                continue
            B = lclose((A & t.LOW[i]) | t.BIT[i], L)
            if bool(np.all(((B ^ A) & t.LOW[i]) == 0)):
                A = B
                break
        else:  # pragma: no cover — NextClosure always has a successor
            raise AssertionError("no lectic successor below M")

    if not L:
        return RuleSet.empty(W)
    prem = np.stack([p for p, _ in L])
    concl = np.stack([c for _, c in L])
    R = prem.shape[0]
    return RuleSet(
        premise=prem, added=concl & ~prem,
        support=np.zeros((R,), np.int32),
        confidence=np.ones((R,), np.float32),
        lift=np.zeros((R,), np.float32),
    )


# ---------------------------------------------------------------------------
# Luxenburger base
# ---------------------------------------------------------------------------


def _rules_from_cover(
    cover_target_child: np.ndarray,  # bool [C, C]: [c, d] ⇒ d ≺ c (d child)
    intents_np: np.ndarray,
    supports_np: np.ndarray,
    n_objects: int,
    min_conf: float,
    intents_dev: jax.Array,
    supports_dev: jax.Array,
    n_concepts: int,
    mask: jax.Array,
) -> RuleSet:
    tgt, src = np.nonzero(cover_target_child)  # rule: intent[src] → intent[tgt]
    keep = supports_np[src] > 0
    tgt, src = tgt[keep], src[keep]
    premise = intents_np[src]
    added = intents_np[tgt] & ~premise
    support = supports_np[tgt].astype(np.int32)
    confidence = (
        support.astype(np.float64) / supports_np[src].astype(np.float64)
    ).astype(np.float32)
    keep = confidence >= np.float32(min_conf)
    premise, added = premise[keep], added[keep]
    support, confidence = support[keep], confidence[keep]
    lift = _consequent_lift(
        added, confidence, intents_dev, supports_dev, n_concepts,
        n_objects, mask,
    )
    order = _canonical_rule_order(premise, added)
    return RuleSet(
        premise=premise[order], added=added[order],
        support=support[order], confidence=confidence[order],
        lift=lift[order],
    )


def _m_mask(W: int, n_attrs: int | None) -> np.ndarray:
    """The top element M for the φ no-cover fallback.  ``n_attrs=None``
    falls back to every bit of the W words — only reachable by callers
    that pass sets no family member covers, which the Luxenburger paths
    never do (every consequent is a subset of a real intent)."""
    if n_attrs is not None:
        return bitset.attr_mask(n_attrs, W)
    return np.full((W,), 0xFFFFFFFF, np.uint32)


def luxenburger_from_snapshot(
    snap, n_objects: int, *, min_conf: float = 0.0,
    n_attrs: int | None = None,
) -> RuleSet:
    """Luxenburger base read off a ConceptStore snapshot: premises/targets
    are the covering pairs the snapshot's device order-table matmuls
    already materialized (``children_rows``)."""
    C = snap.n_concepts
    W = snap.intents_np.shape[1]  # valid even for an empty family
    if C == 0:
        return RuleSet.empty(W)
    kids = np.asarray(snap.children_rows)[:C]
    cover = bitset.unpack_bits(kids, snap.cap)[:, :C]  # [c, d]: d ≺ c
    # family tables straight from the snapshot (already padded on device)
    return _rules_from_cover(
        cover, snap.intents_np, snap.supports_np.astype(np.int32),
        n_objects, min_conf, snap.intents, snap.supports, C,
        jnp.asarray(_m_mask(W, n_attrs)),
    )


def luxenburger_host(
    intents_np: np.ndarray,
    supports_np: np.ndarray,
    n_objects: int,
    *,
    min_conf: float = 0.0,
    n_attrs: int | None = None,
) -> RuleSet:
    """Brute-force oracle: O(C²) subset loops build the strict order, a
    triple loop reduces it to the covering, then the same rule math."""
    C, W = intents_np.shape
    if C == 0:
        return RuleSet.empty(W)
    strict = np.zeros((C, C), bool)
    for i in range(C):
        for j in range(C):
            if i != j and bool(bitset.is_subset(intents_np[i], intents_np[j])):
                strict[i, j] = True  # intent_i ⊂ intent_j
    cover = strict.copy()
    for i in range(C):
        for j in range(C):
            if cover[i, j]:
                for k in range(C):
                    if strict[i, k] and strict[k, j]:
                        cover[i, j] = False
                        break
    # cover[i, j]: j covers i (premise i → target j) → [target, child] layout
    intents_dev, C_ = _padded_family(intents_np, W)
    supports_dev = jnp.zeros((intents_dev.shape[0],), jnp.int32)
    supports_dev = supports_dev.at[:C].set(
        jnp.asarray(supports_np.astype(np.int32))
    )
    return _rules_from_cover(
        cover.T, intents_np, supports_np.astype(np.int32), n_objects,
        min_conf, intents_dev, supports_dev, C_,
        jnp.asarray(_m_mask(W, n_attrs)),
    )


# ---------------------------------------------------------------------------
# one-call extraction over a concept store
# ---------------------------------------------------------------------------


def extract_bases(store, *, min_conf: float = 0.0) -> RuleBasis:
    """DG + Luxenburger bases of the store's active snapshot (full or
    iceberg — φ is the snapshot family's closure system either way)."""
    snap = store.snapshot
    ctx = store.ctx
    implications = dg_basis(
        snap.intents_np, snap.supports_np.astype(np.int32), ctx.n_attrs,
        n_objects=ctx.n_objects,
    )
    partial = luxenburger_from_snapshot(
        snap, ctx.n_objects, min_conf=min_conf, n_attrs=ctx.n_attrs
    )
    return RuleBasis(
        n_objects=ctx.n_objects,
        n_attrs=ctx.n_attrs,
        min_conf=min_conf,
        implications=implications,
        partial=partial,
    )
