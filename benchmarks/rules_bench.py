"""Rules-subsystem benchmark: fused iceberg mining, basis extraction, and
batched rule serving (§Rules).

  * **iceberg A/B** — census-income at 8 simulated shards, MRGanter+ with
    local pruning: the full-lattice mine + post-hoc support filter vs the
    fused in-round ``min_support`` prune.  The concept sets are asserted
    identical *before* any timing is recorded (the acceptance gate); the
    record is the per-round reduce bytes, total rounds, and closures each
    path pays.  MRCbo rides along as a second driver datapoint.
  * **bases** — on every paper dataset (CPU-budget scales): DG implication
    base + Luxenburger partial base of the iceberg store, device passes vs
    the host brute-force oracles — asserted bit-for-bit equal, both sides
    timed.
  * **serving** — a mixed rule-query batch (premise→consequent closure +
    top-k by confidence) through ``QueryEngine.rules_batch`` fixed-slot
    micro-batches vs the per-query host loop, asserted equal, then timed
    (warm best-of-3, the query-bench protocol).

Writes BENCH_rules.json; the headline is the iceberg reduce-byte/round
ratio and the batched-vs-host rule-serving throughput ratio.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import row
from repro.core import ClosureEngine, bitset, mrcbo, mrganter_plus
from repro.core.engine import EngineStats
from repro.data import fca_datasets
from repro.dist.shardplan import ShardPlan
from repro.query import ConceptStore, QueryEngine
from repro.query.engine import QueryConfig, QueryStats
from repro.query.store import host_supports
from repro.rules import (
    RuleIndex,
    dg_basis,
    dg_basis_host,
    extract_bases,
    luxenburger_from_snapshot,
    luxenburger_host,
    resolve_min_support,
)
from repro.rules.index import rule_query_mix

# CPU-budget scales for the bases grid (the DG oracle is sequential python
# over m attrs × |L| rules, so the iceberg keeps it tractable); per-dataset
# min-conf floors sit below each iceberg's covering-edge confidences so the
# Luxenburger side is non-trivial (anon-web's sparse iceberg tops out ~0.08).
PAPER_SCALES = {
    "mushroom": (0.008, 0.3, 0.25),
    "anon-web": (0.008, 0.08, 0.05),
    "census-income": (0.001, 0.15, 0.15),
}


def _keys(intents):
    return {bitset.key_bytes(y) for y in np.asarray(intents, np.uint32)}


def _timed_mine(ctx, plan, driver, **kw) -> tuple[dict, list]:
    """dist_bench warm-run protocol: one pass compiles, the rerun is timed."""
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    driver(ctx, eng, **kw)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = driver(ctx, eng, **kw)
    wall = time.perf_counter() - t0
    st = eng.stats
    rounds = max(1, st.rounds)
    return {
        "driver": res.algorithm,
        "min_support": res.min_support,
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "closures_computed": st.closures_computed,
        "rounds": rounds,
        "reduce_bytes_total": st.modeled_comm_bytes,
        "reduce_bytes_per_round": st.modeled_comm_bytes // rounds,
    }, res.intents


def _host_rule_pass(index, queries, k, min_conf):
    ids = np.full((queries.shape[0], k), -1, np.int32)
    scores = np.full((queries.shape[0], k), -1.0, np.float32)
    unions = np.zeros((queries.shape[0], index.premise_np.shape[1]), np.uint32)
    floor = np.float32(min_conf)
    for b, q in enumerate(queries):
        app = [
            r
            for r in range(index.n_rules)
            if index.confidence_np[r] >= floor
            and bool(bitset.is_subset(index.premise_np[r], q))
        ]
        for r in app:
            unions[b] |= index.added_np[r]
        ranked = sorted(app, key=lambda r: (-index.confidence_np[r], r))[:k]
        for slot, r in enumerate(ranked):
            ids[b, slot] = r
            scores[b, slot] = index.confidence_np[r]
    return ids, scores, unions


def run(
    dataset: str = "census-income",
    scale: float = 0.002,
    parts: int = 8,
    min_support: float = 0.05,
    min_conf: float = 0.5,
    n_queries: int = 2048,
    k: int = 5,
    slots: int = 1024,
    out_path: str = "BENCH_rules.json",
) -> list[str]:
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)
    s = resolve_min_support(min_support, ctx.n_objects)
    plan = ShardPlan.simulated(parts, reduce_impl="rsag")

    # -- iceberg A/B: fused in-round prune vs full mine + post-hoc filter --
    full_rec, full_intents = _timed_mine(
        ctx, plan, mrganter_plus, local_prune=True
    )
    ice_rec, ice_intents = _timed_mine(
        ctx, plan, mrganter_plus, local_prune=True, min_support=s
    )
    cbo_rec, cbo_intents = _timed_mine(ctx, plan, mrcbo, min_support=s)
    # acceptance gate: identical concept sets BEFORE any timing is reported
    sups = host_supports(ctx, np.stack(full_intents))
    posthoc = _keys(np.stack(full_intents)[sups >= s])
    if _keys(ice_intents) != posthoc or _keys(cbo_intents) != posthoc:
        raise AssertionError("fused iceberg mining diverges from post-hoc filter")

    # -- bases on every paper dataset: device vs brute-force oracles -------
    bases = []
    for name, (b_scale, b_frac, b_conf) in PAPER_SCALES.items():
        b_ctx, b_spec = fca_datasets.load(name, scale=b_scale, seed=0)
        b_s = resolve_min_support(b_frac, b_ctx.n_objects)
        b_plan = ShardPlan.simulated(4)
        eng = ClosureEngine(b_ctx, plan=b_plan, backend="jnp")
        res = mrganter_plus(b_ctx, eng, local_prune=True, min_support=b_s)
        store = ConceptStore.build(b_ctx, res.intents, plan=b_plan)
        snap = store.snapshot

        t0 = time.perf_counter()
        dg_dev = dg_basis(
            snap.intents_np, snap.supports_np, b_ctx.n_attrs,
            n_objects=b_ctx.n_objects,
        )
        lux_dev = luxenburger_from_snapshot(
            snap, b_ctx.n_objects, min_conf=b_conf
        )
        dev_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        dg_host = dg_basis_host(snap.intents_np, b_ctx.n_attrs)
        lux_host = luxenburger_host(
            snap.intents_np, snap.supports_np, b_ctx.n_objects,
            min_conf=b_conf,
        )
        host_s = time.perf_counter() - t0
        # bit-for-bit acceptance on every paper dataset
        if not (
            np.array_equal(dg_dev.premise, dg_host.premise)
            and np.array_equal(dg_dev.added, dg_host.added)
            and np.array_equal(lux_dev.premise, lux_host.premise)
            and np.array_equal(lux_dev.added, lux_host.added)
            and np.array_equal(lux_dev.confidence, lux_host.confidence)
        ):
            raise AssertionError(f"{name}: device bases diverge from oracles")
        bases.append({
            "dataset": name,
            "scale": b_scale,
            "objects": b_ctx.n_objects,
            "attrs": b_ctx.n_attrs,
            "min_support": b_s,
            "min_conf": b_conf,
            "iceberg_concepts": res.n_concepts,
            "implications": len(dg_dev),
            "partial_rules": len(lux_dev),
            "device_s": round(dev_s, 4),
            "host_oracle_s": round(host_s, 4),
            "bit_identical": True,
        })

    # -- rule serving: batched vs per-query host loop ----------------------
    store = ConceptStore.build(ctx, ice_intents, plan=plan)
    basis = extract_bases(store, min_conf=min_conf)
    index = RuleIndex.build(basis, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=slots, backend="jnp"))
    rng = np.random.default_rng(1)
    queries = rule_query_mix(ctx, index, n_queries, rng)

    engine_out, engine_wall = None, float("inf")
    for i in range(4):  # pass 0 warms the jit caches
        qe.stats = QueryStats()
        t0 = time.perf_counter()
        out = qe.rules_batch(index, queries, k=k, min_conf=min_conf)
        if i:
            engine_wall = min(engine_wall, time.perf_counter() - t0)
        engine_out = out
    t0 = time.perf_counter()
    host_out = _host_rule_pass(index, queries, k, min_conf)
    host_wall = time.perf_counter() - t0
    for name_, a, b in zip(("ids", "scores", "consequents"), engine_out, host_out):
        if not np.array_equal(a, b):
            raise AssertionError(f"batched rule {name_} diverge from host loop")

    payload = {
        "dataset": dataclasses.asdict(spec),
        "plan": plan.describe(),
        "min_support_resolved": s,
        "min_conf": min_conf,
        "iceberg_ab": {
            "full": full_rec,
            "iceberg_mrganter+": ice_rec,
            "iceberg_mrcbo": cbo_rec,
            "identical_to_posthoc_filter": True,
        },
        "bases": bases,
        "serving": {
            "rules": index.n_rules,
            "exact": index.n_exact,
            "queries": n_queries,
            "k": k,
            "slots": slots,
            "batched_wall_s": round(engine_wall, 4),
            "batched_queries_per_s": round(n_queries / engine_wall, 1),
            "host_wall_s": round(host_wall, 4),
            "host_queries_per_s": round(n_queries / host_wall, 1),
            "bit_identical": True,
        },
        "headline": {
            "reduce_bytes_per_round_full": full_rec["reduce_bytes_per_round"],
            "reduce_bytes_per_round_iceberg": ice_rec["reduce_bytes_per_round"],
            "reduce_bytes_per_round_ratio": round(
                full_rec["reduce_bytes_per_round"]
                / max(1, ice_rec["reduce_bytes_per_round"]), 2,
            ),
            "rounds_full": full_rec["rounds"],
            "rounds_iceberg": ice_rec["rounds"],
            "serving_throughput_ratio": round(host_wall / engine_wall, 1),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = [
        row(
            "rules/iceberg/full_mine", 1e6 * full_rec["wall_time_s"],
            f"rounds={full_rec['rounds']}"
            f"|reduce_B_per_round={full_rec['reduce_bytes_per_round']}"
            f"|concepts={full_rec['n_concepts']}",
        ),
        row(
            "rules/iceberg/fused_minsup", 1e6 * ice_rec["wall_time_s"],
            f"rounds={ice_rec['rounds']}"
            f"|reduce_B_per_round={ice_rec['reduce_bytes_per_round']}"
            f"|concepts={ice_rec['n_concepts']}",
        ),
    ]
    for b in bases:
        out.append(row(
            f"rules/bases/{b['dataset']}", 1e6 * b["device_s"],
            f"DG={b['implications']}|lux={b['partial_rules']}"
            f"|host_oracle_s={b['host_oracle_s']}",
        ))
    out.append(row(
        "rules/serving/batched", 1e6 * engine_wall,
        f"qps={payload['serving']['batched_queries_per_s']}"
        f"|rules={index.n_rules}",
    ))
    out.append(row(
        "rules/serving/host_loop", 1e6 * host_wall,
        f"qps={payload['serving']['host_queries_per_s']}",
    ))
    out.append(row(
        "rules/headline", payload["headline"]["reduce_bytes_per_round_ratio"],
        f"reduce_B_per_round_full_vs_iceberg"
        f"|serving_ratio={payload['headline']['serving_throughput_ratio']}"
        f"|json={out_path}",
    ))
    return out
