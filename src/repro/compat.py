"""Version-bridging shims so the codebase runs on the pinned jax (0.4.x).

The source tree is written against the modern public API surface
(``jax.shard_map``, ``jax.sharding.AxisType``, Pallas ``CompilerParams``);
this module resolves each name against whatever the installed jax provides
so call sites stay version-agnostic.
"""

from __future__ import annotations

import jax

# -- shard_map ---------------------------------------------------------------
if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        # ``check_vma`` was called ``check_rep`` before jax 0.6.
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )


# -- make_mesh ---------------------------------------------------------------
def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` minus the ``axis_types`` kwarg (absent pre-0.5)."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


# -- compiled.cost_analysis() -----------------------------------------------
def cost_analysis(compiled) -> dict:
    """Normalize across jax versions: pre-0.5 returns a list of per-program
    dicts, newer returns one dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca or {}


# -- Pallas TPU compiler params ---------------------------------------------
def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(**kwargs)
