"""ShardPlan scaling sweep: 1→8 object shards × reduce schedule (§Dist).

Two grids over MRGanter+ on the device pipeline, both through
:class:`repro.dist.ShardPlan` (simulated geometry — the arithmetic and the
analytic wire model are shard-count-exact on one CPU; the same plans run
unchanged over a real mesh, equivalence-tested in
tests/test_distributed_8dev.py):

  * **scaling** — shard count k ∈ {1, 2, 4, 8} × schedule ∈
    {allgather, rsag, pmin}, local pruning on: wall time plus the
    per-round reduce wire bytes each schedule puts on the interconnect.
  * **pruning A/B** — at k = 8, every schedule with local pruning off vs
    on: the paper's MRGanter+ claim that per-partition pruning shrinks
    what the reduce moves.  The reduce is sized by the post-prune bucket,
    so pruned candidates never enter the collective.

Writes BENCH_dist.json; the headline is the pruning byte ratio under the
production rsag schedule.
"""

from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.common import row
from repro.core import ClosureEngine, mrganter_plus
from repro.core.engine import EngineStats
from repro.data import fca_datasets
from repro.dist.collectives import IMPLS
from repro.dist.shardplan import ShardPlan


def _timed_run(ctx, plan: ShardPlan, *, local_prune: bool) -> dict:
    """Warm-run protocol: one run populates the plan's jit caches, stats
    reset, then the steady-state run is timed."""
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    mrganter_plus(ctx, eng, local_prune=local_prune)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = mrganter_plus(ctx, eng, local_prune=local_prune)
    wall = time.perf_counter() - t0
    st = eng.stats
    rounds = max(1, st.rounds)
    return {
        "plan": plan.describe(),
        "local_prune": local_prune,
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "closures_computed": st.closures_computed,
        "rounds": rounds,
        "reduce_bytes_total": st.modeled_comm_bytes,
        "reduce_bytes_per_round": st.modeled_comm_bytes // rounds,
    }


def run(
    dataset: str = "census-income",
    scale: float = 0.001,
    shard_counts=(1, 2, 4, 8),
    prune_ab_parts: int = 8,
    out_path: str = "BENCH_dist.json",
) -> list[str]:
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)

    scaling = []
    for impl in IMPLS:
        for k in shard_counts:
            plan = ShardPlan.simulated(k, reduce_impl=impl)
            scaling.append(_timed_run(ctx, plan, local_prune=True))

    pruning = []
    for impl in IMPLS:
        plan = ShardPlan.simulated(prune_ab_parts, reduce_impl=impl)
        for prune in (False, True):
            pruning.append(_timed_run(ctx, plan, local_prune=prune))

    def _ab(impl: str) -> tuple[dict, dict]:
        off, on = (
            r for r in pruning if r["plan"]["reduce_impl"] == impl
        )
        return off, on

    off, on = _ab("rsag")
    payload = {
        "dataset": dataclasses.asdict(spec),
        "scaling": scaling,
        "pruning_ab": pruning,
        "headline": {
            "plan": f"simulated {prune_ab_parts}-shard, rsag schedule",
            "reduce_bytes_per_round_no_prune": off["reduce_bytes_per_round"],
            "reduce_bytes_per_round_local_prune": on["reduce_bytes_per_round"],
            "reduce_bytes_ratio": round(
                off["reduce_bytes_total"] / max(1, on["reduce_bytes_total"]), 2
            ),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = []
    for r in scaling:
        p = r["plan"]
        out.append(row(
            f"dist/scaling/{p['reduce_impl']}/k={p['n_parts']}",
            1e6 * r["wall_time_s"],
            f"reduce_B_per_round={r['reduce_bytes_per_round']}"
            f"|concepts={r['n_concepts']}|closures={r['closures_computed']}",
        ))
    for r in pruning:
        p = r["plan"]
        tag = "prune" if r["local_prune"] else "noprune"
        out.append(row(
            f"dist/prune_ab/{p['reduce_impl']}/k={p['n_parts']}/{tag}",
            1e6 * r["wall_time_s"],
            f"reduce_B_per_round={r['reduce_bytes_per_round']}"
            f"|closures={r['closures_computed']}",
        ))
    out.append(row(
        "dist/headline_prune_bytes_ratio",
        payload["headline"]["reduce_bytes_ratio"],
        f"rsag_k{prune_ab_parts}_noprune_vs_prune|json={out_path}",
    ))
    return out
