"""Distributed FCA launcher — the paper's system as a production CLI.

    # mine (default subcommand)
    python -m repro.launch.fca --dataset mushroom --scale 0.05 \
        --algorithm mrganter+ --parts 8 --reduce rsag --local-prune

    # mine → build the device-resident concept store → serve a mixed
    # query/update batch (repro.query)
    python -m repro.launch.fca serve --dataset mushroom --scale 0.02 \
        --parts 4 --reduce auto --queries 256 --topk 32 --updates 8

    # serve under sustained load: open-loop Poisson arrivals through the
    # continuous admission queue, live /metrics endpoint, saved
    # OpenMetrics exposition (repro.serve)
    python -m repro.launch.fca serve --dataset mushroom --scale 0.02 \
        --parts 4 --load-qps 200 --load-seconds 5 --arrival burst \
        --max-wait-ms 2 --queue-depth 512 \
        --mix closure=0.5,topk=0.3,lookup=0.1,update=0.1 \
        --metrics-port 0 --metrics-dump metrics.txt

    # iceberg-mine → extract implication/association-rule bases → answer
    # a rule-query batch (repro.rules)
    python -m repro.launch.fca rules --dataset census-income --scale 0.002 \
        --parts 8 --min-support 0.05 --min-conf 0.5 --rule-queries 128

With a real multi-device runtime pass ``--mesh`` to shard the context over
the device mesh (objects over the pod×data axes the ShardPlan picks up);
otherwise partitions are simulated on one device with bit-identical
arithmetic.  Either way the run executes through one
:class:`repro.dist.ShardPlan` — the CLI only chooses its geometry.
``--reduce auto`` lets the plan pick allgather-vs-rsag per round from the
measured batch size (the per-round record lands in the printed stats);
``--calibrate-hops`` replaces the model's 4096 B latency default with a
measured interconnect probe.  ``--min-support`` takes an absolute object
count (≥ 1) or a fraction of |O| (in (0, 1)); the resolved count is echoed
in the JSON stats.

Observability (all subcommands): ``--trace out.json`` records every round
/ speculative dispatch+reconcile / query micro-batch / stream commit as a
Chrome/Perfetto timeline (open at https://ui.perfetto.dev, validate with
``python -m repro.obs out.json``) and adds a per-span latency rollup to
the printed stats; ``--stats-json`` writes those stats to a file.  Query
stats carry HDR-histogram p50/p95/p99 micro-batch latencies
(``latency_percentiles``); mining stats carry per-round ones.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import ClosureEngine, bitset, mrcbo, mrganter, mrganter_plus
from repro.core.engine import BACKENDS
from repro.core.mr import PIPELINES, ROUNDS
from repro.data import fca_datasets
from repro.dist.collectives import IMPLS
from repro.dist.shardplan import ShardPlan
from repro.obs import (
    Tracer,
    span_rollup,
    start_device_trace,
    stop_device_trace,
    use_tracer,
)


def build_plan(args) -> ShardPlan:
    """The run's ShardPlan from CLI geometry flags."""
    calibrate = getattr(args, "calibrate_hops", False)
    cand = getattr(args, "cand_shards", 1)
    if args.mesh:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model=1, pod=args.pod, cand=cand)
        return ShardPlan.over_mesh(
            mesh, reduce_impl=args.reduce, calibrate_hops=calibrate
        )
    return ShardPlan.simulated(
        args.parts,
        cand_parts=cand,
        reduce_impl=args.reduce,
        calibrate_hops=calibrate,
    )


def _resolved_min_support(args, ctx) -> int | None:
    if args.min_support is None:
        return None
    from repro.rules import resolve_min_support

    return resolve_min_support(args.min_support, ctx.n_objects)


def _mine(args, ctx, plan, backend, min_support=None):
    eng = ClosureEngine(ctx, plan=plan, backend=backend)
    algo = {"mrganter": mrganter, "mrganter+": mrganter_plus, "mrcbo": mrcbo}[
        args.algorithm
    ]
    kw = {
        "pipeline": args.pipeline,
        "rounds": getattr(args, "rounds", "sync"),
        "min_support": min_support,
    }
    if args.algorithm == "mrganter+":
        kw["local_prune"] = args.local_prune
    res = algo(ctx, eng, max_iterations=args.max_iterations, **kw)
    return eng, res


def cmd_mine(args, ctx, spec, plan, backend):
    eng, res = _mine(args, ctx, plan, backend, _resolved_min_support(args, ctx))
    return {
        "dataset": spec.name,
        "objects": spec.n_objects,
        "attributes": spec.n_attrs,
        "density": round(spec.density, 4),
        "synthetic": spec.synthetic,
        "plan": plan.describe(),
        "backend": backend,
        "pipeline": args.pipeline,
        "rounds": args.rounds,
        "algorithm": res.algorithm,
        "min_support_resolved": res.min_support,
        "concepts": res.n_concepts,
        "iterations": res.n_iterations,
        "closures_computed": res.n_closures_computed,
        "modeled_comm_bytes": res.modeled_comm_bytes,
        "modeled_dispatch_bytes": eng.stats.modeled_dispatch_bytes,
        "modeled_collective_bytes": eng.stats.modeled_collective_bytes,
        "reduce_rounds": eng.stats.reduce_rounds,
        "dispatch_s": round(eng.stats.dispatch_s, 4),
        "host_blocked_s": round(eng.stats.host_blocked_s, 4),
        "spec_rounds": eng.stats.spec_rounds,
        "spec_fallbacks": eng.stats.spec_fallbacks,
        "spec_discarded": eng.stats.spec_discarded,
        "wall_time_s": round(res.wall_time_s, 3),
    }


def cmd_serve(args, ctx, spec, plan, backend):
    """mine → build store → serve one mixed query/update batch."""
    from repro.query import ConceptStore, QueryEngine, StreamUpdater
    from repro.query.engine import QueryConfig

    eng, res = _mine(args, ctx, plan, backend, _resolved_min_support(args, ctx))

    t0 = time.perf_counter()
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    build_s = time.perf_counter() - t0
    qe = QueryEngine(
        store, QueryConfig(slots=args.slots, backend=backend)
    )

    rng = np.random.default_rng(args.seed)
    # query attrsets: real rows with ~25% of their bits kept, so closures
    # hit populated regions of the lattice
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=args.queries)]
    keep = bitset.pack_bool(
        rng.random((args.queries, ctx.n_attrs)) < 0.25, ctx.W
    )
    queries = base & keep

    t0 = time.perf_counter()
    closures, supports, ids = qe.closure_batch(queries)
    tops, top_supports = qe.topk_batch(queries[: args.topk], k=5)
    hit_ids = ids[ids >= 0]
    trav = qe.children(hit_ids[:8]) if hit_ids.size else []
    query_s = time.perf_counter() - t0

    # streaming update: synthetic rows matched to the context density.
    # Skipped for iceberg serves: the Godin grow formula maintains the
    # FULL intent family, so streaming onto an iceberg store would drift
    # to neither the full nor the iceberg lattice of the grown context
    # (re-mine, or serve rules, after updates instead).
    receipt, update_s, post_ids = None, None, ids
    if res.min_support is None:
        upd = StreamUpdater(store)
        new_rows = bitset.pack_bool(
            rng.random((args.updates, ctx.n_attrs)) < max(0.05, spec.density),
            ctx.W,
        )
        t0 = time.perf_counter()
        receipt = upd.stage(new_rows)
        upd.commit()
        update_s = time.perf_counter() - t0
        post_ids = qe.lookup_batch(closures)  # same intents, new snapshot
    elif args.updates:
        print(
            "serve --min-support: skipping the streaming-update phase "
            "(Godin insertion maintains the full family, not an iceberg)",
            file=sys.stderr,
        )

    n_q = args.queries + min(args.queries, args.topk)
    out = {
        "dataset": spec.name,
        "plan": plan.describe(),
        "backend": backend,
        "algorithm": res.algorithm,
        "min_support_resolved": res.min_support,
        "concepts": res.n_concepts,
        "mine_wall_s": round(res.wall_time_s, 3),
        "store": store.describe(),
        "store_build_s": round(build_s, 3),
        "slots": args.slots,
        "queries": int(n_q),
        "query_wall_s": round(query_s, 4),
        "queries_per_s": round(n_q / max(query_s, 1e-9), 1),
        "closure_hit_rate": (
            round(float((ids >= 0).mean()), 4) if ids.size else None
        ),
        "traversal_children_sample": [len(t) for t in trav],
        "top_support_max": (
            int(top_supports.max()) if top_supports.size else None
        ),
        "update": None if receipt is None else dataclass_dict(receipt),
        "update_commit_s": None if update_s is None else round(update_s, 4),
        "post_update_version": store.snapshot.version,
        "post_update_hit_rate": (
            round(float((post_ids >= 0).mean()), 4) if post_ids.size else None
        ),
        "query_stats": qe.describe()["stats"],
    }
    if args.load_qps:
        out["serve_load"] = _serve_load_phase(
            args, ctx, spec, res, store, qe, plan
        )
    return out


def _parse_mix(s: str) -> dict[str, float]:
    """``"closure=0.6,topk=0.3,update=0.1"`` → weighted workload mix."""
    mix = {}
    for part in s.split(","):
        kind, eq, w = part.partition("=")
        if not eq:
            raise SystemExit(f"--mix: expected kind=weight, got {part!r}")
        try:
            mix[kind.strip()] = float(w)
        except ValueError:
            raise SystemExit(f"--mix: non-numeric weight in {part!r}")
    return mix


def _serve_load_phase(args, ctx, spec, res, store, qe, plan):
    """``fca serve --load-qps N``: sustained open-loop load through the
    continuous admission queue, with optional live ``/metrics`` scraping
    (``--metrics-port``) and a saved exposition (``--metrics-dump``)."""
    from repro.obs import MetricsServer, to_openmetrics
    from repro.obs.slo import SLO
    from repro.query import StreamUpdater
    from repro.serve import (
        ARRIVALS,
        AdmissionConfig,
        AdmissionQueue,
        make_workload,
        run_load,
    )

    mix = _parse_mix(args.mix)
    if "update" in mix and res.min_support is not None:
        # same constraint as the one-shot update phase: Godin insertion
        # maintains the full intent family, never an iceberg's
        print("serve --min-support: dropping 'update' from the load mix",
              file=sys.stderr)
        mix.pop("update")
    rules_index = None
    if "rules" in mix:
        from repro.rules import RuleIndex, extract_bases

        rules_index = RuleIndex.build(
            extract_bases(store, min_conf=args.min_conf), plan=plan
        )
    cfg = AdmissionConfig(
        max_wait_s=args.max_wait_ms / 1000.0,
        depth=args.queue_depth,
        rules_k=args.topk_rules,
        rules_min_conf=args.min_conf,
        rules_rank_by=args.rank_by,
    )
    queue = AdmissionQueue(qe, cfg, rules_index=rules_index)
    updater = StreamUpdater(store) if "update" in mix else None

    rng = np.random.default_rng(args.seed + 1)
    # warm each kind's jit cache: the measured window should show steady
    # state, not first-call compilation
    warm = ctx.rows[rng.integers(0, ctx.n_objects, size=qe.cfg.slots)]
    for kind in sorted(set(mix) - {"update"}):
        if kind == "closure":
            qe.closure_batch(warm)
        elif kind == "topk":
            qe.topk_batch(warm, k=cfg.topk_k)
        elif kind == "lookup":
            qe.lookup_batch(warm)
        elif kind == "rules":
            qe.rules_batch(rules_index, warm, k=cfg.rules_k,
                           min_conf=cfg.rules_min_conf,
                           rank_by=cfg.rules_rank_by)

    kwargs = {"factor": args.burst_factor} if args.arrival == "burst" else {}
    arrivals = ARRIVALS[args.arrival](
        args.load_qps, args.load_seconds, rng, **kwargs
    )
    events = make_workload(
        ctx, len(arrivals), rng, mix=mix, density=spec.density
    )
    server = None
    if args.metrics_port is not None:
        server = MetricsServer(lambda: queue.registry, port=args.metrics_port)
        print(f"serving metrics at {server.url}", file=sys.stderr)
    try:
        rep = run_load(queue, arrivals, events, updater=updater, slo=SLO())
    finally:
        if args.metrics_dump:
            with open(args.metrics_dump, "w") as fh:
                fh.write(to_openmetrics(queue.registry))
        if server is not None:
            server.close()
    out = rep.describe()
    out["arrival"] = args.arrival
    out["mix"] = mix
    out["queue"] = queue.describe()
    return out


def cmd_rules(args, ctx, spec, plan, backend):
    """iceberg-mine → store → extract DG + Luxenburger bases → serve a
    rule-query batch through the QueryEngine's fixed-slot rule ops."""
    from repro.query import ConceptStore, QueryEngine
    from repro.query.engine import QueryConfig
    from repro.rules import RuleIndex, extract_bases
    from repro.rules.index import rule_query_mix

    min_support = _resolved_min_support(args, ctx)
    if min_support is None:  # rules without a threshold = iceberg at 1
        min_support = 1
    eng, res = _mine(args, ctx, plan, backend, min_support)

    t0 = time.perf_counter()
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    basis = extract_bases(store, min_conf=args.min_conf)
    index = RuleIndex.build(basis, plan=plan)
    basis_s = time.perf_counter() - t0

    qe = QueryEngine(store, QueryConfig(slots=args.slots, backend=backend))
    rng = np.random.default_rng(args.seed)
    n_q = args.rule_queries
    queries = rule_query_mix(ctx, index, n_q, rng)

    t0 = time.perf_counter()
    ids, scores, consequents = qe.rules_batch(
        index, queries, k=args.topk_rules, min_conf=args.min_conf,
        rank_by=args.rank_by,
    )
    query_s = time.perf_counter() - t0
    hits = ids[:, 0] >= 0

    return {
        "dataset": spec.name,
        "plan": plan.describe(),
        "backend": backend,
        "algorithm": res.algorithm,
        "min_support_resolved": min_support,
        "min_conf": args.min_conf,
        "iceberg_concepts": res.n_concepts,
        "mine_iterations": res.n_iterations,
        "mine_wall_s": round(res.wall_time_s, 3),
        "store_build_s": round(build_s, 3),
        "basis": basis.describe(),
        "rule_index": index.describe(),
        "basis_extract_s": round(basis_s, 3),
        "rule_queries": int(n_q),
        "rank_by": args.rank_by,
        "rule_query_wall_s": round(query_s, 4),
        "rule_queries_per_s": round(n_q / max(query_s, 1e-9), 1),
        "rule_hit_rate": round(float(hits.mean()), 4) if n_q else None,
        "top_score_max": float(scores.max()) if scores.size else None,
        "consequent_bits_mean": (
            round(float(bitset.popcount(consequents).mean()), 2)
            if n_q
            else None
        ),
        "reduce_rounds": eng.stats.reduce_rounds,
        "query_stats": qe.describe()["stats"],
    }


def dataclass_dict(obj):
    import dataclasses

    return dataclasses.asdict(obj)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("command", nargs="?", default="mine",
                   choices=["mine", "serve", "rules"],
                   help="mine (default): run an MR* miner; serve: mine, "
                        "build the repro.query concept store, then run a "
                        "mixed query/update batch; rules: iceberg-mine, "
                        "extract the DG/Luxenburger bases, answer a "
                        "rule-query batch")
    p.add_argument("--dataset", default="mushroom",
                   choices=list(fca_datasets.PAPER_DATASETS))
    p.add_argument("--scale", type=float, default=0.05)
    p.add_argument("--algorithm", default="mrganter+",
                   choices=["mrganter", "mrganter+", "mrcbo"])
    p.add_argument("--parts", type=int, default=8)
    p.add_argument("--cand-shards", type=int, default=1,
                   help="2-D decomposition: block the candidate/frontier "
                        "axis over this many devices (--mesh: a 'cand' mesh "
                        "axis) or simulated lanes; one round then absorbs "
                        "cand-shards × max_batch candidates at the same "
                        "per-device footprint")
    p.add_argument("--reduce", default="rsag",
                   choices=list(IMPLS) + ["auto"],
                   help="AND-allreduce schedule the plan's reduce phase "
                        "runs; 'auto' picks allgather-vs-rsag per round "
                        "from the batch size")
    p.add_argument("--mesh", action="store_true",
                   help="shard over the jax device mesh (needs >1 device)")
    p.add_argument("--pod", type=int, default=1,
                   help="pod axis size for --mesh (>1 builds a pod×data mesh)")
    p.add_argument("--backend", default=None, choices=list(BACKENDS),
                   help="closure map backend (default: kernel — fused "
                        "Pallas frontier steps: closure, support and "
                        "driver filter in one VMEM-resident pass; "
                        "serving kernels route with it)")
    p.add_argument("--no-kernel", action="store_true",
                   help="deprecated: use --backend jnp")
    p.add_argument("--pipeline", default="device", choices=list(PIPELINES),
                   help="device-resident frontier pipeline vs host oracle loop")
    p.add_argument("--rounds", default="sync", choices=list(ROUNDS),
                   help="sync = blocking oracle rounds; async = speculative "
                        "double-buffered scheduler (device pipeline only)")
    p.add_argument("--local-prune", action="store_true",
                   help="mrganter+: per-partition seed dedupe before the "
                        "reduce (pruned candidates never cross the wire)")
    p.add_argument("--max-iterations", type=int, default=None)
    p.add_argument("--data-dir", default=None,
                   help="directory with real UCI .data files (else synthetic)")
    p.add_argument("--min-support", type=float, default=None,
                   help="iceberg threshold: absolute object count (≥1) or "
                        "fraction of |O| (in (0,1)); fused in-round for "
                        "every driver, resolved count echoed in the stats")
    p.add_argument("--calibrate-hops", action="store_true",
                   help="measure the interconnect's per-ring-step latency "
                        "(tiny allgather probe, cached) instead of the "
                        "4096 B auto_hop_bytes default")
    # serve-only knobs
    p.add_argument("--queries", type=int, default=256,
                   help="serve: closure queries in the mixed batch")
    p.add_argument("--topk", type=int, default=32,
                   help="serve: top-k queries in the mixed batch")
    p.add_argument("--updates", type=int, default=8,
                   help="serve: streamed new objects in the update batch")
    p.add_argument("--slots", type=int, default=64,
                   help="serve/rules: fixed micro-batch slot width")
    p.add_argument("--seed", type=int, default=0)
    # serve: sustained-load phase (continuous admission queue)
    p.add_argument("--load-qps", type=float, default=None,
                   help="serve: also run an open-loop sustained-load phase "
                        "at this offered QPS through the continuous "
                        "admission queue (deadline-or-full micro-batch "
                        "dispatch); results land under 'serve_load' with "
                        "p50/p95/p99 e2e latency, shed rate, and an SLO "
                        "verdict")
    p.add_argument("--load-seconds", type=float, default=3.0,
                   help="serve: duration of the --load-qps phase")
    p.add_argument("--arrival", default="poisson",
                   choices=["poisson", "burst"],
                   help="serve: arrival process for --load-qps (burst = "
                        "square-wave-modulated Poisson, mean held at the "
                        "target rate)")
    p.add_argument("--burst-factor", type=float, default=4.0,
                   help="serve: peak/trough rate ratio for --arrival burst")
    p.add_argument("--max-wait-ms", type=float, default=2.0,
                   help="serve: admission deadline — a partial micro-batch "
                        "dispatches once its oldest request has waited this "
                        "long (full batches dispatch immediately)")
    p.add_argument("--queue-depth", type=int, default=512,
                   help="serve: per-kind admission bound; arrivals beyond "
                        "it are shed (counted, never queued)")
    p.add_argument("--mix", default="closure=0.6,topk=0.3,lookup=0.1",
                   help="serve: weighted workload mix for --load-qps, "
                        "kind=weight CSV over closure/topk/lookup/rules/"
                        "update (update streams objects through the store "
                        "— snapshot swaps between micro-batches)")
    p.add_argument("--metrics-port", type=int, default=None,
                   help="serve: expose the live registry as OpenMetrics "
                        "text on http://127.0.0.1:PORT/metrics during the "
                        "load phase (0 = ephemeral port, echoed to stderr)")
    p.add_argument("--metrics-dump", metavar="PATH", default=None,
                   help="serve: write the end-of-run OpenMetrics "
                        "exposition to PATH (validate with "
                        "`python -m repro.obs.export PATH`)")
    # rules-only knobs
    p.add_argument("--min-conf", type=float, default=0.5,
                   help="rules: Luxenburger basis + query confidence floor")
    p.add_argument("--rule-queries", type=int, default=128,
                   help="rules: rule-query batch size")
    p.add_argument("--topk-rules", type=int, default=5,
                   help="rules: top-k rules returned per query")
    p.add_argument("--rank-by", default="confidence",
                   choices=["confidence", "lift"],
                   help="rules: top-k rank metric")
    # observability (all subcommands)
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="write a Chrome/Perfetto trace_event JSON timeline "
                        "of the run (every mining round with its dispatch/"
                        "allreduce/filter phases, speculative dispatch+"
                        "reconcile windows, serving micro-batches, stream "
                        "stage/commit) to PATH; load in ui.perfetto.dev or "
                        "validate with `python -m repro.obs.trace PATH`")
    p.add_argument("--stats-json", metavar="PATH", default=None,
                   help="also write the run's JSON stats to PATH (with "
                        "--trace they gain a per-span latency rollup)")
    p.add_argument("--device-trace", metavar="DIR", default=None,
                   help="pass-through to jax.profiler.start_trace(DIR): "
                        "capture the XLA device timeline alongside --trace")
    args = p.parse_args(argv)

    backend = args.backend
    if backend is None:
        backend = "jnp" if args.no_kernel else "kernel"
    elif args.no_kernel:
        print("--no-kernel is deprecated and ignored when --backend is given",
              file=sys.stderr)

    ctx, spec = fca_datasets.load(args.dataset, scale=args.scale,
                                  data_dir=args.data_dir)
    plan = build_plan(args)
    cmd = {"mine": cmd_mine, "serve": cmd_serve, "rules": cmd_rules}[
        args.command
    ]
    tracer = Tracer() if args.trace else None
    if args.device_trace:
        start_device_trace(args.device_trace)
    try:
        if tracer is not None:
            with use_tracer(tracer):
                out = cmd(args, ctx, spec, plan, backend)
        else:
            out = cmd(args, ctx, spec, plan, backend)
    finally:
        if args.device_trace:
            stop_device_trace()
    if tracer is not None:
        tracer.save(args.trace)
        out["trace_path"] = args.trace
        out["span_rollup"] = span_rollup(tracer.to_dict()["traceEvents"])
    if args.stats_json:
        with open(args.stats_json, "w") as fh:
            json.dump(out, fh, indent=2)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
