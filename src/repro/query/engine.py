"""QueryEngine — fixed-slot micro-batched SPMD serving over a ConceptStore.

The serving twin of :class:`repro.serve.engine.ServeEngine`'s
continuous-batching core, for lattice queries instead of tokens: requests
pad into fixed ``slots``-wide micro-batches (SPMD-friendly static shapes)
and each micro-batch executes as ONE plan round —

  * ``closure``  — closure-of-attrset: per-shard local closure over the
    object-sharded context → AND-allreduce (+ psum of supports) → fused
    two-level-hash concept lookup, all inside one ``ShardPlan.spmd``
    region.  B queries cost one collective round, not B.
  * ``top_k``    — the same closure round with a fused
    contains-mask × supports ``lax.top_k`` stage instead of the lookup.
  * ``extents``  — per-shard extent-table column gather + one all-gather.
  * ``lookup`` / ``supers`` / ``subs`` / ``children`` / ``parents`` —
    pure replicated-table reads: zero collective rounds.

The jitted steps close over the *plan*, never over a snapshot: snapshot
tables arrive as arguments, so streaming commits (new lattice versions)
reuse the compiled steps as long as the padded shapes match — the same
discipline as the mining engine's ``_frontier_cache``.

Schedule autotuning rides along: with ``plan.reduce_impl == "auto"`` each
micro-batch resolves allgather-vs-rsag from its padded slot count
(``plan.resolve_impl``) and the choice is recorded in ``stats``.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitset
from repro.dist import collectives
from repro.kernels import ops
from repro.kernels import serve as skern
from repro.obs import StatsBase
from repro.obs import trace as obs
from repro.query.store import (
    ConceptStore,
    lookup_ids_jnp,
    pack_bool_jnp,
)

BACKENDS = ("kernel", "jnp", "matmul")


@dataclasses.dataclass
class QueryStats(StatsBase):
    """Serving-side stats: the schedule census (``reduce_rounds`` /
    ``auto_hop_bytes`` / ``hop_calibrated``) and ``latency_percentiles``
    are inherited from :class:`repro.obs.StatsBase` — one definition
    shared with the mining engine's ``EngineStats``."""

    queries: int = 0
    micro_batches: int = 0
    collective_rounds: int = 0
    modeled_comm_bytes: int = 0
    by_type: dict = dataclasses.field(default_factory=dict)

    def charge(self, kind: str, n: int, batches: int):
        self.queries += n
        self.micro_batches += batches
        self.by_type[kind] = self.by_type.get(kind, 0) + n


@dataclasses.dataclass
class QueryConfig:
    slots: int = 64  # fixed micro-batch width; every dispatch pads to this
    backend: str = "jnp"  # closure map backend, as in ClosureEngine
    block_n: int = 256
    interpret: bool = True


class QueryEngine:
    def __init__(
        self,
        store: ConceptStore,
        cfg: QueryConfig | None = None,
        *,
        clock=time.perf_counter,
    ):
        self.store = store
        self.cfg = cfg or QueryConfig()
        # Injectable clock for the per-micro-batch service timings: the
        # admission queue and load generator run under virtual clocks in
        # tests, and the engine's latency histograms must tick on the
        # same timebase (repro.analysis lints wall-clock reads here).
        self.clock = clock
        if self.cfg.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.cfg.backend!r}; choose {BACKENDS}"
            )
        self.plan = store.plan
        self.n_attrs = store.ctx.n_attrs
        self.W = store.ctx.W
        self.stats = QueryStats(
            auto_hop_bytes=self.plan.auto_hop_bytes,
            hop_calibrated=self.plan.hop_calibrated,
        )
        self._mask = bitset.attr_mask(self.n_attrs, self.W)
        # jit caches — keyed by everything static to the compiled step.
        # Guarded by ``_steps_lock``: the admission dispatcher thread and
        # the main thread can both miss a cold key, and an unguarded
        # check-then-set would trace and compile the same step twice.
        self._steps_lock = threading.Lock()
        self._closure_steps: dict = {}  # (impl, probe) -> step
        self._topk_steps: dict = {}  # (impl, k) -> step
        self._rules_steps: dict = {}  # k -> step (metric is an operand)
        self._extent_step = None

    # -- step builders (close over plan/config only) ------------------------

    def _local_closure(self):
        cfg, n_attrs = self.cfg, self.n_attrs
        if cfg.backend == "matmul":
            return lambda rows_local, cands: ops.closure_matmul(
                rows_local, cands, n_attrs, n_valid_rows=rows_local.shape[0]
            )
        return lambda rows_local, cands: ops.batched_closure(
            rows_local,
            cands,
            n_attrs,
            n_valid_rows=rows_local.shape[0],
            block_n=cfg.block_n,
            use_kernel=cfg.backend == "kernel",
            interpret=cfg.interpret,
        )

    def _closure_body(self, impl: str):
        plan, n_attrs = self.plan, self.n_attrs
        local_closure = self._local_closure()
        mask = self._mask
        axes = plan.reduce_axes

        def body(rows_local, cands, n_pad):
            lc, ls = local_closure(rows_local, cands)
            gc = collectives.and_allreduce(lc, axes, impl=impl, n_attrs=n_attrs)
            return gc & jnp.asarray(mask), lax.psum(ls, axes) - n_pad

        return body

    def _closure_step(self, impl: str, probe: int):
        step = self._closure_steps.get((impl, probe))  # lock: ok — racy fast path, re-checked under lock
        if step is not None:
            return step
        with self._steps_lock:
            step = self._closure_steps.get((impl, probe))
            if step is not None:
                return step
            n_attrs = self.n_attrs

            def post(gc, gs, intents, skeys, n_concepts):
                ids = lookup_ids_jnp(
                    gc, intents, skeys, n_concepts,
                    n_attrs=n_attrs, probe=probe,
                )
                return gc, gs, ids

            step = jax.jit(
                self.plan.spmd(
                    self._closure_body(impl), n_rep=2, post=post, n_post_rep=3
                )
            )
            self._closure_steps[(impl, probe)] = step
        return step

    def _topk_step(self, impl: str, k: int):
        step = self._topk_steps.get((impl, k))  # lock: ok — racy fast path, re-checked under lock
        if step is not None:
            return step
        with self._steps_lock:
            step = self._topk_steps.get((impl, k))
            if step is not None:
                return step
            cfg = self.cfg

            def post(gc, gs, intents, supports, n_concepts):
                # backend="kernel": the whole post — subset test, validity
                # mask, k selection passes — runs as ONE fused Pallas pass
                # with the query block and intent table VMEM-resident
                # (repro.kernels.serve).  Bit-identical to the jnp stage
                # below, which remains its tested oracle; oversized tables
                # fall back (the shapes are static at trace time).
                if skern.supports_serve(
                    cfg.backend, intents.shape[0], intents.shape[1],
                    gc.shape[0],
                ):
                    idx, vals = skern.contains_topk_call(
                        gc, intents, supports, n_concepts,
                        k=k, interpret=cfg.interpret,
                    )
                    return gc, gs, idx, vals
                # concepts whose intent ⊇ the query attrset == subconcepts
                # of closure(attrset); masked top-k by support.  Extracted
                # with k unrolled argmax passes — same order as lax.top_k
                # (desc value, asc index on ties) but ~100× faster than
                # XLA CPU's top_k on a [slots, cap] score matrix.
                contains = jnp.all(
                    (gc[:, None, :] & ~intents[None, :, :]) == 0, axis=-1
                )
                valid = jnp.arange(intents.shape[0]) < n_concepts
                scores = jnp.where(
                    contains & valid[None, :], supports[None, :], -1
                ).astype(jnp.int32)
                rows_arange = jnp.arange(scores.shape[0])
                ids, vals = [], []
                for _ in range(k):
                    idx = jnp.argmax(scores, axis=1)
                    val = jnp.take_along_axis(
                        scores, idx[:, None], axis=1
                    )[:, 0]
                    ids.append(idx.astype(jnp.int32))
                    vals.append(val)
                    scores = scores.at[rows_arange, idx].set(-2)
                vals = jnp.stack(vals, axis=1)
                idx = jnp.stack(ids, axis=1)
                idx = jnp.where(vals >= 0, idx, -1)
                vals = jnp.maximum(vals, -1)  # exhausted slots read as -1
                return gc, gs, idx, vals

            step = jax.jit(
                self.plan.spmd(
                    self._closure_body(impl), n_rep=2, post=post, n_post_rep=3
                )
            )
            self._topk_steps[(impl, k)] = step
        return step

    def _extents_step(self):
        step = self._extent_step  # lock: ok — racy fast path, re-checked under lock
        if step is not None:
            return step
        with self._steps_lock:
            if self._extent_step is not None:
                return self._extent_step
            axes = self.plan.reduce_axes

            def body(ext_local, ids):
                # [Nl, B] membership bits of each queried concept's column
                w = jnp.take(ext_local, ids // 32, axis=1)
                b = (w >> (ids % 32).astype(jnp.uint32)) & jnp.uint32(1)
                return lax.all_gather(b, axes, axis=0, tiled=True)  # [Np, B]

            def post(bits):
                pad = (-bits.shape[0]) % 32
                if pad:
                    bits = jnp.concatenate(
                        [bits, jnp.zeros((pad, bits.shape[1]), bits.dtype)]
                    )
                return pack_bool_jnp(bits.T.astype(bool))  # [B, Wo]

            step = self._extent_step = jax.jit(
                self.plan.spmd(body, n_rep=1, post=post)
            )
        return step

    # -- micro-batch plumbing ----------------------------------------------

    def _chunks(self, arr: np.ndarray):
        """Yield ``(lo, n_valid, chunk)`` with every chunk padded to the
        fixed slot width — one compiled shape per step, ServeEngine-style.
        Callers early-return on empty batches before reaching here."""
        S = self.cfg.slots
        for lo in range(0, arr.shape[0], S):
            chunk = arr[lo : lo + S]
            b = chunk.shape[0]
            if b < S:
                pad = np.zeros((S - b, *arr.shape[1:]), arr.dtype)
                chunk = np.concatenate([chunk, pad], axis=0)
            yield lo, b, chunk

    def _obs_batch(self, kind: str, dt: float, version: int | None = None):
        """One micro-batch's telemetry: the ``micro_batch`` percentile
        key (the bench/CI contract) plus per-kind ``service_s`` service
        histograms, a dispatch counter, and the snapshot-version gauge —
        all in the stats registry the admission queue and the OpenMetrics
        exporter share."""
        st = self.stats
        st.observe_latency("micro_batch", dt)
        reg = st.registry
        reg.observe("service_s", dt, kind=kind)
        reg.counter("micro_batches_total", kind=kind)
        if version is not None:
            reg.gauge("snapshot_version", version)

    def _charge_round(self, cap: int) -> str:
        impl = self.plan.resolve_impl(cap, self.W, self.n_attrs)
        st = self.stats
        st.collective_rounds += 1
        st.record_reduce(impl)
        st.modeled_comm_bytes += collectives.modeled_comm_bytes(
            impl, self.plan.n_parts, cap, self.W, self.n_attrs
        )
        return impl

    # -- queries ------------------------------------------------------------

    def closure_batch(
        self, attrsets: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Closure-of-attrset for [B, W] packed queries → (closed intents
        [B, W], supports [B], concept ids [B]).  One SPMD round per
        micro-batch; ids resolve against the snapshot read at entry."""
        st = self.store.state  # one consistent (rows, snapshot) view
        snap, rows, n_pad = st.snapshot, st.rows, st.n_pad
        attrsets = np.ascontiguousarray(attrsets, np.uint32) & self._mask
        B = attrsets.shape[0]
        out_c = np.empty((B, self.W), np.uint32)
        out_s = np.empty((B,), np.int32)
        out_i = np.empty((B,), np.int32)
        if B == 0:
            self.stats.charge("closure", 0, 0)
            return out_c, out_s, out_i
        batches = 0
        for lo, b, chunk in self._chunks(attrsets):
            t0 = self.clock()
            with obs.current().span(
                "query/micro_batch", kind="closure", slots=chunk.shape[0]
            ):
                impl = self._charge_round(chunk.shape[0])
                gc, gs, ids = self._closure_step(impl, snap.probe)(
                    rows, jnp.asarray(chunk), jnp.int32(n_pad),
                    snap.intents, snap.skeys, jnp.int32(snap.n_concepts),
                )
                out_c[lo : lo + b] = np.asarray(gc)[:b]
                out_s[lo : lo + b] = np.asarray(gs)[:b]
                out_i[lo : lo + b] = np.asarray(ids)[:b]
            self._obs_batch("closure", self.clock() - t0, snap.version)
            batches += 1
        self.stats.charge("closure", B, batches)
        return out_c, out_s, out_i

    def topk_batch(
        self, attrsets: np.ndarray, k: int = 5
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-k concepts by support containing each query attrset →
        (ids [B, k], supports [B, k]); -1 id pads when fewer match."""
        st = self.store.state
        snap, rows, n_pad = st.snapshot, st.rows, st.n_pad
        attrsets = np.ascontiguousarray(attrsets, np.uint32) & self._mask
        B = attrsets.shape[0]
        out_i = np.empty((B, k), np.int32)
        out_v = np.empty((B, k), np.int32)
        if B == 0:
            self.stats.charge("topk", 0, 0)
            return out_i, out_v
        batches = 0
        for lo, b, chunk in self._chunks(attrsets):
            t0 = self.clock()
            with obs.current().span(
                "query/micro_batch", kind="topk", slots=chunk.shape[0]
            ):
                impl = self._charge_round(chunk.shape[0])
                _, _, idx, vals = self._topk_step(impl, k)(
                    rows, jnp.asarray(chunk), jnp.int32(n_pad),
                    snap.intents, snap.supports, jnp.int32(snap.n_concepts),
                )
                out_i[lo : lo + b] = np.asarray(idx)[:b]
                out_v[lo : lo + b] = np.asarray(vals)[:b]
            self._obs_batch("topk", self.clock() - t0, snap.version)
            batches += 1
        self.stats.charge("topk", B, batches)
        return out_i, out_v

    def lookup_batch(self, intents: np.ndarray) -> np.ndarray:
        """Concept ids for already-closed intents [B, W]; -1 for misses.
        Replicated-table read — no collective round."""
        snap = self.store.snapshot
        intents = np.ascontiguousarray(intents, np.uint32)
        B = intents.shape[0]
        out = np.empty((B,), np.int32)
        if B == 0:
            self.stats.charge("lookup", 0, 0)
            return out
        batches = 0
        for lo, b, chunk in self._chunks(intents):
            t0 = self.clock()
            with obs.current().span(
                "query/micro_batch", kind="lookup", slots=chunk.shape[0]
            ):
                ids = lookup_ids_jnp(
                    jnp.asarray(chunk), snap.intents, snap.skeys,
                    jnp.int32(snap.n_concepts),
                    n_attrs=self.n_attrs, probe=snap.probe,
                )
                out[lo : lo + b] = np.asarray(ids)[:b]
            self._obs_batch("lookup", self.clock() - t0, snap.version)
            batches += 1
        self.stats.charge("lookup", B, batches)
        return out

    def _order_query(self, ids, table: jax.Array, kind: str):
        snap = self.store.snapshot
        ids = np.asarray(ids, np.int32)
        safe = np.clip(ids, 0, snap.cap - 1)
        rows = np.asarray(jnp.take(table, jnp.asarray(safe), axis=0))
        self.stats.charge(kind, ids.shape[0], 1)
        out = []
        for r, i in zip(rows, ids):
            if i < 0 or i >= snap.n_concepts:
                out.append(np.zeros((0,), np.int32))
            else:
                out.append(
                    np.nonzero(bitset.unpack_bits(r, snap.cap))[0].astype(
                        np.int32
                    )
                )
        return out

    def supers(self, ids) -> list[np.ndarray]:
        """All strict superconcepts (smaller intents) per queried id."""
        return self._order_query(ids, self.store.snapshot.sup_rows, "supers")

    def subs(self, ids) -> list[np.ndarray]:
        """All strict subconcepts (larger intents) per queried id."""
        return self._order_query(ids, self.store.snapshot.sub_rows, "subs")

    def children(self, ids) -> list[np.ndarray]:
        """Covering-relation reads: the ids each concept covers
        (``ConceptLattice.children`` convention)."""
        return self._order_query(
            ids, self.store.snapshot.children_rows, "children"
        )

    def parents(self, ids) -> list[np.ndarray]:
        return self._order_query(
            ids, self.store.snapshot.parents_rows, "parents"
        )

    def extents_batch(self, ids) -> np.ndarray:
        """Packed object extents [B, Wo] for concept ids (one all-gather
        round over the object-sharded extent table per micro-batch)."""
        st = self.store.state
        snap = st.snapshot
        ids = np.asarray(ids, np.int32)
        B = ids.shape[0]
        Wo = -(-st.N_padded // 32)
        out = np.empty((B, Wo), np.uint32)
        if B == 0:
            self.stats.charge("extents", 0, 0)
            return out
        step = self._extents_step()
        batches = 0
        for lo, b, chunk in self._chunks(np.clip(ids, 0, snap.cap - 1)):
            t0 = self.clock()
            with obs.current().span(
                "query/micro_batch", kind="extents", slots=chunk.shape[0]
            ):
                packed = step(snap.ext_cols, jnp.asarray(chunk))
                out[lo : lo + b] = np.asarray(packed)[:b]
            self._obs_batch("extents", self.clock() - t0, snap.version)
            batches += 1
            self.stats.collective_rounds += 1
            # the round's all-gather moves each shard's [Nl, B] membership
            # words to every peer — charge it like the closure rounds do
            # (transfer-census parity; tested in tests/test_obs.py)
            if self.plan.n_parts > 1:
                self.stats.record_reduce("allgather")
                n_local = st.N_padded // self.plan.n_parts
                # k·(k-1) rings × each shard's [Nl, B] words — the same
                # whole-collective convention modeled_comm_bytes uses for
                # the closure rounds (and the one repro.analysis audits);
                # the old (k-1)·Nl·B charge under-counted by ×k
                self.stats.modeled_comm_bytes += (
                    self.plan.n_parts
                    * (self.plan.n_parts - 1)
                    * n_local
                    * chunk.shape[0]
                    * 4
                )
        # misses / out-of-snapshot ids get the empty extent, mirroring
        # _order_query's empty result (never another concept's objects)
        out[(ids < 0) | (ids >= snap.n_concepts)] = 0
        self.stats.charge("extents", B, batches)
        return out

    # -- rule queries (repro.rules.RuleIndex) --------------------------------

    RANK_BY = ("confidence", "lift")

    def _rules_step(self, k: int):
        # keyed by k alone: the rank metric arrives as a runtime operand,
        # so confidence- and lift-ranked queries share one compiled step
        step = self._rules_steps.get(k)  # lock: ok — racy fast path, re-checked under lock
        if step is not None:
            return step
        with self._steps_lock:
            step = self._rules_steps.get(k)
            if step is not None:
                return step
            cfg = self.cfg

            def run(prem, added, conf, metric, rid, n_rules, queries, min_conf):
                # backend="kernel": premise-subset test → conf mask →
                # consequent union → metric top-k as one fused VMEM pass
                # (repro.kernels.serve.rules_topk_call), bit-identical to
                # the jnp stage below (its property-tested oracle).
                if skern.supports_serve(
                    cfg.backend, prem.shape[0], prem.shape[1],
                    queries.shape[0],
                ):
                    return skern.rules_topk_call(
                        prem, added, conf, metric, rid, n_rules,
                        queries, min_conf, k=k, interpret=cfg.interpret,
                    )
                R = prem.shape[0]
                # applicable[b, r]: premise_r ⊆ query attrset b
                app = jnp.all(
                    (prem[None, :, :] & ~queries[:, None, :]) == 0, axis=-1
                )
                ok = (
                    app
                    & (conf >= min_conf)[None, :]
                    & (jnp.arange(R) < n_rules)[None, :]
                )
                # premise→consequent lookup: union of all firing conclusions
                union = lax.reduce(
                    jnp.where(ok[:, :, None], added[None], jnp.uint32(0)),
                    jnp.uint32(0),
                    lambda a, b: a | b,
                    (1,),
                )
                # top-k by the rank metric — k unrolled max passes (same
                # order as lax.top_k, ~100× faster on XLA CPU).  Ties on
                # the metric break by *rule id* (lowest wins), never by
                # table-slot position: the returned ranking is then
                # invariant to query-batch padding, index cap, and any
                # future rule-table layout (shard/permutation), and two
                # runs of the same query always agree.
                score = jnp.where(ok, metric[None, :], jnp.float32(-1.0))
                rows_arange = jnp.arange(score.shape[0])
                ids, vals = [], []
                for _ in range(k):
                    best = jnp.max(score, axis=1)
                    is_best = score == best[:, None]
                    sel = jnp.min(
                        jnp.where(is_best, rid[None, :], jnp.int32(0x7FFFFFFF)),
                        axis=1,
                    )
                    pos = jnp.argmax(
                        is_best & (rid[None, :] == sel[:, None]), axis=1
                    )
                    ids.append(sel)
                    vals.append(best)
                    score = score.at[rows_arange, pos].set(-2.0)
                vals = jnp.stack(vals, axis=1)
                idx = jnp.stack(ids, axis=1)
                idx = jnp.where(vals >= 0, idx, -1)
                vals = jnp.maximum(vals, -1.0)
                return idx, vals, union

            step = jax.jit(run)
            self._rules_steps[k] = step
        return step

    def rules_batch(
        self,
        index,
        attrsets: np.ndarray,
        *,
        k: int = 5,
        min_conf: float = 0.0,
        rank_by: str = "confidence",
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched rule lookup against a :class:`repro.rules.RuleIndex`.

        For each query attrset: the top-``k`` applicable rules (premise ⊆
        attrset, confidence ≥ ``min_conf``) ranked by ``rank_by`` ∈
        {confidence, lift}, and the premise→consequent closure — the union
        of every firing rule's added attributes.  Returns ``(rule ids
        [B, k] (-1 pads), scores [B, k], consequents [B, W])``.
        Replicated-table read, fixed-slot micro-batches, zero collective
        rounds — the rule twin of :meth:`lookup_batch`.
        """
        if rank_by not in self.RANK_BY:
            raise ValueError(
                f"unknown rank_by {rank_by!r}; choose {self.RANK_BY}"
            )
        attrsets = np.ascontiguousarray(attrsets, np.uint32) & self._mask
        B = attrsets.shape[0]
        out_i = np.empty((B, k), np.int32)
        out_s = np.empty((B, k), np.float32)
        out_c = np.empty((B, self.W), np.uint32)
        if B == 0:
            self.stats.charge("rules", 0, 0)
            return out_i, out_s, out_c
        metric = index.confidence if rank_by == "confidence" else index.lift
        step = self._rules_step(k)
        batches = 0
        for lo, b, chunk in self._chunks(attrsets):
            t0 = self.clock()
            with obs.current().span(
                "query/micro_batch", kind="rules", slots=chunk.shape[0]
            ):
                idx, vals, union = step(
                    index.premise, index.added, index.confidence, metric,
                    index.rule_id, jnp.int32(index.n_rules),
                    jnp.asarray(chunk), jnp.float32(min_conf),
                )
                out_i[lo : lo + b] = np.asarray(idx)[:b]
                out_s[lo : lo + b] = np.asarray(vals)[:b]
                out_c[lo : lo + b] = np.asarray(union)[:b]
            self._obs_batch("rules", self.clock() - t0)
            batches += 1
        self.stats.charge("rules", B, batches)
        return out_i, out_s, out_c

    def describe(self) -> dict:
        return {
            "slots": self.cfg.slots,
            "backend": self.cfg.backend,
            "plan": self.plan.describe(),
            "stats": dataclasses.asdict(self.stats),
        }
