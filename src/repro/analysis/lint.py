"""Pass 2 — host-sync, wall-clock, and recompile-hazard linter.

AST-level rules over ``src/repro``:

* ``host-sync`` — no ``.block_until_ready()`` / ``np.asarray(...)`` /
  ``jax.device_get(...)`` inside the *async driver regions* (the
  ``_*_async`` round loops in ``repro.core.mr`` and the speculative
  ``spec_*``/``reconcile_*`` orchestration in ``repro.core.frontier``).
  Those loops exist to keep rounds in flight; a stray sync collapses the
  double-buffering.  The blessed reconcile points (``_download``,
  ``_download_packed``, ``_block_scalar``) are allowlisted; ad-hoc
  exceptions annotate the line with ``# sync: ok``.

* ``wall-clock`` — no direct ``time.time()`` / ``time.monotonic()`` /
  ``time.perf_counter()`` *calls* in clock-injectable serve/loadgen/query
  code (the virtual-clock test harnesses and the schedule fuzzer depend
  on every read going through the injected ``clock``).  Bare attribute
  references in keyword defaults (``clock=time.monotonic``) are the
  injection mechanism itself and stay legal.  Annotate ``# clock: ok``.

* ``mutable-default`` — no mutable default arguments anywhere (classic
  shared-state bug, and a recompile hazard when the default reaches a
  jit boundary as an operand identity).

* ``jit-in-loop`` — no ``jax.jit(...)`` call inside a ``for``/``while``
  body (each iteration makes a fresh callable with an empty compile
  cache — the canonical silent-recompile hazard).

* ``bare-except`` — no bare ``except:`` (swallows KeyboardInterrupt and
  masks device/collective failures as empty results).

The allowlist (``allowlist.json``) maps rule -> ["path::qualname", ...];
inline annotations handle one-off lines.  Both are deliberate, visible
opt-outs — the strict gate treats everything else as an error.
"""

from __future__ import annotations

import ast
import json
import pathlib
import re

from repro.analysis.findings import Finding

# async driver regions: file (repo-relative, posix) -> function-name regexes
ASYNC_SCOPES = {
    "src/repro/core/mr.py": (r".*_async$",),
    "src/repro/core/frontier.py": (
        r"^spec_", r"^reconcile_", r"^_reconcile_", r"^discard_spec$",
        r"^_adopt_spec$", r"^_download", r"^_block_scalar$",
    ),
}

# clock-injectable tiers: every wall-clock read must go through the
# injected ``clock`` callable.  Entries ending in "/" scope a whole
# directory (the serve tier is clock-injectable wholesale).
CLOCK_SCOPES = (
    "src/repro/serve/",
    "src/repro/query/engine.py",
    "src/repro/query/stream.py",
)


def _clock_scoped(rel: str) -> bool:
    return any(
        rel == s or (s.endswith("/") and rel.startswith(s))
        for s in CLOCK_SCOPES
    )

_WALL_CLOCK_FNS = {"time", "monotonic", "perf_counter", "monotonic_ns", "time_ns"}
# np.asarray is this codebase's d2h idiom; np.array(list, ...) host
# constructions are not syncs and stay legal
_SYNC_NP_FNS = {"asarray"}

_DEFAULT_ALLOWLIST = pathlib.Path(__file__).with_name("allowlist.json")


def load_allowlist(path=None) -> dict:
    p = pathlib.Path(path) if path else _DEFAULT_ALLOWLIST
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    return {rule: set(entries) for rule, entries in data.items()}


def _line_has_marker(source_lines, lineno: int, marker: str) -> bool:
    if 1 <= lineno <= len(source_lines):
        return marker in source_lines[lineno - 1]
    return False


def _dotted(node) -> str | None:
    """'np.asarray' / 'time.monotonic' / 'jax.jit' for an Attribute/Name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, source: str, allow: dict):
        self.rel = rel
        self.lines = source.splitlines()
        self.allow = allow
        self.findings: list[Finding] = []
        self.stack: list[str] = []  # qualname segments
        self.loop_depth = 0
        self.async_patterns = [
            re.compile(p) for p in ASYNC_SCOPES.get(rel, ())
        ]
        self.clock_scoped = _clock_scoped(rel)
        self.async_depth = 0  # inside a function matching async_patterns
        self.defaults_depth = 0  # visiting default-argument expressions

    # -- helpers -----------------------------------------------------------

    def _qualname(self) -> str:
        return ".".join(self.stack) or "<module>"

    def _allowed(self, rule: str) -> bool:
        entries = self.allow.get(rule, ())
        qn = self._qualname()
        return f"{self.rel}::{qn}" in entries

    def _emit(self, rule: str, node, msg: str, marker: str | None = None):
        if marker and _line_has_marker(self.lines, node.lineno, marker):
            return
        if self._allowed(rule):
            return
        self.findings.append(
            Finding("lint", rule, f"{self.rel}:{node.lineno}", msg)
        )

    # -- scopes ------------------------------------------------------------

    def _visit_func(self, node):
        for d in node.args.defaults + [
            d for d in node.args.kw_defaults if d is not None
        ]:
            if isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call)
                and _dotted(d.func) in ("list", "dict", "set", "bytearray")
            ):
                self._emit(
                    "mutable-default", d,
                    f"mutable default argument in {self._qualname()}."
                    f"{node.name} — shared across calls and a jit-cache "
                    "identity hazard",
                )
        is_async_scope = any(p.search(node.name) for p in self.async_patterns)
        self.stack.append(node.name)
        if is_async_scope:
            self.async_depth += 1
        outer_loop = self.loop_depth
        self.loop_depth = 0  # a nested def is a fresh loop context
        self.generic_visit(node)
        self.loop_depth = outer_loop
        if is_async_scope:
            self.async_depth -= 1
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop

    # -- rules -------------------------------------------------------------

    def visit_Call(self, node):
        name = _dotted(node.func)
        if name:
            root = name.split(".", 1)[0]
            leaf = name.rsplit(".", 1)[-1]
            if (
                self.async_depth
                and (
                    (root in ("np", "numpy") and leaf in _SYNC_NP_FNS)
                    or name in ("jax.device_get", "jax.block_until_ready")
                )
            ):
                self._emit(
                    "host-sync", node,
                    f"{name}() inside async driver region "
                    f"{self._qualname()} — blocks the in-flight round; "
                    "route through the blessed reconcile points or "
                    "annotate '# sync: ok'",
                    marker="# sync: ok",
                )
            if (
                self.clock_scoped
                and root == "time"
                and leaf in _WALL_CLOCK_FNS
            ):
                self._emit(
                    "wall-clock", node,
                    f"direct {name}() in clock-injectable code "
                    f"({self._qualname()}) — read the injected clock "
                    "instead, or annotate '# clock: ok'",
                    marker="# clock: ok",
                )
            if self.loop_depth and name in ("jax.jit", "jit"):
                self._emit(
                    "jit-in-loop", node,
                    f"jax.jit called inside a loop in {self._qualname()} — "
                    "every iteration recompiles from an empty cache",
                )
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if self.async_depth and node.attr == "block_until_ready":
            self._emit(
                "host-sync", node,
                f".block_until_ready inside async driver region "
                f"{self._qualname()}",
                marker="# sync: ok",
            )
        self.generic_visit(node)

    def visit_ExceptHandler(self, node):
        if node.type is None:
            self._emit(
                "bare-except", node,
                f"bare 'except:' in {self._qualname()} — catches "
                "KeyboardInterrupt/SystemExit and masks collective failures",
            )
        self.generic_visit(node)


def lint_file(path, rel: str, allow: dict) -> list[Finding]:
    source = pathlib.Path(path).read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [
            Finding("lint", "syntax-error", f"{rel}:{e.lineno}", str(e))
        ]
    linter = _Linter(rel, source, allow)
    linter.visit(tree)
    return linter.findings


def run(report, *, root=None, allowlist_path=None, extra_files=()) -> list[Finding]:
    """Lint every ``repro`` source file under ``root`` (the repo root)."""
    root = pathlib.Path(root) if root else _repo_root()
    allow = load_allowlist(allowlist_path)
    findings = []
    files = sorted((root / "src" / "repro").rglob("*.py")) + [
        pathlib.Path(f) for f in extra_files
    ]
    for path in files:
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        findings.extend(lint_file(path, rel, allow))
        report.note_checked("lint", "files")
    return findings


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/lint.py -> repo root three levels up from src/
    return pathlib.Path(__file__).resolve().parents[3]
