"""Batched serving with prefill + lock-step decode.

    PYTHONPATH=src python examples/serve_lm.py --arch codeqwen1.5-7b
"""

import argparse
import time

from repro.configs import get_config
from repro.models import transformer
from repro.serve.engine import ServeConfig, ServeEngine


def main(arch="codeqwen1.5-7b", max_new=24):
    cfg = get_config(arch).reduced()
    params, _ = transformer.init_params(cfg, seed=0)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=256, batch_slots=4))

    prompts = [[1, 5, 42, 7], [9, 9, 3], [100, 20, 30, 40, 50], [2]]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new=max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} → {o}")
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s, "
          f"batch={len(prompts)}, greedy)")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="codeqwen1.5-7b")
    p.add_argument("--max-new", type=int, default=24)
    a = p.parse_args()
    main(arch=a.arch, max_new=a.max_new)
