"""Metrics registry — counters, gauges, and log-bucketed histograms.

The paper's unit of analysis is the *round*; the ROADMAP's serving tier
demands *latency percentiles, not just throughput*.  This module carries
both: a tiny label-aware :class:`Registry` (counters / gauges /
histograms) that `EngineStats` and `QueryStats` publish into, and an
HDR-style log-bucketed :class:`Histogram` whose p50/p95/p99 surface as
``QueryStats.latency_percentiles`` and in BENCH_query.json.

Design constraint: the stats dataclasses are public API — every existing
test and bench JSON field must survive bit-compatibly, and call sites
mutate fields directly (``st.h2d_transfers += 1``).  So the dataclasses
stay the source of truth for scalar counters; each stats object owns a
private registry (non-field, created in ``__post_init__`` so
``dataclasses.asdict`` never sees it) holding the latency histograms,
and :meth:`StatsBase.publish` exports the scalar fields into the
registry for unified export.  The previously copy-pasted schedule-census
triple (``reduce_rounds`` / ``auto_hop_bytes`` / ``hop_calibrated``)
lives once here as :class:`ScheduleCensus`, so the autotuner's census is
recorded identically in the mining and serving tiers.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# HDR-style log-bucketed histogram
# ---------------------------------------------------------------------------

# Bucket boundaries grow geometrically by 2**(1/8) (~9% relative error per
# bucket) from a 1 µs floor — sparse dict storage, so an idle histogram
# costs one empty dict.
_FACTOR = 2.0 ** 0.125
_LOG_FACTOR = math.log(_FACTOR)
_VMIN = 1e-6


class Histogram:
    """Log-bucketed latency histogram with percentile readout.

    Values are seconds.  ``record`` is O(1); ``percentile`` walks the
    sorted buckets (tens of entries for realistic latency ranges).
    Relative quantile error is bounded by the bucket factor (~9%), the
    standard HDR trade: constant memory, no sample retention.

    The ~9% bound only holds *above* the 1 µs floor: observations below
    it land in the explicit underflow bucket (index 0, upper edge
    ``_VMIN``), are counted in ``count``/``sum``/percentile ranks as
    usual, and surface separately as :attr:`underflow` so a histogram
    dominated by sub-floor samples can't masquerade as a measured one.

    ``record`` is lock-protected: the serving tier observes latencies
    from dispatcher threads while the metrics endpoint snapshots — a
    bare ``count += 1`` would lose increments across threads.
    """

    __slots__ = ("buckets", "count", "sum", "min", "max", "_lock")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0
        self._lock = threading.Lock()

    def record(self, value: float) -> None:
        v = max(float(value), 0.0)
        idx = 0 if v < _VMIN else int(math.log(v / _VMIN) / _LOG_FACTOR) + 1
        with self._lock:
            self.buckets[idx] = self.buckets.get(idx, 0) + 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    @property
    def underflow(self) -> int:
        """Observations below the 1 µs floor (bucket 0) — reported
        explicitly so percentile error bounds stay honest."""
        return self.buckets.get(0, 0)  # lock: ok — one atomic dict read

    def _state(self):
        """Consistent ``(buckets, count, sum, min, max)`` snapshot.

        Readers must not walk ``self.buckets`` directly: dispatcher
        threads ``record`` concurrently, and a dict resize mid-iteration
        raises — and even without the raise, count/buckets would tear."""
        with self._lock:
            return dict(self.buckets), self.count, self.sum, self.min, self.max

    def fraction_below(self, threshold: float) -> float:
        """Fraction of observations whose bucket lies entirely at or
        below ``threshold`` seconds (conservative to one bucket's ~9%
        width) — the SLO compliance readout.  1.0 when empty."""
        with self._lock:
            if self.count == 0:
                return 1.0
            n = sum(
                c
                for idx, c in self.buckets.items()
                if _VMIN * _FACTOR**idx <= threshold
            )
            return n / self.count

    def bucket_edges(self) -> list[tuple[float, int]]:
        """Sorted ``(upper_edge_seconds, count)`` pairs of the populated
        buckets — the exporter's cumulative-bucket source."""
        with self._lock:
            return [
                (_VMIN * _FACTOR**idx, c)
                for idx, c in sorted(self.buckets.items())
            ]

    @staticmethod
    def _percentile_of(buckets, count, vmin, vmax, q: float) -> float:
        if count == 0:
            return 0.0
        rank = q / 100.0 * count
        seen = 0
        for idx in sorted(buckets):
            seen += buckets[idx]
            if seen >= rank:
                if idx == 0:
                    return min(_VMIN, vmax)
                # bucket upper edge, clamped to observed extrema
                upper = _VMIN * _FACTOR ** idx
                return max(vmin, min(upper, vmax))
        return vmax

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]); 0.0 when empty."""
        buckets, count, _, vmin, vmax = self._state()
        return self._percentile_of(buckets, count, vmin, vmax, q)

    def percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        # one snapshot for the whole readout — p50/p95/p99 must agree on
        # the sample set even while records land concurrently
        buckets, count, _, vmin, vmax = self._state()
        return {
            f"p{q:g}": self._percentile_of(buckets, count, vmin, vmax, q)
            for q in qs
        }

    def summary(self) -> dict:
        buckets, count, total, vmin, vmax = self._state()
        return {
            "count": count,
            "sum": total,
            "min": 0.0 if count == 0 else vmin,
            "max": vmax,
            "underflow": buckets.get(0, 0),
            **{
                f"p{q:g}": self._percentile_of(buckets, count, vmin, vmax, q)
                for q in (50, 95, 99)
            },
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _key(name: str, labels: dict | None) -> tuple:
    return (name, tuple(sorted((labels or {}).items())))


# The label set every over-cap observation collapses into, plus the
# warning counter that records how many observations were rerouted per
# metric name.
OVERFLOW_LABELS = (("overflow", "true"),)
OVERFLOW_COUNTER = "labels_overflow_total"


class Registry:
    """Counters, gauges, and histograms with optional labels.

    One registry per stats object (mining engine, query engine) — no
    global mutable state, so two engines in one process never alias.

    Label cardinality is bounded: each metric name may carry at most
    ``max_label_sets`` distinct label combinations.  A labeled counter
    keyed on an unbounded value (query ids, client addresses) would
    otherwise grow the registry — and the exporter's scrape payload —
    without limit.  Observations past the cap collapse into one
    overflow series (labels ``{overflow="true"}``) and increment
    ``labels_overflow_total{metric=<name>}`` so the truncation is
    visible, never silent.

    Mutations and export take a lock: the serving tier's dispatcher
    records while the ``/metrics`` endpoint snapshots concurrently.
    """

    def __init__(self, max_label_sets: int = 64):
        self.max_label_sets = max_label_sets
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, Histogram] = {}
        self._label_sets: dict[str, set] = {}
        self._lock = threading.RLock()

    def _resolve(self, name: str, labels: dict) -> tuple:
        """The storage key for ``(name, labels)`` under the cardinality
        cap — callers must hold the lock."""
        k = _key(name, labels)
        if not k[1]:
            return k
        seen = self._label_sets.setdefault(name, set())
        if k[1] in seen:
            return k
        if len(seen) >= self.max_label_sets:
            wk = (OVERFLOW_COUNTER, (("metric", name),))
            self._counters[wk] = self._counters.get(wk, 0.0) + 1.0
            return (name, OVERFLOW_LABELS)
        seen.add(k[1])
        return k

    def counter(self, name: str, inc: float = 1.0, **labels) -> None:
        with self._lock:
            k = self._resolve(name, labels)
            self._counters[k] = self._counters.get(k, 0.0) + inc

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges[self._resolve(name, labels)] = float(value)

    def histogram(self, name: str, **labels) -> Histogram:
        with self._lock:
            k = self._resolve(name, labels)
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = Histogram()
            return h

    def observe(self, name: str, value: float, **labels) -> None:
        self.histogram(name, **labels).record(value)

    @staticmethod
    def _fmt(k: tuple) -> str:
        name, labels = k
        if not labels:
            return name
        body = ",".join(f"{lk}={lv}" for lk, lv in labels)
        return f"{name}{{{body}}}"

    def export(self) -> dict:
        """Flat ``{metric{label=...}: value-or-summary}`` snapshot."""
        counters, gauges, hists = self._snapshot()
        out: dict = {}
        for k, v in counters:
            out[self._fmt(k)] = v
        for k, v in gauges:
            out[self._fmt(k)] = v
        for k, h in hists:
            out[self._fmt(k)] = h.summary()
        return out

    def _snapshot(self):
        with self._lock:
            return (
                sorted(self._counters.items()),
                sorted(self._gauges.items()),
                sorted(self._hists.items()),
            )

    def families(self) -> list[tuple[str, str, list]]:
        """Grouped ``(name, type, [(labels_tuple, value-or-Histogram)])``
        triples, names sorted — the OpenMetrics exporter's source view.
        A name used as two different types (never done by our call
        sites) exports each type under its own suffix-disambiguated
        family downstream; here they simply appear twice."""
        counters, gauges, hists = self._snapshot()
        fams: dict[tuple, list] = {}
        for (name, labels), v in counters:
            fams.setdefault((name, "counter"), []).append((labels, v))
        for (name, labels), v in gauges:
            fams.setdefault((name, "gauge"), []).append((labels, v))
        for (name, labels), h in hists:
            fams.setdefault((name, "histogram"), []).append((labels, h))
        return [
            (name, typ, series) for (name, typ), series in sorted(fams.items())
        ]


# ---------------------------------------------------------------------------
# shared stats base: schedule census + latency percentiles
# ---------------------------------------------------------------------------


@dataclass
class ScheduleCensus:
    """The autotuner's schedule census, shared by both stats tiers.

    ``reduce_rounds`` counts collective rounds by resolved reduce
    implementation (``allgather`` / ``rsag``); ``auto_hop_bytes`` and
    ``hop_calibrated`` record the wire-model calibration the `auto`
    resolver used.  Field order puts these first in subclass dataclasses
    — safe because every construction site passes keywords.
    """

    reduce_rounds: dict = field(default_factory=dict)
    auto_hop_bytes: int = 0
    hop_calibrated: bool = False

    def record_reduce(self, impl: str, n: int = 1) -> None:
        self.reduce_rounds[impl] = self.reduce_rounds.get(impl, 0) + n


@dataclass
class StatsBase(ScheduleCensus):
    """Census + latency view: dataclass fields stay the public API; the
    private registry (non-field — invisible to ``dataclasses.asdict``)
    holds the histograms behind ``latency_percentiles``."""

    latency_percentiles: dict = field(default_factory=dict)

    def __post_init__(self):
        # object.__setattr__-free: plain attrs, excluded from asdict/fields
        self._registry = Registry()
        self._obs_lock = threading.Lock()

    @property
    def registry(self) -> Registry:
        reg = getattr(self, "_registry", None)
        if reg is None:  # copy.replace / __reduce__ paths skip __post_init__
            reg = self._registry = Registry()
        return reg

    def _latency_lock(self) -> threading.Lock:
        lock = getattr(self, "_obs_lock", None)
        if lock is None:  # same skipped-__post_init__ paths as registry
            lock = self._obs_lock = threading.Lock()
        return lock

    def observe_latency(self, kind: str, seconds: float) -> None:
        """Record one latency sample and refresh the percentile view.

        ``latency_percentiles[kind]`` is a real dict field so it rides
        ``dataclasses.asdict`` into every stats JSON for free.  The view
        is replaced copy-on-write under ``_obs_lock``: dispatcher threads
        observe while exporters ``asdict``-iterate the field, and an
        in-place mutation would change the dict under the iterator.
        """
        h = self.registry.histogram("latency_s", kind=kind)
        h.record(seconds)
        view = {k: round(v, 9) for k, v in h.percentiles().items()}
        with self._latency_lock():
            fresh = dict(self.latency_percentiles)
            fresh[kind] = view
            self.latency_percentiles = fresh

    def publish(self) -> dict:
        """Export scalar dataclass fields + histograms as one flat dict."""
        reg = self.registry
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, bool):
                reg.gauge(f.name, float(v))
            elif isinstance(v, (int, float)):
                reg.gauge(f.name, v)
            elif isinstance(v, dict) and f.name == "reduce_rounds":
                for impl, n in v.items():
                    reg.gauge("reduce_rounds", n, impl=impl)
        return reg.export()
