"""Pass 1 — jaxpr-level SPMD auditor.

Every fused step the engines cache carries an ``audit_spec`` (attached by
``repro.dist.shardplan._attach_audit``): the canonical *shard-level*
function one device runs inside the SPMD region, before shard_map/vmap
lowering.  This pass traces that function with ``jax.make_jaxpr`` under
an extended axis environment — the same named axes the plan executes
under — and verifies three contracts against the plan's analytic model:

1. **axis binding & schedule order** — every collective equation
   (psum / all_gather / all_to_all / …) binds only declared plan axes;
   object-axis collectives complete before any candidate-axis gather
   (the 2-D decomposition's "reduce inside the block, gather survivors
   after" ordering); rsag traces exactly all_to_all → all_gather and
   allgather exactly one all_gather per reduce.

2. **wire-byte census** — the bytes the traced collectives move (summed
   with the whole-collective ring convention ``modeled_comm_bytes``
   uses, times the number of independent rings the other axes induce)
   equal ``plan.modeled_reduce_bytes`` / ``plan.modeled_round_bytes_cand``
   exactly.  The analytic model the schedule autotuner and the stats
   census trust is thereby pinned to the code the compiler actually sees.

3. **region hygiene** — no pure_callback / io_callback / debug_callback
   (and hence no debug prints or host round-trips) anywhere inside an
   SPMD region.

Closure words — uint32 operands whose trailing dim is the context's W —
are the *modeled* traffic class; supports psums, gens gathers, and
scalar counts are *sideband* (reported, never counted by the model).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding

try:  # jax 0.4.x
    from jax import core as jax_core
except ImportError:  # pragma: no cover - newer jax moves core
    from jax.extend import core as jax_core  # type: ignore

COLLECTIVE_PRIMS = {
    "psum", "pmin", "pmax", "all_gather", "all_to_all",
    "reduce_scatter", "ppermute", "pbroadcast",
}
CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback", "callback"}

# step-variant argument specs (shard-level, after rows_local):
# name -> tuple of ("cand"|"rep", shape_key, dtype) where shape_key is
# resolved against the geometry: "bW" candidate bucket x words (blocked
# /cand_parts at shard level for 2-D variants), "b" bucket, "W" one set,
# "s" scalar.
_SPEC_1D = {
    "plain": (("bW", "u32"),),
    "unique": (("bW", "u32"), ("s", "i32")),
    "iceberg": (("bW", "u32"), ("s", "i32"), ("s", "i32")),
    "iceberg_unique": (("bW", "u32"), ("s", "i32"), ("s", "i32")),
    "cbo": (("bW", "u32"), ("bW", "u32"), ("b", "i32"), ("s", "i32")),
    "cbo_iceberg": (
        ("bW", "u32"), ("bW", "u32"), ("b", "i32"), ("s", "i32"), ("s", "i32")
    ),
    "ganter": (("bW", "u32"), ("W", "u32"), ("s", "bool")),
    "ganter_iceberg": (("bW", "u32"), ("W", "u32"), ("s", "bool"), ("s", "i32")),
}
_DTYPES = {"u32": jnp.uint32, "i32": jnp.int32, "bool": jnp.bool_}


@dataclasses.dataclass(frozen=True)
class CollectiveEqn:
    """One collective equation lifted out of a traced SPMD region."""

    index: int  # position in schedule order (flattened eqn walk)
    prim: str
    axes: tuple[str, ...]  # named axes the collective binds
    shape: tuple[int, ...]
    dtype: str
    ring_k: int  # devices per ring (product of bound axis sizes)
    ring_count: int  # independent rings (product of unbound env axes)
    bytes_total: int  # whole-collective wire bytes across all rings
    modeled: bool  # counted by the analytic model (closure words)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jax_core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax_core.Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for w in v:
                if isinstance(w, jax_core.ClosedJaxpr):
                    yield w.jaxpr
                elif isinstance(w, jax_core.Jaxpr):
                    yield w


def _walk(jaxpr):
    """Yield every equation in schedule order, recursing into sub-jaxprs
    (pjit bodies, scan/cond branches, pallas_call kernels) in place."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from _walk(sub)


def _eqn_axes(eqn) -> tuple[str, ...]:
    raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(raw, str):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _operand_bytes(eqn) -> int:
    total = 0
    for var in eqn.invars:
        if isinstance(var, jax_core.Literal):
            continue
        aval = var.aval
        total += int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    return total


def _ring_bytes(prim: str, k: int, nbytes: int) -> int:
    """Whole-collective wire bytes for ONE ring of ``k`` devices moving a
    per-device operand of ``nbytes`` (the ``modeled_comm_bytes``
    convention: every device's traffic summed)."""
    if k <= 1:
        return 0
    if prim in ("all_gather", "pmin", "pmax"):
        return k * (k - 1) * nbytes
    if prim == "all_to_all":
        # operand carries the leading ring axis: each device keeps 1/k
        return (k - 1) * nbytes
    if prim in ("psum", "reduce_scatter"):
        return (k - 1) * nbytes if prim == "reduce_scatter" else 2 * (k - 1) * nbytes
    return k * nbytes  # ppermute/pbroadcast: one full-operand hop per device


def trace_region(shard_fn, args, axis_env: dict, W: int):
    """Trace one shard-level SPMD function under ``axis_env`` and lift
    (collectives, callbacks) out of the jaxpr.

    ``axis_env`` maps named axis -> size for every axis the region runs
    under; a collective's ring spans the axes it binds, and the axes it
    does NOT bind multiply into independent rings (ring_count).
    """
    with jax_core.extend_axis_env_nd(list(axis_env.items())):
        closed = jax.make_jaxpr(shard_fn)(*args)
    collectives, callbacks = [], []
    for idx, eqn in enumerate(_walk(closed.jaxpr)):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS:
            callbacks.append((idx, name))
            continue
        if name not in COLLECTIVE_PRIMS:
            continue
        axes = _eqn_axes(eqn)
        k = math.prod(axis_env.get(a, 1) for a in axes)
        ring_count = math.prod(
            size for ax, size in axis_env.items() if ax not in axes
        )
        nbytes = _operand_bytes(eqn)
        aval = next(
            (v.aval for v in eqn.invars if not isinstance(v, jax_core.Literal)),
            None,
        )
        shape = tuple(aval.shape) if aval is not None else ()
        dtype = str(aval.dtype) if aval is not None else "?"
        modeled = (
            aval is not None
            and aval.dtype == jnp.uint32
            and len(shape) >= 1
            and shape[-1] == W
        )
        collectives.append(
            CollectiveEqn(
                index=idx,
                prim=name,
                axes=axes,
                shape=shape,
                dtype=dtype,
                ring_k=k,
                ring_count=ring_count,
                bytes_total=ring_count * _ring_bytes(name, k, nbytes),
                modeled=modeled,
            )
        )
    return collectives, callbacks


def _norm_axes(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def check_region(
    label: str,
    collectives,
    callbacks,
    *,
    obj_axes: tuple[str, ...],
    cand_axes: tuple[str, ...],
    impl: str,
    n_parts: int,
    cand_parts: int,
    expected_bytes: int,
    expect_obj_pattern: bool = True,
) -> list[Finding]:
    """The three contracts, applied to one traced region."""
    out = []

    def err(rule, msg):
        out.append(Finding("spmd", rule, label, msg))

    for idx, name in callbacks:
        err("callback-in-region", f"{name} equation at position {idx}")

    declared = set(obj_axes) | set(cand_axes)
    for c in collectives:
        undeclared = [a for a in c.axes if a not in declared]
        if undeclared:
            err(
                "undeclared-axis",
                f"{c.prim} binds axis(es) {undeclared} outside the plan's "
                f"declared axes {sorted(declared)}",
            )

    obj_eqns = [c for c in collectives if set(c.axes) & set(obj_axes)]
    cand_eqns = [c for c in collectives if set(c.axes) & set(cand_axes)]
    for c in collectives:
        if set(c.axes) & set(obj_axes) and set(c.axes) & set(cand_axes):
            err(
                "mixed-axis-collective",
                f"{c.prim} binds object and candidate axes together "
                f"({c.axes}) — the 2-D schedule reduces them separately",
            )

    # schedule order: all object-axis collectives precede the first
    # candidate-axis survivor gather
    if obj_eqns and cand_eqns:
        last_obj = max(c.index for c in obj_eqns)
        first_cand = min(c.index for c in cand_eqns)
        if last_obj > first_cand:
            err(
                "cand-gather-before-reduce",
                f"candidate-axis {cand_eqns[0].prim} at {first_cand} "
                f"precedes object-axis collective at {last_obj}",
            )

    # the modeled reduce schedule, in order
    obj_modeled = [c.prim for c in obj_eqns if c.modeled]
    if expect_obj_pattern:
        want = (
            []
            if n_parts <= 1
            else (["all_to_all", "all_gather"] if impl == "rsag" else ["all_gather"])
        )
        if obj_modeled != want:
            err(
                "reduce-schedule-mismatch",
                f"object-axis modeled collectives {obj_modeled} != {want} "
                f"for impl={impl!r} at k={n_parts}",
            )
    cand_modeled = [c for c in cand_eqns if c.modeled]
    if cand_axes and cand_parts > 1:
        if [c.prim for c in cand_modeled] != ["all_gather"]:
            err(
                "cand-gather-mismatch",
                "expected exactly one modeled candidate-axis all_gather "
                f"(the survivor buffer), traced "
                f"{[c.prim for c in cand_modeled]}",
            )

    traced = sum(c.bytes_total for c in collectives if c.modeled)
    if traced != expected_bytes:
        err(
            "byte-census-mismatch",
            f"traced modeled collective bytes {traced} != analytic model "
            f"{expected_bytes} (modeled eqns: "
            + "; ".join(
                f"{c.prim}{c.shape}x{c.ring_count}rings={c.bytes_total}B"
                for c in collectives
                if c.modeled
            )
            + ")",
        )
    return out


# ---------------------------------------------------------------------------
# frontier step sweep
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _variant_args(name: str, *, B: int, cand_parts: int, W: int, cap_g: int):
    """Shard-level trace avals for one frontier step variant (the operands
    after ``rows_local``)."""
    base = name[:-2] if name.endswith("2d") else name
    spec = _SPEC_1D[base]
    b = B // cand_parts if name.endswith("2d") else B
    if base.startswith("ganter"):
        b = cap_g
    shapes = {"bW": (b, W), "b": (b,), "W": (W,), "s": ()}
    return tuple(_sds(shapes[key], _DTYPES[dt]) for key, dt in spec)


def audit_step(label: str, step, args, *, W: int, n_attrs: int) -> list[Finding]:
    """Audit one cached engine step via its attached ``audit_spec``."""
    spec = getattr(step, "audit_spec", None)
    if spec is None:
        return [
            Finding(
                "spmd", "missing-audit-spec", label,
                "step carries no audit_spec — it bypassed "
                "ShardPlan.spmd/spmd_cand",
            )
        ]
    plan = spec["plan"]
    obj_axes = _norm_axes(plan.reduce_axes)
    cand_axes = _norm_axes(plan.cand_axes)
    axis_env = {a: None for a in obj_axes}
    for a in obj_axes:
        axis_env[a] = plan.n_parts  # single object axis on simulated plans
    if spec["kind"] == "spmd_cand":
        for a in cand_axes:
            axis_env[a] = plan.cand_parts
    else:
        cand_axes = ()
    batch = args[1].shape[0]  # args[0] is the rows/extent shard
    if spec["kind"] == "spmd_cand":
        expected = plan.modeled_round_bytes_cand(batch, W, n_attrs)
    else:
        expected = plan.modeled_reduce_bytes(batch, W, n_attrs)
    try:
        collectives, callbacks = trace_region(
            spec["shard_fn"], args, axis_env, W
        )
    except Exception as e:  # trace failure is itself a finding
        return [
            Finding(
                "spmd", "trace-failure", label,
                f"make_jaxpr failed: {type(e).__name__}: {e}",
            )
        ]
    return check_region(
        label,
        collectives,
        callbacks,
        obj_axes=obj_axes,
        cand_axes=cand_axes if spec["kind"] == "spmd_cand" else (),
        impl=plan.reduce_impl,
        n_parts=plan.n_parts,
        cand_parts=plan.cand_parts if spec["kind"] == "spmd_cand" else 1,
        expected_bytes=expected,
    )


GEOMETRIES = ((1, 1), (4, 1), (2, 4))
IMPLS = ("rsag", "allgather")


def _frontier_ctx(n_attrs: int = 40, n_objects: int = 24):
    from repro.core.context import FormalContext

    rng = np.random.default_rng(7)
    W = -(-n_attrs // 32)
    rows = rng.integers(0, 2**32, size=(n_objects, W), dtype=np.uint32)
    mask = np.full(W, 0xFFFFFFFF, np.uint32)
    tail = n_attrs % 32
    if tail:
        mask[-1] = (1 << tail) - 1
    return FormalContext(
        rows=rows & mask, n_objects=n_objects, n_attrs=n_attrs, attr_names=None
    )


def audit_frontier_steps(
    report,
    *,
    geometries=GEOMETRIES,
    impls=IMPLS,
    batch: int = 32,  # /cand_parts must stay a multiple of the kernels' 8-row block
) -> list[Finding]:
    """Trace every cached frontier step variant — jnp and fused-kernel
    twins — under each (n_parts x cand_parts) geometry and reduce impl."""
    from repro.core.engine import ClosureEngine
    from repro.core.frontier import DeviceFrontier
    from repro.dist.shardplan import ShardPlan
    from repro.kernels import frontier as fkern
    from repro.kernels.ops import bucket_size

    ctx = _frontier_ctx()
    findings = []
    backends = ["jnp"]
    if fkern.supports_fused("kernel", ctx.W):
        backends.append("kernel")
    for n_parts, cand_parts in geometries:
        for impl in impls:
            for backend in backends:
                plan = ShardPlan.simulated(
                    n_parts, cand_parts=cand_parts, reduce_impl=impl,
                    block_n=max(8, ctx.n_objects // max(1, n_parts)),
                )
                engine = ClosureEngine(ctx, plan=plan, backend=backend)
                frontier = DeviceFrontier(engine)
                cap_g = bucket_size(ctx.n_attrs, minimum=engine.min_bucket)
                rows_shard = _sds(engine.rows.shape[1:], jnp.uint32)
                for name in sorted(frontier._cache["builders"]):
                    label = (
                        f"{n_parts}x{cand_parts}/{impl}/{backend}/{name}"
                    )
                    step = frontier._step_fn(name)
                    args = (rows_shard,) + _variant_args(
                        name,
                        B=batch,
                        cand_parts=cand_parts if name.endswith("2d") else 1,
                        W=ctx.W,
                        cap_g=cap_g,
                    )
                    findings.extend(
                        audit_step(label, step, args, W=ctx.W, n_attrs=ctx.n_attrs)
                    )
                    report.note_checked("spmd", "frontier_steps")
    return findings


# ---------------------------------------------------------------------------
# query-engine batch steps + rules/basis device passes
# ---------------------------------------------------------------------------


def _tiny_store(n_parts: int, impl: str):
    """A real ConceptStore over a brute-force-mined 8-attribute context
    (shapes are all the auditor needs; tracing never executes)."""
    from repro.core.context import FormalContext
    from repro.dist.shardplan import ShardPlan
    from repro.query.store import ConceptStore

    rng = np.random.default_rng(11)
    n_attrs, n_objects = 8, 20
    dense = rng.integers(0, 2, size=(n_objects, n_attrs), dtype=np.uint8)
    rows = np.zeros((n_objects, 1), np.uint32)
    for a in range(n_attrs):
        rows[:, 0] |= dense[:, a].astype(np.uint32) << a
    ctx = FormalContext(
        rows=rows, n_objects=n_objects, n_attrs=n_attrs, attr_names=None
    )
    # brute-force intents: closure of every attribute subset
    intents = set()
    for m in range(1 << n_attrs):
        have = (rows[:, 0] & np.uint32(m)) == np.uint32(m)
        intent = np.uint32((1 << n_attrs) - 1)
        for r in rows[have, 0]:
            intent &= r
        intents.add(int(intent) if have.any() else (1 << n_attrs) - 1)
    intents = np.array(sorted(intents), np.uint32)[:, None]
    plan = ShardPlan.simulated(n_parts, reduce_impl=impl, block_n=8)
    return ConceptStore.build(ctx, intents, plan=plan)


def audit_query_steps(report, *, n_parts_list=(1, 4), impls=IMPLS) -> list[Finding]:
    from repro.query.engine import QueryEngine

    findings = []
    for n_parts in n_parts_list:
        for impl in impls:
            store = _tiny_store(n_parts, impl)
            qe = QueryEngine(store)
            st = store.state
            snap = st.snapshot
            S, W = qe.cfg.slots, qe.W
            rows_shard = _sds(st.rows.shape[1:], jnp.uint32)
            closure_args = (
                rows_shard,
                _sds((S, W), jnp.uint32),
                _sds((), jnp.int32),
                _sds(tuple(snap.intents.shape), jnp.uint32),
                _sds(tuple(snap.skeys.shape), snap.skeys.dtype),
                _sds((), jnp.int32),
            )
            for kind, step in (
                ("closure", qe._closure_step(impl, snap.probe)),
                ("topk", qe._topk_step(impl, 5)),
            ):
                label = f"{n_parts}x1/{impl}/query/{kind}"
                args = closure_args
                if kind == "topk":
                    args = closure_args[:4] + (
                        _sds(tuple(snap.supports.shape), snap.supports.dtype),
                        _sds((), jnp.int32),
                    )
                findings.extend(
                    audit_step(label, step, args, W=W, n_attrs=qe.n_attrs)
                )
                report.note_checked("spmd", "query_steps")

            # extents: the membership gather IS the modeled payload —
            # uint32 [Nl, S] words, one ring, charged k·(k-1)·Nl·S·4
            step = qe._extents_step()
            spec = getattr(step, "audit_spec", None)
            label = f"{n_parts}x1/{impl}/query/extents"
            if spec is None:
                findings.append(
                    Finding("spmd", "missing-audit-spec", label,
                            "extents step bypassed ShardPlan.spmd")
                )
            else:
                plan = spec["plan"]
                obj_axes = _norm_axes(plan.reduce_axes)
                n_local = st.N_padded // n_parts
                ext_shard = _sds(tuple(snap.ext_cols.shape[1:]), jnp.uint32)
                colls, cbs = trace_region(
                    spec["shard_fn"],
                    (ext_shard, _sds((S,), jnp.int32)),
                    {a: n_parts for a in obj_axes},
                    W=S,  # membership words: trailing dim is the id batch
                )
                findings.extend(
                    check_region(
                        label, colls, cbs,
                        obj_axes=obj_axes, cand_axes=(),
                        impl="allgather", n_parts=n_parts, cand_parts=1,
                        expected_bytes=(
                            n_parts * (n_parts - 1) * n_local * S * 4
                        ),
                        expect_obj_pattern=False,
                    )
                )
                report.note_checked("spmd", "query_steps")

            # rules step: replicated-table compute — a collective or a
            # callback appearing here would break snapshot consistency
            R = 8
            rules_args = (
                _sds((R, W), jnp.uint32), _sds((R, W), jnp.uint32),
                _sds((R,), jnp.float32), _sds((R,), jnp.float32),
                _sds((R,), jnp.int32), _sds((), jnp.int32),
                _sds((S, W), jnp.uint32), _sds((), jnp.float32),
            )
            colls, cbs = trace_region(
                qe._rules_step(5), rules_args, {}, W=W
            )
            label = f"{n_parts}x1/{impl}/query/rules"
            for c in colls:
                findings.append(
                    Finding("spmd", "collective-in-replicated-pass", label,
                            f"{c.prim} in the replicated rules pass")
                )
            for idx, name in cbs:
                findings.append(
                    Finding("spmd", "callback-in-region", label,
                            f"{name} equation at position {idx}")
                )
            report.note_checked("spmd", "query_steps")
    return findings


def audit_basis_passes(report) -> list[Finding]:
    """The rules/basis extraction device passes are replicated-table
    compute: assert no collectives and no callbacks sneak in."""
    from repro.rules import basis as basis_mod

    findings = []
    C, W = 8, 1
    X = _sds((4, W), jnp.uint32)
    fam = _sds((C, W), jnp.uint32)
    sup = _sds((C,), jnp.int32)
    sc = _sds((), jnp.int32)
    targets = [
        ("family_closure_jnp",
         basis_mod.family_closure_jnp, (X, fam, sc, _sds((W,), jnp.uint32))),
        ("family_support_jnp",
         basis_mod.family_support_jnp, (X, fam, sup, sc)),
        ("lclosure_jnp",
         basis_mod.lclosure_jnp, (X, fam, fam, sc)),
    ]
    for name, fn, args in targets:
        try:
            colls, cbs = trace_region(fn, args, {}, W=W)
        except Exception:
            continue  # signature drift: covered by the unit suites
        label = f"basis/{name}"
        for c in colls:
            findings.append(
                Finding("spmd", "collective-in-replicated-pass", label,
                        f"{c.prim} in replicated basis pass")
            )
        for idx, cb in cbs:
            findings.append(
                Finding("spmd", "callback-in-region", label,
                        f"{cb} equation at position {idx}")
            )
        report.note_checked("spmd", "basis_passes")
    return findings


def run(report, *, quick: bool = False) -> list[Finding]:
    """Full Pass-1 sweep; ``quick`` restricts to one geometry per shape
    class (used by the linter's own smoke tests, not the strict gate)."""
    geoms = ((1, 1), (2, 4)) if quick else GEOMETRIES
    findings = []
    findings += audit_frontier_steps(report, geometries=geoms)
    findings += audit_query_steps(
        report, n_parts_list=(2,) if quick else (1, 4)
    )
    findings += audit_basis_passes(report)
    return findings
