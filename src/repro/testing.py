"""Deterministic fallback for the ``hypothesis`` property-testing API.

The test-suite's property tests are written against ``hypothesis`` (``given``
/ ``settings`` / ``strategies``).  On clean environments without it, this
module provides a drop-in subset: strategies become seeded-numpy samplers
and ``@given`` runs the test body ``max_examples`` times with a
deterministic per-example rng — same invariants exercised, reproducible
failures, zero dependencies.

Usage (in tests)::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from repro.testing import given, settings, st
"""

from __future__ import annotations

import zlib

import numpy as np


class Strategy:
    """A sampler: ``fn(rng) -> value``."""

    def __init__(self, fn):
        self.fn = fn

    def sample(self, rng: np.random.Generator):
        return self.fn(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def lists(elem: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.sample(rng) for _ in range(n)]

        return Strategy(draw)

    @staticmethod
    def composite(f):
        """``@st.composite``: ``f(draw, **kw)`` → strategy factory."""

        def factory(*args, **kwargs):
            return Strategy(
                lambda rng: f(lambda s: s.sample(rng), *args, **kwargs)
            )

        return factory


st = _Strategies()


class settings:  # noqa: N801 — mirrors the hypothesis API
    _profiles: dict[str, dict] = {}
    _active: dict = {"max_examples": 20}

    def __init__(self, **kwargs):
        self._kwargs = kwargs

    def __call__(self, fn):  # @settings(...) decorator form
        fn._repro_settings = self._kwargs
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str):
        cls._active = dict(cls._profiles.get(name, {}))
        cls._active.setdefault("max_examples", 20)


def given(*strategies: Strategy):
    def decorate(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the property's drawn parameters (it would treat them as
        # fixtures).
        def wrapper():
            n = int(
                getattr(fn, "_repro_settings", {}).get("max_examples", 0)
                or settings._active.get("max_examples", 20)
            )
            # crc32, not hash(): PYTHONHASHSEED randomizes str hashes per
            # process, which would make the printed failure seed useless.
            seed0 = zlib.crc32(fn.__qualname__.encode()) % (2**31)
            for i in range(n):
                rng = np.random.default_rng([seed0, i])
                drawn = [s.sample(rng) for s in strategies]
                try:
                    fn(*drawn)
                except Exception as e:  # annotate the failing example
                    raise AssertionError(
                        f"property falsified on example {i} "
                        f"(seed [{seed0}, {i}]): {drawn!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate
