"""Abstract input specs (ShapeDtypeStruct stand-ins) for every cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
``train_step`` / ``prefill_step`` / ``decode_step`` against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training batch: tokens (or stub embeddings) + next-token labels."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeds":
        inputs = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = _sds((B, S), jnp.int32)
    out = {"inputs": inputs, "labels": _sds((B, S), jnp.int32)}
    if cfg.rope_kind == "mrope":
        out["positions"] = _sds((3, B, S), jnp.int32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """One-step decode: single token per slot + KV/state caches at S_max."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeds":
        inputs = _sds((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = _sds((B, 1), jnp.int32)
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, B, S))
    out = {"inputs": inputs, "t": _sds((), jnp.int32), "caches": caches}
    if cfg.rope_kind == "mrope":
        out["positions"] = _sds((3, B, 1), jnp.int32)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeds":
        inputs = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = _sds((B, S), jnp.int32)
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, B, S))
    out = {"inputs": inputs, "caches": caches}
    if cfg.rope_kind == "mrope":
        out["positions"] = _sds((3, B, S), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Dispatch on the shape's kind (train | prefill | decode)."""
    if shape.kind == "train":
        return batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)
