"""LM data pipeline: deterministic synthetic token streams.

Offline container ⇒ corpora are synthesized, but the pipeline has the real
shape: deterministic per-step batches (seeded, so a restarted run resumes
bit-identically mid-epoch — required for checkpoint/restart equivalence
tests), next-token labels, and device placement with DP sharding.

The generator is a Zipf-distributed Markov chain rather than IID noise so
that a ~100M-param model has actual structure to learn (the end-to-end
example shows loss dropping well below the unigram entropy floor).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 32  # Markov successors per state


class SyntheticLM:
    """Deterministic, seekable synthetic corpus."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Each token has `branching` plausible successors with Zipf weights.
        self._succ = rng.integers(0, V, size=(V, cfg.branching), dtype=np.int32)
        w = 1.0 / np.arange(1, cfg.branching + 1)
        self._w = w / w.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Batch for a given step — pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
        choices = rng.choice(cfg.branching, size=(B, S), p=self._w)
        for t in range(S):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iterator(model_cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                        start_step: int = 0):
    """Step-indexed iterator, resumable from any step."""
    data = SyntheticLM(
        LMDataConfig(
            vocab_size=model_cfg.vocab_size,
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            seed=seed,
        )
    )
    step = start_step
    while True:
        b = data.batch(step)
        if model_cfg.input_mode == "embeds":
            # Modality stub: hash tokens into deterministic embeddings.
            rng = np.random.default_rng((seed, step, 1))
            b["inputs"] = rng.standard_normal(
                (shape.global_batch, shape.seq_len, model_cfg.d_model)
            ).astype(np.float32)
        if model_cfg.rope_kind == "mrope":
            pos = np.broadcast_to(
                np.arange(shape.seq_len, dtype=np.int32),
                (3, shape.global_batch, shape.seq_len),
            )
            b["positions"] = np.ascontiguousarray(pos)
        yield step, b
        step += 1
