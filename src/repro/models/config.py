"""Model / shape / mesh configuration dataclasses for the assigned archs.

Every architecture is expressed as a ``ModelConfig``; heterogeneous layer
stacks (gemma2 local/global alternation, griffin's rec-rec-attn pattern) are
encoded as a repeating ``layer_pattern`` so the transformer stack can
``lax.scan`` over *super-blocks* (one pattern period each) — compact HLO and
fast 512-device compiles, with any non-divisible tail unrolled.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: dense FFN residual ∥ MoE
    shared_expert: bool = False  # llama4: always-on shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 128
    conv_width: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    lru_width: int | None = None  # defaults to d_model
    conv_width: int = 4
    attn_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # Attention flavour.
    rope_kind: str = "standard"  # none | standard | mrope
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_window: int | None = None  # for *_local layers
    layer_pattern: tuple[str, ...] = ("attn",)  # attn|attn_local|attn_global|rec|ssd
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    qkv_bias: bool = False
    # FFN / norms.
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    post_norm: bool = False  # gemma2: post-attn/post-ffn norms
    emb_scale: bool = False  # gemma: embeddings × sqrt(d_model)
    tie_embeddings: bool = False
    # Sub-configs.
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    griffin: GriffinConfig | None = None
    # Modality stubs (vlm/audio): the backbone consumes precomputed
    # frame/patch embeddings instead of token ids (assignment rules).
    input_mode: str = "tokens"  # tokens | embeds
    # Sub-quadratic decode: eligible for the long_500k shape.
    subquadratic: bool = False
    dtype: str = "bfloat16"

    # -- derived ------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def tail_pattern(self) -> tuple[str, ...]:
        """Layers left over when n_layers % period != 0 (unrolled)."""
        return self.layer_pattern[: self.n_layers % self.period]

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        gated = self.mlp_kind in ("swiglu", "geglu")
        mlp_dense = d * self.d_ff * (3 if gated else 2)
        total = 0
        for kind in self.layer_pattern * self.n_periods + self.tail_pattern:
            if kind.startswith("attn"):
                total += attn + mlp_dense
            elif kind == "rec":
                g = self.griffin
                w = g.lru_width or d
                total += 2 * d * w + w * d + w * g.conv_width + 3 * w + mlp_dense
            elif kind == "ssd":
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.state_size
                total += (
                    d * (2 * d_in + 2 * s.n_groups * s.state_size + nh)
                    + conv_dim * s.conv_width
                    + d_in * d
                )
        if self.moe is not None:
            e = self.moe
            moe_mlp = e.n_experts * d * e.d_ff_expert * 3 + d * e.n_experts
            if e.shared_expert:
                moe_mlp += d * e.d_ff_expert * 3
            per_layer_dense = mlp_dense if self.moe.dense_residual else 0
            # replace the dense MLP accounted above with MoE (+ optional dense)
            total += self.n_layers * (moe_mlp + per_layer_dense - mlp_dense)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        inactive_experts = e.n_experts - e.top_k
        return full - self.n_layers * inactive_experts * self.d_model * e.d_ff_expert * 3

    # -- reduced config for CPU smoke tests ----------------------------------

    def reduced(self) -> "ModelConfig":
        """Same family/pattern, tiny dims — runs a real step on CPU."""
        changes: dict = dict(
            # 2 full periods + the original tail remainder, so the smoke
            # test exercises both the scanned and unrolled paths.
            n_layers=2 * self.period + (self.n_layers % self.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_size=16, head_dim=16, chunk_size=32
            )
        if self.griffin is not None:
            changes["griffin"] = dataclasses.replace(
                self.griffin, lru_width=64, attn_window=32
            )
        if self.attn_window is not None:
            changes["attn_window"] = 32
        if self.rope_kind == "mrope":
            changes["mrope_sections"] = (2, 3, 3)  # sums to head_dim/2 = 8
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (skip, see DESIGN.md)"
    return True, ""
