"""``python -m repro.obs TRACE.json [--expect-async-overlap]`` — validate
a saved trace (same CLI as ``repro.obs.trace``, minus the runpy
double-import warning that ``-m repro.obs.trace`` triggers)."""

import sys

from repro.obs.trace import main

if __name__ == "__main__":
    sys.exit(main())
