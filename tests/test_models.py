"""Per-arch smoke tests (assignment deliverable f): reduced configs run a
real forward + train step on CPU; shapes and finiteness asserted.  Decode
consistency vs the full forward is asserted for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer
from repro.models.config import SHAPES, shape_applicable


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "embeds":
        inputs = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
        )
    else:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {
        "inputs": inputs,
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.rope_kind == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params, axes = transformer.init_params(cfg, seed=0)
    batch = _batch(cfg)
    loss, metrics = transformer.train_loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one grad step moves the loss
    g = jax.grad(lambda p: transformer.train_loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0, arch
    new_params = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)
    loss2, _ = transformer.train_loss_fn(new_params, cfg, batch)
    assert float(loss2) < float(loss), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_hidden_shapes(arch):
    cfg = get_config(arch).reduced()
    params, _ = transformer.init_params(cfg, seed=0)
    batch = _batch(cfg)
    hidden, caches, aux = transformer.forward_hidden(
        params, cfg, batch["inputs"], mode="train",
        rope_positions=batch.get("positions"),
    )
    B, S = 2, 32
    assert hidden.shape == (B, S, cfg.d_model)
    assert caches is None
    logits = transformer.logits_for(params, cfg, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # no-drop capacity so full fwd is exact too
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params, _ = transformer.init_params(cfg, seed=0)
    B, S, T = 2, 24, 16
    batch = _batch(cfg, B=B, S=S, seed=1)
    seq = batch["inputs"]

    kw = {"rope_positions": batch.get("positions")}
    hidden, _, _ = transformer.forward_hidden(params, cfg, seq, mode="train", **kw)
    full_logits = transformer.logits_for(params, cfg, hidden)

    caches = transformer.init_caches(cfg, B, S)
    pre = seq[:, :T]
    kwp = {}
    if cfg.rope_kind == "mrope":
        kwp["rope_positions"] = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (3, B, T))
    logits_T, caches = transformer.prefill(params, cfg, pre, caches, **kwp)
    errs = [float(jnp.max(jnp.abs(logits_T[:, 0] - full_logits[:, T - 1])))]
    for t in range(T, S):
        tok = seq[:, t : t + 1]
        kwd = {}
        if cfg.rope_kind == "mrope":
            kwd["rope_positions"] = jnp.full((3, B, 1), t, jnp.int32)
        logits_t, caches = transformer.decode_step(params, cfg, tok, t, caches, **kwd)
        errs.append(float(jnp.max(jnp.abs(logits_t[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-3, (arch, max(errs))


def test_all_cells_applicability():
    """40 cells: long_500k only for the two sub-quadratic archs."""
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert skipped == {
        (a, "long_500k")
        for a in ARCH_IDS
        if a not in ("recurrentgemma-2b", "mamba2-370m")
    }


def test_param_counts_in_expected_range():
    """Full configs' param counts land near their nameplate sizes."""
    expect = {
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "starcoder2-7b": (6e9, 8.5e9),
        "gemma2-9b": (8e9, 11e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "arctic-480b": (430e9, 520e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),  # total (not active) params
        "musicgen-large": (1.5e9, 2.8e9),
        "mamba2-370m": (3e8, 4.6e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
