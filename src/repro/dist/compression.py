"""Compressed data-parallel training: int8-quantized gradient exchange
with error feedback (1-bit-Adam-style residual accumulation).

Each data shard computes its local gradient, adds the carried quantization
residual, quantizes to int8 (per-leaf absmax scale), and the *dequantized*
grads are psum-averaged — modeling an 8-bit wire format at 4× bandwidth
reduction.  The residual keeps long-run updates unbiased, so convergence
matches uncompressed SGD to float precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat

_LEVELS = 127.0


def _quantize(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int8 round-to-nearest with per-array absmax scale; returns
    (dequantized value, residual)."""
    scale = jnp.max(jnp.abs(v)) / _LEVELS
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(v / scale), -_LEVELS, _LEVELS)
    deq = q * scale
    return deq, v - deq


def make_ddp_step(value_and_grad_fn, mesh, *, lr: float, axis_name: str = "data"):
    """Build ``(step, init_err)`` for compressed DDP-SGD.

    value_and_grad_fn: ``(params, batch) -> (loss, grads)`` on a local
                       batch shard (losses are per-shard means).
    step:              ``(params, err, batch) -> (params, err, loss)``;
                       ``err`` is the per-shard residual state,
                       ``[k, ...]``-stacked and sharded over ``axis_name``.
    """
    k = mesh.shape[axis_name]

    def init_err(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros((k,) + p.shape, jnp.float32), params
        )

    def body(params, err, batch):
        loss, grads = value_and_grad_fn(params, batch)
        acc = jax.tree_util.tree_map(
            lambda g, e: g.astype(jnp.float32) + e[0], grads, err
        )
        pairs = jax.tree_util.tree_map(_quantize, acc)
        deq = jax.tree_util.tree_map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(lambda pr: pr[1][None], pairs, is_leaf=lambda x: isinstance(x, tuple))
        g_global = jax.tree_util.tree_map(
            lambda d: lax.psum(d, axis_name) / k, deq
        )
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - lr * g.astype(p.dtype), params, g_global
        )
        return new_params, new_err, lax.psum(loss, axis_name) / k

    smapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(axis_name), P(axis_name)),
        out_specs=(P(), P(axis_name), P()),
        check_vma=False,
    )

    @jax.jit
    def step(params, err, batch):
        return smapped(params, err, batch)

    return step, init_err
