"""Pallas closure-kernel micro-bench (interpret mode on CPU) vs oracles.

Wall times here are *not* TPU projections (interpret mode runs the kernel
body in Python/XLA-CPU); the point is the work-per-call census used in the
§Roofline discussion plus regression tracking of the jnp reference path.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import ClosureEngine, FormalContext, mrcbo, mrganter_plus
from repro.core.closure import batched_closure_np
from repro.core.engine import EngineStats
from repro.data import fca_datasets
from repro.kernels import ops


def run(shapes=((2048, 128, 256), (8192, 512, 64))) -> list[str]:
    out = []
    for N, m, B in shapes:
        ctx = FormalContext.synthetic(N, m, 0.15, seed=1)
        cands = FormalContext.synthetic(B, m, 0.05, seed=2).rows
        rows_p, _ = ctx.padded_rows(256)
        rows_j, cands_j = jnp.asarray(rows_p), jnp.asarray(cands)

        # warm + time the jnp reference path (jit, no pallas)
        f_ref = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=False
        )[0].block_until_ready()
        f_ref()
        _, t_ref = timed(f_ref)

        # numpy oracle
        _, t_np = timed(batched_closure_np, ctx.rows, cands, ctx.attr_mask())

        # pallas interpret (correctness-path cost only)
        f_k = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=True
        )[0].block_until_ready()
        f_k()
        _, t_k = timed(f_k)

        work = B * N * ops.bucket_size(1)  # word-ops order of magnitude
        out.append(row(
            f"kernel/closure/N={N},m={m},B={B}/jnp_ref", 1e6 * t_ref,
            f"numpy_us={1e6 * t_np:.0f}|pallas_interpret_us={1e6 * t_k:.0f}"
            f"|BNW={B * N * (m // 32 + 1)}",
        ))
    return out


# ---------------------------------------------------------------------------
# Frontier pipeline: host-loop vs device-resident drivers (EXPERIMENTS §Perf)
# ---------------------------------------------------------------------------


def _timed_driver(ctx, algo, *, n_parts, backend, pipeline, **kw):
    """Warm-run protocol: build the engine, run once to populate every jit
    cache (the engine's sharded step is per-instance), reset the stats
    ledger, then time the steady-state run."""
    eng = ClosureEngine(ctx, n_parts=n_parts, backend=backend)
    algo(ctx, eng, pipeline=pipeline, **kw)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = algo(ctx, eng, pipeline=pipeline, **kw)
    wall = time.perf_counter() - t0
    st = eng.stats
    it = max(1, res.n_iterations - 1)  # expansion rounds
    return {
        "algorithm": res.algorithm,
        "pipeline": pipeline,
        "backend": backend,
        "options": {k: v for k, v in kw.items()},
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "closures_computed": st.closures_computed,
        "h2d_transfers_per_iter": round(st.h2d_transfers / it, 2),
        "d2h_transfers_per_iter": round(st.d2h_transfers / it, 2),
        "h2d_bytes": st.h2d_bytes,
        "d2h_bytes": st.d2h_bytes,
        "modeled_comm_bytes": st.modeled_comm_bytes,
    }


def run_frontier(
    dataset: str = "census-income",
    scale: float = 0.002,
    n_parts: int = 4,
    out_path: str = "BENCH_frontier.json",
) -> list[str]:
    """Host-loop vs device-resident frontier pipeline on the largest
    bundled dataset (Table 7), simulated multi-part engine.

    The headline record is paper-faithful MRGanter+ (host loop, no dedupe)
    against the production device pipeline (on-device seed dedupe) — the
    acceptance bar is ≥2× end-to-end.  A backend sweep (kernel/jnp/matmul)
    runs on a reduced slice since Pallas interpret mode is a correctness
    tool, not a wall-clock one.
    """
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)
    records = []
    grid = [
        (mrganter_plus, "host", "jnp", {}),
        (mrganter_plus, "host", "jnp", {"dedupe_candidates": True}),
        (mrganter_plus, "device", "jnp", {"dedupe_candidates": True}),
        (mrganter_plus, "device", "jnp",
         {"dedupe_candidates": True, "dedupe_closures": True}),
        (mrcbo, "host", "jnp", {}),
        (mrcbo, "device", "jnp", {}),
    ]
    for algo, pipeline, backend, kw in grid:
        records.append(
            _timed_driver(
                ctx, algo, n_parts=n_parts, backend=backend,
                pipeline=pipeline, **kw,
            )
        )

    # backend sweep on a reduced slice (kernel = interpret mode on CPU)
    ctx_s, spec_s = fca_datasets.load(dataset, scale=scale / 4, seed=0)
    sweep = []
    for backend in ("kernel", "jnp", "matmul"):
        sweep.append(
            _timed_driver(
                ctx_s, mrganter_plus, n_parts=n_parts, backend=backend,
                pipeline="device", dedupe_candidates=True,
            )
        )

    base = next(
        r for r in records
        if r["pipeline"] == "host" and r["algorithm"] == "mrganter+"
        and not r["options"]
    )
    best = min(
        (r for r in records
         if r["pipeline"] == "device" and r["algorithm"] == "mrganter+"),
        key=lambda r: r["wall_time_s"],
    )
    speedup = base["wall_time_s"] / best["wall_time_s"]
    payload = {
        "dataset": dataclasses.asdict(spec),
        "n_parts": n_parts,
        "records": records,
        "backend_sweep": {
            "dataset": dataclasses.asdict(spec_s),
            "records": sweep,
        },
        "headline": {
            "baseline": "mrganter+ host-loop (paper-faithful)",
            "candidate": "mrganter+ device pipeline",
            "speedup_x": round(speedup, 2),
            "h2d_bytes_ratio": round(
                base["h2d_bytes"] / max(1, best["h2d_bytes"]), 1
            ),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = []
    for r in records + sweep:
        name = (
            f"frontier/{r['algorithm']}/{r['pipeline']}/{r['backend']}"
            + ("+dc" if r["options"].get("dedupe_candidates") else "")
            + ("+dz" if r["options"].get("dedupe_closures") else "")
        )
        out.append(row(
            name, 1e6 * r["wall_time_s"],
            f"concepts={r['n_concepts']}|closures={r['closures_computed']}"
            f"|h2d_B={r['h2d_bytes']}|d2h_B={r['d2h_bytes']}",
        ))
    out.append(row(
        "frontier/headline_speedup", speedup,
        f"devices_beat_host_x{speedup:.2f}|json={out_path}",
    ))
    return out
