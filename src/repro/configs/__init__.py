"""Config registry: 10 assigned architectures + the paper's FCA datasets.

``get_config(name)`` returns the full-size ModelConfig; ``--arch`` ids use
the assignment spelling (dots/dashes), module names use underscores.
``ArchPlan`` carries per-arch deployment choices (FSDP, optimizer) used by
the launcher and the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_ARCH_MODULES = {
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma2-9b": "gemma2_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "arctic-480b": "arctic_480b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "musicgen-large": "musicgen_large",
    "mamba2-370m": "mamba2_370m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ArchPlan:
    """Deployment plan: how this arch is sharded/optimized at scale."""

    fsdp: bool  # shard params' d_model dims over 'data' (ZeRO-3 style)
    optimizer: str  # adamw | adafactor


# Models ≳30B parameters need FSDP + factored optimizer state to fit v5e HBM.
_PLANS = {
    "codeqwen1.5-7b": ArchPlan(fsdp=False, optimizer="adamw"),
    "starcoder2-7b": ArchPlan(fsdp=False, optimizer="adamw"),
    "gemma2-9b": ArchPlan(fsdp=False, optimizer="adamw"),
    "deepseek-coder-33b": ArchPlan(fsdp=True, optimizer="adamw"),
    "qwen2-vl-72b": ArchPlan(fsdp=True, optimizer="adafactor"),
    "recurrentgemma-2b": ArchPlan(fsdp=False, optimizer="adamw"),
    "arctic-480b": ArchPlan(fsdp=True, optimizer="adafactor"),
    "llama4-scout-17b-a16e": ArchPlan(fsdp=True, optimizer="adamw"),
    "musicgen-large": ArchPlan(fsdp=False, optimizer="adamw"),
    "mamba2-370m": ArchPlan(fsdp=False, optimizer="adamw"),
}


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise ValueError(f"unknown arch {name!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_plan(name: str) -> ArchPlan:
    return _PLANS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) cell with its applicability verdict."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ArchPlan",
    "all_cells",
    "get_config",
    "get_plan",
    "get_shape",
]
