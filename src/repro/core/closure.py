"""Derivation and closure operators over packed bitset contexts.

Two interchangeable backends:
  * numpy  — host-side, used by the centralized baselines (NextClosure,
             CloseByOne) and as the ultimate oracle in tests;
  * jnp    — device-side, jit-able, used by the distributed MR* engines and
             mirrored by the Pallas kernel (``repro.kernels``).

All functions share the padding discipline documented in
``repro.core.context.FormalContext.padded_rows``: padded object rows are
all-ones (AND-identity; they match every candidate, so supports are corrected
by the pad count), and results are masked with ``attr_mask`` so padded
attribute bits never leak.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset

# ---------------------------------------------------------------------------
# numpy backend (host / oracle)
# ---------------------------------------------------------------------------


def extent_np(rows: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """``Y' `` — bool mask over objects whose row contains ``cand``. [N]"""
    return np.all((rows & cand) == cand, axis=-1)


def closure_np(
    rows: np.ndarray, cand: np.ndarray, attr_mask: np.ndarray
) -> tuple[np.ndarray, int]:
    """``Y''`` and ``|Y'|`` for a single packed candidate ``[W]``."""
    match = extent_np(rows, cand)
    sel = rows[match]
    if sel.shape[0] == 0:
        return attr_mask.copy(), 0
    return np.bitwise_and.reduce(sel, axis=0) & attr_mask, int(match.sum())


def batched_closure_np(
    rows: np.ndarray, cands: np.ndarray, attr_mask: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``Y''`` / supports.  rows [N,W], cands [B,W] → ([B,W], [B]).

    Memory O(B·N·W); chunk over B for very large batches.
    """
    out_c = np.empty_like(cands)
    out_s = np.empty(cands.shape[0], dtype=np.int64)
    # Chunk to bound the [b, N, W] intermediate at ~64 MB.
    nw = max(1, rows.shape[0] * rows.shape[1])
    chunk = max(1, int(16e6 // nw))
    full = np.uint32(0xFFFFFFFF)
    for lo in range(0, cands.shape[0], chunk):
        c = cands[lo : lo + chunk]
        match = np.all((rows[None, :, :] & c[:, None, :]) == c[:, None, :], axis=-1)
        sel = np.where(match[:, :, None], rows[None, :, :], full)
        out_c[lo : lo + chunk] = np.bitwise_and.reduce(sel, axis=1) & attr_mask
        out_s[lo : lo + chunk] = match.sum(axis=1)
    return out_c, out_s


def intent_of_extent_np(
    rows: np.ndarray, extent: np.ndarray, attr_mask: np.ndarray
) -> np.ndarray:
    """``X'`` — intent of a bool object mask ``[N]``."""
    sel = rows[extent]
    if sel.shape[0] == 0:
        return attr_mask.copy()
    return np.bitwise_and.reduce(sel, axis=0) & attr_mask


# ---------------------------------------------------------------------------
# jnp backend (device)
# ---------------------------------------------------------------------------


def extent_jnp(rows: jax.Array, cand: jax.Array) -> jax.Array:
    return jnp.all((rows & cand) == cand, axis=-1)


def batched_closure_jnp(
    rows: jax.Array, cands: jax.Array, attr_mask: jax.Array,
    fused_reduce: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp batched closure — the reference the Pallas kernel must match.

    rows [N, W] uint32 (padded rows all-ones), cands [B, W] uint32.
    Returns (closures [B, W] uint32, raw supports [B] int32 — *including*
    all-ones padding rows; callers subtract the pad count).

    ``fused_reduce=True`` (§Perf, beyond-paper): express the AND-reduction
    as ``lax.reduce`` with a bitwise-AND monoid, so XLA input-fuses the
    select and the [B, N, W] intermediate never reaches HBM.  ``False`` is
    the naive materialize-then-tree-reduce baseline (EXPERIMENTS.md §Perf).
    """
    rows = rows.astype(jnp.uint32)
    cands = cands.astype(jnp.uint32)
    match = jnp.all(
        (rows[None, :, :] & cands[:, None, :]) == cands[:, None, :], axis=-1
    )  # [B, N]
    full = jnp.uint32(0xFFFFFFFF)
    sel = jnp.where(match[:, :, None], rows[None, :, :], full)  # [B, N, W]
    if fused_reduce:
        closures = jax.lax.reduce(
            sel, full, lambda a, b: jax.lax.bitwise_and(a, b), dimensions=(1,)
        ) & attr_mask
    else:
        # AND-reduce over objects via a log2 tree of full-width vector ANDs.
        closures = _and_reduce(sel, axis=1) & attr_mask
    supports = match.sum(axis=-1, dtype=jnp.int32)
    return closures, supports


def _and_reduce(x: jax.Array, axis: int) -> jax.Array:
    """Bitwise-AND reduction along ``axis`` (log-tree; works for any length)."""
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    while n > 1:
        half = n // 2
        head = x[: 2 * half]
        x = jnp.concatenate(
            [head[0::2] & head[1::2], x[2 * half : n]], axis=0
        )
        n = x.shape[0]
    return x[0]


def closure_properties_hold(
    rows: np.ndarray, y: np.ndarray, attr_mask: np.ndarray
) -> bool:
    """Check extensive/idempotent for one candidate (test helper)."""
    c1, _ = closure_np(rows, y & attr_mask, attr_mask)
    c2, _ = closure_np(rows, c1, attr_mask)
    extensive = bool(np.all((y & attr_mask) & ~c1 == 0))
    idempotent = bool(np.array_equal(c1, c2))
    return extensive and idempotent
