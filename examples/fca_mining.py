"""Distributed concept mining on a Table-7-matched dataset (paper §5).

    PYTHONPATH=src python examples/fca_mining.py --dataset mushroom --scale 0.03

Runs MRGanter+ across a sweep of partition counts (the paper's Figs 2–4
x-axis) and reports rounds, wall time, and modeled reduce-phase traffic for
the three collective schedules.
"""

import argparse
import time

from repro.core import ClosureEngine, all_closures_batched, bitset, mrganter_plus
from repro.data import fca_datasets


def main(dataset="mushroom", scale=0.03, parts=(1, 2, 4, 8)):
    ctx, spec = fca_datasets.load(dataset, scale=scale)
    print(f"{dataset}: {spec.n_objects} objects × {spec.n_attrs} attrs "
          f"@ {spec.density:.3f} density (scale={scale}, "
          f"{'synthetic' if spec.synthetic else 'real UCI'})")

    t0 = time.perf_counter()
    ref = all_closures_batched(ctx)
    print(f"NextClosure (centralized): {len(ref)} concepts "
          f"in {time.perf_counter() - t0:.2f}s")

    for k in parts:
        for impl in ("allgather", "rsag"):
            eng = ClosureEngine(ctx, n_parts=k, reduce_impl=impl)
            t0 = time.perf_counter()
            res = mrganter_plus(ctx, eng, dedupe_candidates=True)
            dt = time.perf_counter() - t0
            ok = {bitset.key_bytes(y) for y in res.intents} == {
                bitset.key_bytes(y) for y in ref
            }
            print(f"MRGanter+ parts={k} reduce={impl:9s}: "
                  f"{res.n_iterations:2d} rounds, {dt:5.2f}s, "
                  f"comm={res.modeled_comm_bytes / 1e6:7.2f} MB, match={ok}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="mushroom",
                   choices=list(fca_datasets.PAPER_DATASETS))
    p.add_argument("--scale", type=float, default=0.03)
    a = p.parse_args()
    main(dataset=a.dataset, scale=a.scale)
