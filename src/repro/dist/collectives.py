"""Bitwise-AND all-reduce — the paper's reduce phase (Theorem 2) as a
device collective, in three interchangeable implementations:

  * ``allgather`` — every shard all-gathers the full [B, W] local-closure
    block and AND-folds locally.  One hop, k·B·W words on the wire per
    device; the baseline reduce.
  * ``rsag``      — reduce-scatter + all-gather: shards exchange 1/k-sized
    batch chunks (all_to_all), AND-fold their owned chunk, then all-gather
    the folded chunks.  2·(k-1)/k·B·W words per device — the bandwidth-
    optimal ring schedule, same arithmetic, bit-identical output.
  * ``pmin``      — unpack words to attribute lanes and ``lax.pmin``:
    AND of {0,1} bits == elementwise min.  Exercises the scalar-collective
    path (useful on interconnects with native min/max reductions); costs
    32× the wire bytes of the packed impls unless ``n_attrs`` is passed to
    bound the unpacked width.

All three are monoid reductions over the AND semigroup, so the results are
bit-identical regardless of shard count or schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

IMPLS = ("allgather", "rsag", "pmin")


def _and_fold(x: jax.Array) -> jax.Array:
    """AND-fold over the leading axis via a log2 tree (static shapes)."""
    n = x.shape[0]
    while n > 1:
        half = n // 2
        head = x[: 2 * half]
        x = jnp.concatenate([head[0::2] & head[1::2], x[2 * half :]], axis=0)
        n = x.shape[0]
    return x[0]


def _axis_size(axis_names) -> int:
    from jax import core as jax_core

    names = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    k = 1
    for a in names:
        frame = jax_core.axis_frame(a)
        k *= frame if isinstance(frame, int) else frame.size
    return k


def and_allreduce(
    x: jax.Array,
    axis_names,
    *,
    impl: str = "rsag",
    n_attrs: int | None = None,
) -> jax.Array:
    """Global bitwise-AND of ``x [B, W]`` across ``axis_names`` shards.

    Must be called inside ``shard_map``; returns the same value on every
    shard.  ``n_attrs`` (optional) bounds the unpacked width of the
    ``pmin`` impl to the real attribute count.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown reduce impl {impl!r}; choose {IMPLS}")
    k = _axis_size(axis_names)
    if k == 1:
        return x

    if impl == "allgather":
        g = lax.all_gather(x, axis_names)  # [k, B, W]
        return _and_fold(g.reshape(k, *x.shape))

    if impl == "rsag":
        B, W = x.shape
        pad = -B % k
        if pad:
            x = jnp.concatenate(
                [x, jnp.full((pad, W), 0xFFFFFFFF, dtype=x.dtype)], axis=0
            )
        chunks = x.reshape(k, (B + pad) // k, W)
        # reduce-scatter: shard i receives every shard's chunk i …
        recv = lax.all_to_all(chunks, axis_names, split_axis=0, concat_axis=0)
        recv = recv.reshape(k, (B + pad) // k, W)
        owned = _and_fold(recv)  # [B/k, W] — globally-reduced chunk
        # … all-gather the folded chunks back to the full batch.
        full = lax.all_gather(owned, axis_names).reshape(B + pad, W)
        return full[:B]

    # pmin: AND of bits == min of bits, one lane per attribute.
    W = x.shape[-1]
    m = n_attrs if n_attrs is not None else W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((x[..., None] >> shifts) & jnp.uint32(1)).reshape(*x.shape[:-1], W * 32)
    bits = lax.pmin(bits[..., :m], axis_names)
    pad = W * 32 - m
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], pad), bits.dtype)], axis=-1
        )
    weights = (jnp.uint32(1) << shifts).astype(jnp.uint32)
    return (
        bits.reshape(*x.shape[:-1], W, 32).astype(jnp.uint32) * weights
    ).sum(axis=-1, dtype=jnp.uint32)


def modeled_comm_bytes(
    impl: str, n_parts: int, batch: int, W: int, n_attrs: int | None = None
) -> int:
    """Analytic wire bytes for one reduce round over all ``n_parts`` shards.

    Used for the paper's communication-cost accounting (Table 8 discussion)
    and by the dry-run/benchmarks; the simulated engine charges this model
    since nothing actually crosses a network on one device.  ``n_attrs``
    bounds the pmin lane count exactly as it bounds the implementation
    (without it the full ``W·32`` unpacked width is charged).
    """
    if n_parts <= 1:
        return 0
    word_bytes = batch * W * 4
    if impl == "allgather":
        return n_parts * (n_parts - 1) * word_bytes
    if impl == "rsag":
        return int(2 * (n_parts - 1) * word_bytes)  # ring RS + AG, summed
    if impl == "pmin":
        # one uint32 per unpacked attribute lane — what lax.pmin actually
        # exchanges (32× the packed impls when unbounded)
        lanes = n_attrs if n_attrs is not None else W * 32
        return n_parts * (n_parts - 1) * batch * lanes * 4
    raise ValueError(f"unknown reduce impl {impl!r}; choose {IMPLS}")


def ring_steps(impl: str, n_parts: int) -> int:
    """Per-device ring-step (latency hop) count for one reduce round.

    ``allgather``/``pmin`` are one ring pass (k-1 steps); ``rsag`` pays two
    passes (reduce-scatter then all-gather, 2(k-1) steps) for its lower
    wire-byte volume — the classic latency/bandwidth trade the schedule
    autotuner arbitrates.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown reduce impl {impl!r}; choose {IMPLS}")
    if n_parts <= 1:
        return 0
    k = n_parts
    return 2 * (k - 1) if impl == "rsag" else k - 1


def modeled_cost_bytes(
    impl: str,
    n_parts: int,
    batch: int,
    W: int,
    n_attrs: int | None = None,
    *,
    hop_bytes: int = 4096,
) -> int:
    """α-β reduce-cost model in byte units: wire volume + per-hop latency.

    ``hop_bytes`` is the latency term α expressed as its bandwidth-
    equivalent byte cost per ring step per device.  Small batches are
    latency-bound (allgather's single pass wins); large batches are
    bandwidth-bound (rsag's 2(k-1)/k volume wins).  This is what
    ``ShardPlan.resolve_impl`` minimizes for ``reduce_impl="auto"``.
    """
    if n_parts <= 1:
        return 0
    return modeled_comm_bytes(impl, n_parts, batch, W, n_attrs) + (
        n_parts * ring_steps(impl, n_parts) * hop_bytes
    )
