"""Multi-device tests — run in a subprocess with 8 fake CPU devices
(jax locks the device count at first init, so the main pytest process
cannot host these)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=420) -> str:
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_fca_mesh_matches_centralized():
    out = _run("""
        from repro.core import FormalContext, ClosureEngine, mrganter_plus, all_closures, bitset
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        fc = FormalContext.synthetic(300, 48, 0.2, seed=3)
        ref = {bitset.key_bytes(y) for y in all_closures(fc)}
        for impl in ("allgather", "rsag", "pmin"):
            eng = ClosureEngine(fc, mesh=mesh, axis_names=("pod", "data"), reduce_impl=impl, block_n=64)
            res = mrganter_plus(fc, eng, dedupe_candidates=True)
            got = {bitset.key_bytes(y) for y in res.intents}
            assert got == ref, impl
        print("OK", len(ref))
    """)
    assert "OK" in out


def test_moe_ep_shardmap_matches_pjit():
    out = _run("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import moe, transformer
        from repro.dist.partition import Partitioner
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("arctic-480b").reduced()
        # capacity_factor 8 ⇒ no token drops on either path (exact compare);
        # exact=False so the EP shard_map path is the one exercised.
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=8, capacity_factor=8.0))
        params_tree = transformer.init_model(cfg, jax.random.key(0))
        from repro.models.layers import split_params
        params, _ = split_params(params_tree)
        p = params["layers"]["block0"]["moe"]
        p = jax.tree_util.tree_map(lambda v: v[0], p)  # un-stack one layer
        x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model), jnp.float32)
        y_ref, aux_ref = moe.moe_fwd(p, x, cfg, shard=None, exact=False)
        part = Partitioner(mesh)
        y_ep, aux_ep = jax.jit(lambda p_, x_: moe.moe_fwd(p_, x_, cfg, shard=part, exact=False))(p, x)
        err = float(jnp.max(jnp.abs(y_ep - y_ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    out = _run("""
        import tempfile
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, tree)
        sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored = restore_checkpoint(d, 1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
        assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_and_compression():
    out = _run("""
        from repro.dist.pipeline import pipeline_apply
        from repro.dist.compression import make_ddp_step
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        # pipeline equivalence
        Ws = jax.random.normal(jax.random.key(0), (2, 8, 8)) * 0.3
        stage_fn = lambda W, x: jnp.tanh(x @ W)
        x = jax.random.normal(jax.random.key(1), (6, 4, 8))
        outp = pipeline_apply(stage_fn, Ws, x, mesh, axis_name="model")
        ref = x
        for s in range(2):
            ref = jax.vmap(lambda xi: stage_fn(Ws[s], xi))(ref)
        assert jnp.allclose(outp, ref, atol=1e-5)
        # compressed DDP convergence
        target = jax.random.normal(jax.random.key(2), (32,))
        def vag(params, batch):
            f = lambda p: jnp.mean((batch["x"] @ p["w"] - batch["x"] @ target) ** 2)
            return jax.value_and_grad(f)(params)
        step, init_err = make_ddp_step(vag, mesh, lr=0.03, axis_name="data")
        params = {"w": jnp.zeros((32,))}
        err = init_err(params)
        rng = np.random.default_rng(0)
        for _ in range(400):
            X = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
            params, err, loss = step(params, err, {"x": X})
        assert float(loss) < 1e-4, float(loss)
        print("OK", float(loss))
    """)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a small mesh + FCA cell."""
    out = _run("""
        from repro.launch.dryrun_lib import run_fca_cell
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(data=4, model=2)
        r = run_fca_cell(mesh, "4x2", n_objects=1 << 14, n_attrs=512, batch=256)
        assert r["status"] == "ok", r
        assert r["flops_per_device"] > 0
        assert r["collective_bytes_per_device"] > 0
        print("OK", int(r["flops_per_device"]))
    """)
    assert "OK" in out


def test_train_step_sharded_end_to_end():
    """Real sharded train steps on an 8-device mesh: loss decreases."""
    out = _run("""
        from repro.configs import get_config
        from repro.models import transformer
        from repro.models.config import ShapeConfig
        from repro.dist.partition import Partitioner
        from repro.train import step as tstep
        from repro.train.optim import get_optimizer, warmup_cosine
        from repro.data.lm_data import make_batch_iterator

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("mamba2-370m").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        part = Partitioner(mesh, fsdp=True)
        params, axes = transformer.init_params(cfg, seed=0)
        opt = get_optimizer("adamw", warmup_cosine(2e-2, 2, 60))
        state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
        sh = tstep.state_shardings(part, axes, jax.tree_util.tree_map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params), opt)
        state = jax.device_put(state, sh)
        step_fn = jax.jit(tstep.make_train_step(cfg, opt, part), in_shardings=(sh, None), donate_argnums=0)
        it = make_batch_iterator(cfg, shape, seed=0)
        losses = []
        for _ in range(25):
            _, batch = next(it)
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.1, (first, last)
        print("OK", first, "->", last)
    """)
    assert "OK" in out
