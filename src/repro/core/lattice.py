"""Concept lattice construction from a mined intent set.

FCA's main theorem guarantees the complete set of intents forms a lattice
under set inclusion; this module materializes the covering relation (Hasse
diagram) used by the examples and the paper-example tests (Table 2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitset, closure
from repro.core.context import FormalContext


@dataclasses.dataclass
class ConceptLattice:
    intents: np.ndarray  # [C, W] uint32, sorted by popcount ascending
    extents: np.ndarray  # [C, N] bool
    children: list[list[int]]  # covering relation: i covers j (j's intent ⊂ i's)

    @property
    def n_concepts(self) -> int:
        return self.intents.shape[0]

    def top(self) -> int:
        """Index of ⟨O, ∅''⟩ — the concept with the smallest intent."""
        return 0

    def bottom(self) -> int:
        return self.n_concepts - 1


def build_lattice(ctx: FormalContext, intents: list[np.ndarray]) -> ConceptLattice:
    arr = np.stack(intents)
    sizes = bitset.popcount(arr)
    order = np.argsort(sizes, kind="stable")
    arr = arr[order]
    sizes = sizes[order]
    extents = np.stack([closure.extent_np(ctx.rows, y) for y in arr])

    C = arr.shape[0]
    children: list[list[int]] = [[] for _ in range(C)]
    # i covers j  ⟺  intent[j] ⊂ intent[i] and no k with j ⊂ k ⊂ i.
    for i in range(C):
        subs = [
            j
            for j in range(i)
            if sizes[j] < sizes[i] and bool(bitset.is_subset(arr[j], arr[i]))
        ]
        sub_set = set(subs)
        for j in subs:
            if not any(
                k in sub_set and bool(bitset.is_subset(arr[j], arr[k])) and k != j
                for k in subs
                if sizes[k] > sizes[j]
            ):
                children[i].append(j)
    return ConceptLattice(intents=arr, extents=extents, children=children)
