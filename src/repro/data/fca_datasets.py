"""FCA dataset pipeline: UCI loaders + offline synthetic stand-ins.

The paper evaluates on three UCI KDD datasets (Table 7):

    dataset        objects   attributes   density
    mushroom         8124       125        17.36 %
    anon-web        32711       294         1.03 %
    census-income  103950       133         6.70 %

This container is offline, so ``load(name)`` generates synthetic contexts
**matched in objects/attributes/density** (and with correlated column
structure so the concept lattice is non-trivial, unlike IID noise).  When a
real UCI file is present under ``data_dir`` it is binarized and used
instead; scale factors (for CPU-budget runs) shrink the object count while
preserving attribute count and density.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.context import FormalContext

PAPER_DATASETS = {
    # name: (objects, attributes, density)
    "mushroom": (8124, 125, 0.1736),
    "anon-web": (32711, 294, 0.0103),
    "census-income": (103950, 133, 0.067),
}


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_objects: int
    n_attrs: int
    density: float
    synthetic: bool


def _synthetic_correlated(
    n_objects: int, n_attrs: int, density: float, seed: int
) -> np.ndarray:
    """Synthetic context with block/cluster structure.

    Objects are drawn from a small number of latent 'profiles' (attribute
    subsets), plus Bernoulli noise calibrated so the *total* density matches
    the target.  Profiles create genuinely shared attribute sets, i.e. a
    rich concept lattice — matching the qualitative behaviour of the UCI
    categorical one-hot data far better than IID noise.
    """
    rng = np.random.default_rng(seed)
    n_profiles = max(4, n_attrs // 8)
    # Each profile activates ~density·n_attrs attributes.
    k = max(1, int(round(density * n_attrs)))
    profiles = np.zeros((n_profiles, n_attrs), dtype=bool)
    for p in range(n_profiles):
        profiles[p, rng.choice(n_attrs, size=k, replace=False)] = True
    assign = rng.integers(0, n_profiles, size=n_objects)
    dense = profiles[assign].copy()
    # Profile membership is kept with prob 0.85; noise fills the rest so the
    # expected density lands on target.
    keep = rng.random(dense.shape) < 0.85
    dense &= keep
    cur = dense.mean()
    if cur < density:
        p_noise = (density - cur) / max(1e-9, 1.0 - cur)
        dense |= rng.random(dense.shape) < p_noise
    return dense


def _binarize_categorical(rows: list[list[str]]) -> np.ndarray:
    """One-hot encode categorical CSV records (UCI mushroom-style)."""
    n_cols = len(rows[0])
    col_values: list[dict[str, int]] = [{} for _ in range(n_cols)]
    for r in rows:
        for c, v in enumerate(r):
            if v not in col_values[c]:
                col_values[c][v] = len(col_values[c])
    offsets = np.cumsum([0] + [len(cv) for cv in col_values[:-1]])
    n_attrs = int(offsets[-1] + len(col_values[-1]))
    dense = np.zeros((len(rows), n_attrs), dtype=bool)
    for i, r in enumerate(rows):
        for c, v in enumerate(r):
            dense[i, offsets[c] + col_values[c][v]] = True
    return dense


def load_uci_file(path: str) -> FormalContext:
    """Load a UCI categorical CSV (`.data`) into a context via one-hot."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(line.split(","))
    return FormalContext.from_dense(_binarize_categorical(rows))


def load(
    name: str,
    *,
    scale: float = 1.0,
    seed: int = 0,
    data_dir: str | None = None,
) -> tuple[FormalContext, DatasetSpec]:
    """Load a paper dataset (real if available, else matched synthetic)."""
    if name not in PAPER_DATASETS:
        raise ValueError(f"unknown dataset {name!r}; choose {list(PAPER_DATASETS)}")
    n_obj, n_attr, dens = PAPER_DATASETS[name]
    n_obj = max(8, int(round(n_obj * scale)))

    if data_dir:
        path = os.path.join(data_dir, f"{name}.data")
        if os.path.exists(path):
            ctx = load_uci_file(path)
            if scale < 1.0:
                keep = np.random.default_rng(seed).choice(
                    ctx.n_objects, size=n_obj, replace=False
                )
                ctx = FormalContext(
                    rows=ctx.rows[np.sort(keep)],
                    n_objects=n_obj,
                    n_attrs=ctx.n_attrs,
                )
            return ctx, DatasetSpec(name, ctx.n_objects, ctx.n_attrs, ctx.density, False)

    dense = _synthetic_correlated(n_obj, n_attr, dens, seed)
    ctx = FormalContext.from_dense(dense)
    return ctx, DatasetSpec(name, ctx.n_objects, ctx.n_attrs, ctx.density, True)
