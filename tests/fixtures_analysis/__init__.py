# Fixture corpus for tests/test_analysis.py.  These files are analyzed
# by *path* (ast.parse) and must never be imported: the *_bad.py members
# deliberately contain every defect the repro.analysis rules exist to
# catch, each paired with a clean twin that must stay silent.
