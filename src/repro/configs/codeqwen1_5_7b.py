"""codeqwen1.5-7b [dense] — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,  # GQA kv=32 == MHA
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    rope_kind="standard",
    rope_theta=1_000_000.0,
    qkv_bias=True,  # qwen1.5 uses qkv biases
    mlp_kind="swiglu",
)
