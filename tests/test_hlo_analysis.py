"""The while-aware HLO analyzer vs hand-counted programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo


def _compile(fn, *avals):
    return jax.jit(fn).lower(*avals).compile()


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    t = analyze(comp.as_text())
    assert t.flops == 2 * 64 * 128 * 32
    assert t.unresolved_whiles == 0


def test_scan_trip_count_scaling():
    """Dots inside lax.scan must be multiplied by the trip count."""
    T = 9

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((T, 32, 32), jnp.float32)
    comp = _compile(fn, x, w)
    t = analyze(comp.as_text())
    assert t.flops == T * 2 * 16 * 32 * 32
    assert t.unresolved_whiles == 0


def test_nested_scan_scaling():
    T1, T2 = 4, 5

    def inner(c, wi):
        return jnp.tanh(c @ wi), None

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, None

    def fn(x, w):
        out, _ = jax.lax.scan(outer, x, w)
        return out

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((T1, T2, 16, 16), jnp.float32)
    comp = _compile(fn, x, w)
    t = analyze(comp.as_text())
    assert t.flops == T1 * T2 * 2 * 8 * 16 * 16


def test_grad_flops_3x_forward():
    def fn(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    fwd = analyze(_compile(fn, x, w).as_text()).flops
    grad = analyze(_compile(jax.grad(fn, argnums=1), x, w).as_text()).flops
    assert fwd == 2 * 32 * 64 * 16
    assert grad >= 2 * fwd  # dx (often DCE'd) + dw ≈ 2×; with dx 3×


def test_parse_hlo_computation_census():
    comp = _compile(lambda x: jnp.tanh(x) + 1, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_hlo(comp.as_text())
    assert "__entry__" in comps
    assert len(comps["__entry__"].order) >= 1


def test_collectives_counted_on_mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (see test_distributed_8dev.py)")
