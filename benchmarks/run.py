# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument(
        "--only", default=None,
        help="comma-separated subset: table7,table8,table9,fig234,kernel,frontier,dist,query,rules,serve_load,roofline",
    )
    p.add_argument("--roofline-path", default="dryrun_single.jsonl")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (
        dist_bench,
        fig234_scaling,
        kernel_bench,
        query_bench,
        roofline,
        rules_bench,
        serve_load_bench,
        table7_datasets,
        table8_runtime,
        table9_iterations,
    )

    suites = {
        "table7": table7_datasets.run,
        "table8": table8_runtime.run,
        "table9": table9_iterations.run,
        "fig234": fig234_scaling.run,
        "kernel": kernel_bench.run,
        "frontier": kernel_bench.run_frontier,
        "dist": dist_bench.run,
        "query": query_bench.run,
        "rules": rules_bench.run,
        "serve_load": serve_load_bench.run,
        "roofline": lambda: roofline.run(args.roofline_path),
    }
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for name, fn in suites.items():
        if only is not None and name not in only:
            continue
        t1 = time.perf_counter()
        for line in fn():
            print(line, flush=True)
        print(f"# {name} done in {time.perf_counter() - t1:.1f}s", file=sys.stderr)
    print(f"# all benchmarks done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
