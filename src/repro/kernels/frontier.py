"""Fused frontier-step Pallas kernels — closure, support, and the driver
filter in one VMEM-resident pass (ISSUE 6 tentpole).

Since PR 1–5 the mining hot loop is ``closure map → popcount/AND reduce →
driver filter``, executed as *separate* XLA ops that round-trip the
bit-packed ``[B, W]`` closure block through HBM between stages.  This
module fuses the whole per-chunk step into Pallas kernels so the candidate
block and context rows stay in VMEM/registers from the subset test to the
survivor mask:

``fused_closure_call``  (the full fusion)
    One ``pallas_call`` computing, per candidate block,

        closure  = AND of matching context rows   (masked to real attrs)
        support  = #matching rows − #all-ones pad rows
        keep     = row-validity ∧ [support ≥ min_sup] ∧ [CbO canonicity]

    with the iceberg threshold, valid-row count, pad count and the 2-D
    block offset riding as a **scalar-prefetch** operand (SMEM) — one
    compile serves every threshold and every candidate block.  Exact when
    local closure == global closure, i.e. on single-object-shard plans
    (``n_parts == 1``, with or without candidate-axis sharding).

``map_closure_call``
    The map half for multi-shard plans: closure + support popcount with
    the attribute mask applied **in-kernel** (AND distributes over the
    mask, so masked locals AND-allreduce to the masked global closure and
    the separate post-reduce mask op disappears).

``filter_call``
    The post-reduce half for multi-shard plans: one ``pallas_call``
    evaluating pad correction + iceberg cut + CbO canonicity on the
    globally reduced ``[B, W]`` block — the three driver-filter ops fused
    into a single VMEM pass.

The driver-side compaction (``_compact`` / ``_sort_unique`` argsorts in
:mod:`repro.core.frontier`) stays jnp: a data-dependent permutation is
XLA's job, and it consumes only the kernel's survivor mask + closures —
never a full intermediate.  CbO's canonicity operand ``LOW[gen]`` is
gathered outside the kernel (a [B, W] table row gather) and enters as a
regular blocked input.

Padding discipline matches ``kernels/closure.py``: context rows padded to
``block_n`` multiples with all-ones AND-identity rows (supports corrected
in-kernel via the scalar operand), candidate caps are power-of-two buckets
``≥ block_b``.  Everything is validated bit-identical to the jnp step
oracles in interpret mode (tests/test_fused_frontier.py); widths beyond
``MAX_W`` take the jnp path, same as ``ops.batched_closure``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro import compat
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.closure import (
    DEFAULT_B_BLK,
    DEFAULT_N_BLK,
    FULL_WORD,
    MAX_W,
    _tree_and,
)

# scalar-prefetch operand layout (int32 [4], SMEM):
#   [0] n_valid   — valid candidate rows in the (whole-chunk) batch
#   [1] min_sup   — iceberg threshold (ignored unless iceberg=True)
#   [2] n_pad     — all-ones context padding rows to subtract from supports
#   [3] row_off   — this block's first row's chunk-global index
#                   (cand_index * block_rows on 2-D plans, 0 on 1-D)
N_SCALARS = 4


def pack_scalars(n_valid, min_sup=0, n_pad=0, row_off=0) -> jax.Array:
    """Assemble the kernels' scalar-prefetch operand (traced values ok)."""
    return jnp.stack(
        [
            jnp.asarray(n_valid, jnp.int32),
            jnp.asarray(min_sup, jnp.int32),
            jnp.asarray(n_pad, jnp.int32),
            jnp.asarray(row_off, jnp.int32),
        ]
    )


def _row_valid(s_ref, b_step, bb):
    """Chunk-global row validity for this candidate block ([bb, 1] bool)."""
    idx = lax.broadcasted_iota(jnp.int32, (bb, 1), 0) + b_step * bb
    return (idx + s_ref[3]) < s_ref[0]


def _keep_mask(s_ref, b_step, gc, sup_c, parent, lowrow, *, iceberg, cbo):
    """The fused driver filter: validity ∧ iceberg cut ∧ CbO canonicity.

    ``gc`` is the masked closure block [bb, W], ``sup_c`` the corrected
    supports [bb, 1].  Mirrors the jnp posts bit-for-bit:
    ``post_iceberg``'s ``(arange < n_valid) & (gs >= min_sup)`` and
    ``lectic.feasible_jnp``'s ``((Z ^ Y) & LOW[a]) == 0``.
    """
    keep = _row_valid(s_ref, b_step, gc.shape[0])
    if iceberg:
        keep = keep & (sup_c >= s_ref[1])
    if cbo:
        canonical = jnp.all((gc ^ parent) & lowrow == 0, axis=-1, keepdims=True)
        keep = keep & canonical
    return keep.astype(jnp.int32)


def _fused_kernel(
    iceberg, cbo,
    s_ref, cand_ref, rows_ref, mask_ref, *refs,
):
    """closure → support popcount → driver filter, one grid pass.

    Grid is (B/bb, N/bn) with N innermost; the closure/support output
    blocks accumulate across the N steps (TPU sequential-grid semantics)
    and the filter runs once, on the final N step, against the fully
    accumulated block — nothing ever leaves VMEM in between.
    """
    if cbo:
        parent_ref, lowrow_ref, out_c_ref, out_s_ref, out_k_ref = refs
    else:
        parent_ref = lowrow_ref = None
        out_c_ref, out_s_ref, out_k_ref = refs
    b_step = pl.program_id(0)
    n_step = pl.program_id(1)
    n_steps = pl.num_programs(1)
    cands = cand_ref[...]  # [bb, W]
    rows = rows_ref[...]  # [bn, W]

    inter = rows[None, :, :] & cands[:, None, :]
    match = jnp.all(inter == cands[:, None, :], axis=-1)  # [bb, bn]
    full = jnp.full((), FULL_WORD, dtype=jnp.uint32)
    sel = jnp.where(match[:, :, None], rows[None, :, :], full)
    acc = _tree_and(sel, axis=1)  # [bb, W]
    sup = jnp.sum(match.astype(jnp.int32), axis=-1, keepdims=True)

    @pl.when(n_step == 0)
    def _init():
        out_c_ref[...] = acc
        out_s_ref[...] = sup
        out_k_ref[...] = jnp.zeros_like(out_k_ref)

    @pl.when(n_step != 0)
    def _accum():
        out_c_ref[...] = out_c_ref[...] & acc
        out_s_ref[...] = out_s_ref[...] + sup

    @pl.when(n_step == n_steps - 1)
    def _finalize():
        gc = out_c_ref[...] & mask_ref[...]  # broadcast [1, W]
        sup_c = out_s_ref[...] - s_ref[2]
        out_c_ref[...] = gc
        out_s_ref[...] = sup_c
        out_k_ref[...] = _keep_mask(
            s_ref, b_step, gc, sup_c,
            None if parent_ref is None else parent_ref[...],
            None if lowrow_ref is None else lowrow_ref[...],
            iceberg=iceberg, cbo=cbo,
        )


@functools.partial(
    jax.jit,
    static_argnames=("iceberg", "cbo", "block_b", "block_n", "interpret"),
)
def fused_closure_call(
    rows: jax.Array,
    cands: jax.Array,
    mask: jax.Array,
    scalars: jax.Array,
    *,
    parent: jax.Array | None = None,
    lowrow: jax.Array | None = None,
    iceberg: bool = False,
    cbo: bool = False,
    block_b: int = DEFAULT_B_BLK,
    block_n: int = DEFAULT_N_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The fully fused frontier step (single-object-shard plans).

    rows [N, W] (all-ones padded, N % block_n == 0), cands [B, W]
    (B % block_b == 0), mask [1, W], scalars int32 [4] (see module top).
    CbO variants additionally take parent/lowrow [B, W].
    Returns (closures [B, W] masked, supports [B] corrected, keep [B]).
    """
    N, W = rows.shape
    B = cands.shape[0]
    if W > MAX_W:
        raise ValueError(f"W={W} exceeds MAX_W={MAX_W}; use the jnp path")
    if N % block_n or B % block_b:
        raise ValueError(f"unaligned shapes N={N}%{block_n}, B={B}%{block_b}")
    if cbo and (parent is None or lowrow is None):
        raise ValueError("cbo=True needs parent= and lowrow= operands")

    grid = (B // block_b, N // block_n)
    in_specs = [
        pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
        pl.BlockSpec((block_n, W), lambda b, n, s: (n, 0)),
        pl.BlockSpec((1, W), lambda b, n, s: (0, 0)),
    ]
    inputs = [cands, rows, mask]
    if cbo:
        in_specs += [
            pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
            pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
        ]
        inputs += [parent, lowrow]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, n, s: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, n, s: (b, 0)),
        ],
    )
    out_c, out_s, out_k = pl.pallas_call(
        functools.partial(_fused_kernel, iceberg, cbo),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(scalars, *inputs)
    return out_c, out_s[:, 0], out_k[:, 0] > 0


def _map_kernel(s_ref, cand_ref, rows_ref, mask_ref, out_c_ref, out_s_ref):
    """closure + support popcount with the attr mask folded in-kernel."""
    n_step = pl.program_id(1)
    n_steps = pl.num_programs(1)
    cands = cand_ref[...]
    rows = rows_ref[...]
    inter = rows[None, :, :] & cands[:, None, :]
    match = jnp.all(inter == cands[:, None, :], axis=-1)
    full = jnp.full((), FULL_WORD, dtype=jnp.uint32)
    sel = jnp.where(match[:, :, None], rows[None, :, :], full)
    acc = _tree_and(sel, axis=1)
    sup = jnp.sum(match.astype(jnp.int32), axis=-1, keepdims=True)

    @pl.when(n_step == 0)
    def _init():
        out_c_ref[...] = acc
        out_s_ref[...] = sup

    @pl.when(n_step != 0)
    def _accum():
        out_c_ref[...] = out_c_ref[...] & acc
        out_s_ref[...] = out_s_ref[...] + sup

    @pl.when(n_step == n_steps - 1)
    def _finalize():
        out_c_ref[...] = out_c_ref[...] & mask_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret")
)
def map_closure_call(
    rows: jax.Array,
    cands: jax.Array,
    mask: jax.Array,
    *,
    block_b: int = DEFAULT_B_BLK,
    block_n: int = DEFAULT_N_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Per-shard map half for multi-shard plans: masked local closures
    [B, W] + raw local supports [B] (pad correction happens after the
    psum, in :func:`filter_call`)."""
    N, W = rows.shape
    B = cands.shape[0]
    if W > MAX_W:
        raise ValueError(f"W={W} exceeds MAX_W={MAX_W}; use the jnp path")
    if N % block_n or B % block_b:
        raise ValueError(f"unaligned shapes N={N}%{block_n}, B={B}%{block_b}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // block_b, N // block_n),
        in_specs=[
            pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
            pl.BlockSpec((block_n, W), lambda b, n, s: (n, 0)),
            pl.BlockSpec((1, W), lambda b, n, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, W), lambda b, n, s: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, n, s: (b, 0)),
        ],
    )
    out_c, out_s = pl.pallas_call(
        _map_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(jnp.zeros((N_SCALARS,), jnp.int32), cands, rows, mask)
    return out_c, out_s[:, 0]


def _filter_kernel(iceberg, cbo, s_ref, gc_ref, gs_ref, *refs):
    if cbo:
        parent_ref, lowrow_ref, out_s_ref, out_k_ref = refs
    else:
        parent_ref = lowrow_ref = None
        out_s_ref, out_k_ref = refs
    b_step = pl.program_id(0)
    gc = gc_ref[...]
    sup_c = gs_ref[...] - s_ref[2]
    out_s_ref[...] = sup_c
    out_k_ref[...] = _keep_mask(
        s_ref, b_step, gc, sup_c,
        None if parent_ref is None else parent_ref[...],
        None if lowrow_ref is None else lowrow_ref[...],
        iceberg=iceberg, cbo=cbo,
    )


@functools.partial(
    jax.jit,
    static_argnames=("iceberg", "cbo", "block_b", "interpret"),
)
def filter_call(
    gc: jax.Array,
    gs: jax.Array,
    scalars: jax.Array,
    *,
    parent: jax.Array | None = None,
    lowrow: jax.Array | None = None,
    iceberg: bool = False,
    cbo: bool = False,
    block_b: int = DEFAULT_B_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Post-reduce fused driver filter for multi-shard plans.

    gc [B, W] globally reduced masked closures, gs [B] psum'd raw
    supports.  Returns (supports corrected [B], keep [B] bool).
    """
    B, W = gc.shape
    if B % block_b:
        raise ValueError(f"unaligned batch B={B}%{block_b}")
    if cbo and (parent is None or lowrow is None):
        raise ValueError("cbo=True needs parent= and lowrow= operands")
    in_specs = [
        pl.BlockSpec((block_b, W), lambda b, s: (b, 0)),
        pl.BlockSpec((block_b, 1), lambda b, s: (b, 0)),
    ]
    inputs = [gc, gs[:, None]]
    if cbo:
        in_specs += [
            pl.BlockSpec((block_b, W), lambda b, s: (b, 0)),
            pl.BlockSpec((block_b, W), lambda b, s: (b, 0)),
        ]
        inputs += [parent, lowrow]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // block_b,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda b, s: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, s: (b, 0)),
        ],
    )
    out_s, out_k = pl.pallas_call(
        functools.partial(_filter_kernel, iceberg, cbo),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(scalars, *inputs)
    return out_s[:, 0], out_k[:, 0] > 0


# ---------------------------------------------------------------------------
# step-variant metadata shared with the engine wiring
# ---------------------------------------------------------------------------

# variant name -> (iceberg, cbo, unique) flags; the engine's fused step
# builders key off these, the drivers keep using the same names they pass
# to DeviceFrontier._step_fn.
VARIANTS = {
    "plain": (False, False, False),
    "unique": (False, False, True),
    "iceberg": (True, False, False),
    "iceberg_unique": (True, False, True),
    "cbo": (False, True, False),
    "cbo_iceberg": (True, True, False),
}


def supports_fused(backend: str, W: int) -> bool:
    """Whether the fused frontier kernels can serve this engine config."""
    return backend == "kernel" and W <= MAX_W
