"""RuleIndex — the extracted bases as a device-resident serving artifact.

The serving twin of the concept store's snapshot: the combined rule table
(DG implications, confidence ≡ 1, followed by the Luxenburger partial
rules) padded to a power-of-two cap and replicated through the plan, so
:class:`repro.query.engine.QueryEngine`'s fixed-slot rule ops read it like
any other snapshot table — zero collective rounds, one compiled step per
(k, rank metric) reused across index rebuilds of the same padded shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import bucket_size
from repro.rules.basis import RuleBasis, RuleSet


@dataclasses.dataclass(frozen=True)
class RuleIndex:
    n_rules: int
    n_exact: int  # leading rows that are DG implications (conf ≡ 1)
    cap: int
    premise: jax.Array  # [cap, W] uint32 (pads all-ones: match nothing real)
    added: jax.Array  # [cap, W] uint32
    support: jax.Array  # [cap] int32
    confidence: jax.Array  # [cap] float32 (pads -1)
    lift: jax.Array  # [cap] float32 (pads -1)
    # canonical rule identity, the deterministic tie-break key for ranked
    # queries: position in the combined basis (implications first, then the
    # Luxenburger rules in canonical order).  Pads get INT32_MAX so a pad
    # can never win a tie against a real rule.
    rule_id: jax.Array  # [cap] int32
    # host copies (oracles, answer detail expansion)
    premise_np: np.ndarray
    added_np: np.ndarray
    support_np: np.ndarray
    confidence_np: np.ndarray
    lift_np: np.ndarray

    @classmethod
    def build(cls, basis: RuleBasis, *, plan=None) -> "RuleIndex":
        combined: RuleSet = basis.combined()
        R = len(combined)
        W = combined.premise.shape[1]
        cap = bucket_size(max(1, R), minimum=8)
        prem = np.full((cap, W), 0xFFFFFFFF, np.uint32)
        added = np.zeros((cap, W), np.uint32)
        sup = np.zeros((cap,), np.int32)
        conf = np.full((cap,), -1.0, np.float32)
        lift = np.full((cap,), -1.0, np.float32)
        prem[:R] = combined.premise
        added[:R] = combined.added
        sup[:R] = combined.support
        conf[:R] = combined.confidence
        lift[:R] = combined.lift
        rid = np.full((cap,), np.iinfo(np.int32).max, np.int32)
        rid[:R] = np.arange(R, dtype=np.int32)
        place = plan.replicate if plan is not None else jnp.asarray
        return cls(
            n_rules=R,
            n_exact=basis.n_implications,
            cap=cap,
            premise=place(prem),
            added=place(added),
            support=place(sup),
            confidence=place(conf),
            lift=place(lift),
            rule_id=place(rid),
            premise_np=prem[:R],
            added_np=added[:R],
            support_np=sup[:R],
            confidence_np=conf[:R],
            lift_np=lift[:R],
        )

    def describe(self) -> dict:
        return {
            "rules": self.n_rules,
            "exact": self.n_exact,
            "partial": self.n_rules - self.n_exact,
            "cap": self.cap,
        }


def rule_query_mix(
    ctx,
    index: RuleIndex,
    n: int,
    rng,
    *,
    thin: float = 0.3,
    hit_fraction: float = 0.5,
) -> "np.ndarray":
    """The standard rule-serving traffic mix (CLI smoke + benchmark share
    it): context rows thinned to ``thin`` bit density (mixed hit/miss
    traffic), with the leading ``hit_fraction`` of the batch overwritten
    by real rule premises (guaranteed hits)."""
    from repro.core import bitset

    base = ctx.rows[rng.integers(0, ctx.n_objects, size=n)]
    keep = bitset.pack_bool(rng.random((n, ctx.n_attrs)) < thin, ctx.W)
    queries = base & keep
    if index.n_rules:
        n_hit = int(n * hit_fraction)
        picks = rng.integers(0, index.n_rules, size=n_hit)
        queries[:n_hit] = index.premise_np[picks]
    return queries
