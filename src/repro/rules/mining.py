"""Iceberg mining entry points: threshold resolution + driver dispatch.

The actual in-round pruning lives in the miners themselves
(:mod:`repro.core.mr` ``min_support=``, fused after the support psum in
:mod:`repro.core.frontier`); this module owns the user-facing threshold
vocabulary (absolute count or fraction of |O|) and the one-call
mine-to-store path the CLI and benchmarks share.
"""

from __future__ import annotations

import math

from repro.core.mr import MRResult, mrcbo, mrganter, mrganter_plus

ALGORITHMS = {
    "mrganter": mrganter,
    "mrganter+": mrganter_plus,
    "mrcbo": mrcbo,
}


def resolve_min_support(value, n_objects: int) -> int:
    """An absolute object count from a count-or-fraction spec.

    Fractions in (0, 1) resolve to ``ceil(value · n_objects)`` (≥ 1);
    values ≥ 1 must be whole counts.  The resolved count is what the
    miners, store filters and CLI stats all speak.

    The ceiling snaps to the nearest integer when the product sits within
    floating-point noise of it: a fraction that lands *exactly* on an
    integer support must resolve to that integer, but binary floating
    point can nudge the product just above (e.g. ``0.07 * 100 ==
    7.000000000000001``) and a naive ``ceil`` would then silently drop
    every concept sitting exactly on the threshold boundary.  "Support ≥
    7" and "support ≥ 0.07·|O|" have to mean the same thing.  The snap
    tolerance is relative (1e-12 ≈ 4000 ulp — far above the few-ulp error
    of one divide+multiply, far below any meaningful fractional part), so
    genuinely fractional targets still round up at any |O|.
    """
    v = float(value)
    if not math.isfinite(v) or v <= 0:
        raise ValueError(f"min_support must be positive, got {value!r}")
    if v < 1:
        target = v * n_objects
        nearest = round(target)
        if nearest >= 1 and abs(target - nearest) <= 1e-12 * max(1.0, target):
            return int(nearest)
        return max(1, math.ceil(target))
    if v != int(v):
        raise ValueError(
            f"min_support ≥ 1 must be a whole object count, got {value!r}"
        )
    return int(v)


def mine_iceberg(
    ctx,
    engine,
    *,
    min_support,
    algorithm: str = "mrganter+",
    pipeline: str = "device",
    **kw,
) -> MRResult:
    """Mine the iceberg lattice at ``min_support`` (count or fraction).

    Dispatches to the chosen MR* driver with the threshold resolved to an
    absolute count; the pruning is fused into the drivers' SPMD rounds.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; choose {sorted(ALGORITHMS)}"
        )
    s = resolve_min_support(min_support, ctx.n_objects)
    return ALGORITHMS[algorithm](
        ctx, engine, pipeline=pipeline, min_support=s, **kw
    )
