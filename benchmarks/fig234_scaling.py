"""Figs 2–4 — scalability: per-round time & modeled comm vs node count.

Sweeps the partition count (the paper's x-axis) for MRGanter+ and MRCbo and
reports wall time plus the modeled per-round collective traffic for the
three reduce schedules (allgather — paper-faithful shuffle topology; rsag —
bandwidth-optimal ring, beyond-paper; pmin — unpacked XLA all-reduce).
"""

from __future__ import annotations

from benchmarks.common import load_scaled, make_engine, row, timed
from repro.core import mrcbo, mrganter_plus
from repro.dist.collectives import modeled_comm_bytes


def run(parts=(1, 2, 4, 8), datasets=("mushroom", "census-income")) -> list[str]:
    out = []
    for name in datasets:
        ctx, _ = load_scaled(name)
        for k in parts:
            eng = make_engine(ctx, k)
            res, t = timed(mrganter_plus, ctx, eng, dedupe_candidates=True)
            out.append(row(
                f"fig234/{name}/mrganter+/parts={k}",
                1e6 * t / max(1, res.n_iterations),
                f"total_s={t:.3f}|iters={res.n_iterations}"
                f"|comm={res.modeled_comm_bytes}",
            ))
            eng = make_engine(ctx, k)
            res2, t2 = timed(mrcbo, ctx, eng)
            out.append(row(
                f"fig234/{name}/mrcbo/parts={k}",
                1e6 * t2 / max(1, res2.n_iterations),
                f"total_s={t2:.3f}|iters={res2.n_iterations}"
                f"|comm={res2.modeled_comm_bytes}",
            ))
        # reduce-schedule comparison at fixed round shape (B=1024 closures)
        for impl in ("allgather", "rsag", "pmin"):
            out.append(row(
                f"fig234/{name}/comm_model/{impl}/parts=8", 0.0,
                f"bytes_per_round="
                f"{modeled_comm_bytes(impl, 8, 1024, ctx.W, ctx.n_attrs)}",
            ))
    return out
