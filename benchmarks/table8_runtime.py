"""Table 8 — execution time per algorithm per dataset.

Centralized NextClosure / CloseByOne (numpy bitset) vs distributed
MRGanter / MRCbo / MRGanter+ (ClosureEngine, simulated partitions on one
CPU device — the arithmetic, batching, and reduce schedule are identical
to the mesh path, which is exercised separately by tests/dry-run).

MRGanter enumerates one concept per MapReduce round (the paper's result —
it's the slow one), so its rounds are capped and the total extrapolated.
"""

from __future__ import annotations

from benchmarks.common import load_scaled, make_engine, row, timed
from repro.core import (
    all_closures_batched,
    close_by_one,
    mrcbo,
    mrganter,
    mrganter_plus,
)

MRGANTER_CAP = 500  # rounds; total time extrapolated to full concept count


def run(n_parts: int = 4, datasets=("mushroom", "anon-web", "census-income")) -> list[str]:
    out = []
    for name in datasets:
        ctx, spec = load_scaled(name)

        intents, t_nc = timed(all_closures_batched, ctx)
        n_concepts = len(intents)
        out.append(row(f"table8/{name}/nextclosure", 1e6 * t_nc / max(1, n_concepts),
                       f"total_s={t_nc:.3f}|concepts={n_concepts}"))

        res_cbo, t_cbo = timed(close_by_one, ctx)
        out.append(row(f"table8/{name}/closebyone", 1e6 * t_cbo / max(1, n_concepts),
                       f"total_s={t_cbo:.3f}|concepts={len(res_cbo.intents)}"))

        eng = make_engine(ctx, n_parts)
        res_mg, t_mg = timed(mrganter, ctx, eng, max_iterations=MRGANTER_CAP)
        scale = n_concepts / max(1, res_mg.n_iterations)
        out.append(row(
            f"table8/{name}/mrganter", 1e6 * t_mg / max(1, res_mg.n_iterations),
            f"capped_s={t_mg:.3f}|rounds={res_mg.n_iterations}"
            f"|extrapolated_s={t_mg * scale:.1f}",
        ))

        eng = make_engine(ctx, n_parts)
        res_cb, t_cb = timed(mrcbo, ctx, eng)
        out.append(row(f"table8/{name}/mrcbo", 1e6 * t_cb / max(1, n_concepts),
                       f"total_s={t_cb:.3f}|iters={res_cb.n_iterations}"))

        eng = make_engine(ctx, n_parts)
        res_mgp, t_mgp = timed(mrganter_plus, ctx, eng, dedupe_candidates=True)
        assert len(res_mgp.intents) == n_concepts, (len(res_mgp.intents), n_concepts)
        out.append(row(f"table8/{name}/mrganter+", 1e6 * t_mgp / max(1, n_concepts),
                       f"total_s={t_mgp:.3f}|iters={res_mgp.n_iterations}"
                       f"|comm_bytes={res_mgp.modeled_comm_bytes}"))
    return out
