"""repro.obs — trace export well-formedness, metrics/histogram units,
no-op-tracer transparency (traced mine bit-identical to untraced), the
async overlap signature, and the sync-vs-async transfer-census parity the
tracer made checkable."""

import json

import numpy as np
import pytest

from repro.core import ClosureEngine, all_closures_batched, bitset, mrcbo, mrganter
from repro.core.context import FormalContext
from repro.dist.shardplan import ShardPlan
from repro.obs import (
    Histogram,
    Registry,
    ScheduleCensus,
    StatsBase,
    Tracer,
    async_overlaps,
    current,
    span_rollup,
    use_tracer,
    validate_trace,
)
from repro.obs.trace import NOOP, _NULL_SPAN
from repro.query import ConceptStore, QueryEngine
from repro.query.engine import QueryConfig, QueryStats


def _keys(intents):
    return {bitset.key_bytes(y) for y in np.asarray(intents, np.uint32)}


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(60, 14, 0.3, seed=11)


# -- histogram / registry ----------------------------------------------------


def test_histogram_percentiles_within_bucket_error():
    h = Histogram()
    for v in np.linspace(0.001, 0.1, 1000):
        h.record(float(v))
    # log-bucketed: relative error bounded by the 2**(1/8) bucket factor
    for q, expect in ((50, 0.0505), (95, 0.0950), (99, 0.0990)):
        got = h.percentile(q)
        assert abs(got - expect) / expect < 0.10, (q, got)
    assert h.percentile(100) == pytest.approx(0.1)
    assert h.count == 1000


def test_histogram_empty_and_clamps():
    h = Histogram()
    assert h.percentile(50) == 0.0
    h.record(0.0)  # below the 1 µs floor → bucket 0
    assert h.percentile(99) <= 1e-6
    h2 = Histogram()
    h2.record(2.5)
    # single sample: every percentile is clamped to the observed extrema
    assert h2.percentile(50) == pytest.approx(2.5, rel=0.09)
    assert set(h2.percentiles()) == {"p50", "p95", "p99"}


def test_histogram_underflow_bucket_is_explicit():
    h = Histogram()
    h.record(5e-7)  # below the 1 µs floor
    h.record(2e-7)
    h.record(0.004)
    assert h.underflow == 2
    assert h.count == 3  # underflow counts in rank/count/sum as usual
    assert h.sum == pytest.approx(0.004 + 7e-7)
    # bucket 0's upper edge is the floor itself — the exporter renders it
    # as a real le="1e-06" bucket, not as silently-clamped observations
    edges = h.bucket_edges()
    assert edges[0] == (1e-6, 2)
    assert h.summary()["underflow"] == 2
    assert h.fraction_below(1e-6) == pytest.approx(2 / 3)


def test_registry_label_cardinality_cap_overflows_visibly():
    r = Registry(max_label_sets=4)
    for i in range(10):  # unbounded label value (e.g. a client id)
        r.counter("hits", qid=str(i))
    for i in range(6):  # histograms share the same per-name cap
        r.observe("lat_s", 0.001, qid=str(i))
    out = r.export()
    # first 4 label sets stored as-is; the rest collapse into overflow
    assert sum(1 for k in out if k.startswith("hits{qid=")) == 4
    assert out["hits{overflow=true}"] == 6
    assert out["lat_s{overflow=true}"]["count"] == 2
    # ...and the truncation is counted per metric name, never silent
    assert out["labels_overflow_total{metric=hits}"] == 6
    assert out["labels_overflow_total{metric=lat_s}"] == 2
    # unlabeled metrics are exempt (a single series can't explode)
    r2 = Registry(max_label_sets=1)
    r2.counter("a")
    r2.counter("b")
    assert set(r2.export()) == {"a", "b"}


def test_registry_labels_and_export():
    r = Registry()
    r.counter("rounds", 1, impl="rsag")
    r.counter("rounds", 2, impl="rsag")
    r.gauge("parts", 4)
    r.observe("lat", 0.01, kind="round")
    out = r.export()
    assert out["rounds{impl=rsag}"] == 3
    assert out["parts"] == 4
    assert out["lat{kind=round}"]["count"] == 1
    json.dumps(out)  # JSON-serialisable snapshot


def test_stats_base_latency_view_rides_asdict():
    import dataclasses

    st = StatsBase()
    st.record_reduce("allgather")
    st.record_reduce("allgather")
    st.observe_latency("round", 0.002)
    st.observe_latency("round", 0.004)
    d = dataclasses.asdict(st)
    assert d["reduce_rounds"] == {"allgather": 2}
    assert set(d["latency_percentiles"]["round"]) == {"p50", "p95", "p99"}
    assert "_registry" not in d  # the registry is a non-field attr
    pub = st.publish()
    assert pub["reduce_rounds{impl=allgather}"] == 2
    assert isinstance(ScheduleCensus(), ScheduleCensus)


# -- tracer export -----------------------------------------------------------


def test_trace_well_formed_and_round_trips():
    tr = Tracer()
    with tr.span("a", x=1):
        with tr.span("a/b"):
            tr.instant("mark")
        with tr.span("a/c") as sp:
            sp.set(outcome="done")
    obj = json.loads(json.dumps(tr.to_dict()))  # Perfetto JSON round-trip
    summary = validate_trace(obj)
    assert summary["spans"] == 3 and summary["max_depth"] == 2
    ts = [e["ts"] for e in obj["traceEvents"]]
    assert ts == sorted(ts)  # monotone per (single) track
    ends = {e["name"]: e.get("args") for e in obj["traceEvents"] if e["ph"] == "E"}
    assert ends["a/c"] == {"outcome": "done"}


def test_trace_async_pairing_and_save_closes_leaks(tmp_path):
    tr = Tracer()
    tr.begin_async("round", 7, algo="x")
    with tr.span("dispatch"):
        pass
    tr.end_async("round", 7, outcome="adopt")
    validate_trace(tr.to_dict())
    # a span leaked by an exception is closed by save() so the file validates
    tr2 = Tracer()
    tr2.span("leaked").__enter__()
    p = tmp_path / "t.json"
    tr2.save(str(p))
    validate_trace(json.loads(p.read_text()))


def test_validate_trace_rejects_malformed():
    base = {"pid": 0, "tid": 0, "cat": "host"}
    bad_unbalanced = {"traceEvents": [dict(base, name="a", ph="B", ts=1.0)]}
    with pytest.raises(ValueError, match="unclosed"):
        validate_trace(bad_unbalanced)
    bad_nest = {"traceEvents": [
        dict(base, name="a", ph="B", ts=1.0),
        dict(base, name="b", ph="B", ts=2.0),
        dict(base, name="a", ph="E", ts=3.0),
    ]}
    with pytest.raises(ValueError, match="nest"):
        validate_trace(bad_nest)
    bad_ts = {"traceEvents": [
        dict(base, name="a", ph="B", ts=5.0),
        dict(base, name="a", ph="E", ts=1.0),
    ]}
    with pytest.raises(ValueError, match="monotone"):
        validate_trace(bad_ts)
    bad_async = {"traceEvents": [
        dict(base, name="r", ph="e", ts=1.0, id=3, cat="round"),
    ]}
    with pytest.raises(ValueError, match="matching b"):
        validate_trace(bad_async)


def test_span_rollup_strips_indices():
    tr = Tracer()
    for i in range(3):
        with tr.span(f"mine/round[{i}]"):
            with tr.span(f"mine/round[{i}]/filter"):
                pass
    roll = span_rollup(tr.to_dict()["traceEvents"])
    assert roll["mine/round"]["count"] == 3
    assert roll["mine/round/filter"]["count"] == 3
    assert set(roll["mine/round"]) >= {"count", "total_s", "p50_s", "p95_s", "p99_s"}


def test_noop_tracer_is_allocation_free_default():
    assert current() is NOOP
    assert NOOP.span("x", a=1) is _NULL_SPAN
    with NOOP.span("x") as sp:
        sp.set(outcome="dropped")  # no-op, no state


# -- tracing transparency: traced mine ≡ untraced mine -----------------------


def _mine_fingerprint(ctx, tracer):
    plan = ShardPlan.simulated(2, block_n=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    with use_tracer(tracer):
        res = mrcbo(ctx, eng)
    s = eng.stats
    return {
        "keys": _keys(res.intents),
        "iterations": res.n_iterations,
        "closure_calls": s.closure_calls,
        "closures_computed": s.closures_computed,
        "modeled_comm_bytes": s.modeled_comm_bytes,
        "reduce_rounds": dict(s.reduce_rounds),
        "h2d": (s.h2d_transfers, s.h2d_bytes),
        "d2h": (s.d2h_transfers, s.d2h_bytes),
    }


def test_traced_mine_bit_identical_to_untraced(ctx):
    untraced = _mine_fingerprint(ctx, None)  # use_tracer(None) installs NOOP
    traced = _mine_fingerprint(ctx, Tracer())
    assert traced == untraced
    assert untraced["keys"] == _keys(all_closures_batched(ctx))


def test_mine_trace_validates_and_has_round_spans(ctx):
    plan = ShardPlan.simulated(2, block_n=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    tr = Tracer()
    with use_tracer(tr):
        mrcbo(ctx, eng)
    obj = json.loads(json.dumps(tr.to_dict()))
    validate_trace(obj)
    roll = span_rollup(obj["traceEvents"])
    for name in ("mine/mrcbo", "mine/round", "mine/round/allreduce",
                 "mine/round/filter", "engine/closure"):
        assert roll[name]["count"] >= 1, name
    # sync mine: no async windows, hence no speculative overlap
    assert not async_overlaps(obj)
    # round spans carry the shard-plan geometry tags
    b = next(e for e in obj["traceEvents"]
             if e["ph"] == "B" and e["name"].startswith("mine/round["))
    assert b["args"]["n_parts"] == 2 and b["args"]["mode"] == "sync"
    # engine invariant survives the instrumentation
    assert sum(eng.stats.reduce_rounds.values()) == eng.stats.closure_calls
    assert "round" in eng.stats.latency_percentiles


# -- async: overlap signature + transfer-census parity (satellite audit) -----


def _sync_async_pair(ctx, algo):
    out = []
    for mode in ("sync", "async"):
        plan = ShardPlan.simulated(2, block_n=64)
        eng = ClosureEngine(ctx, plan=plan, backend="jnp")
        tr = Tracer()
        with use_tracer(tr):
            res = algo(ctx, eng, rounds=mode)
        out.append((eng, res, tr))
    return out


def test_async_trace_shows_speculative_overlap(ctx):
    (_, res_s, _), (eng_a, res_a, tr_a) = _sync_async_pair(ctx, mrcbo)
    assert _keys(res_a.intents) == _keys(res_s.intents)
    obj = tr_a.to_dict()
    summary = validate_trace(obj)
    assert summary["async_spans"] >= res_a.n_iterations - 1
    ov = async_overlaps(obj)
    # the speculative signature: round r+1's dispatch begins while the
    # async window of round r is still in flight
    assert any(o["span"].startswith("spec/dispatch") for o in ov)
    roll = span_rollup(obj["traceEvents"])
    assert roll["spec/reconcile"]["count"] >= 1
    # every async round window ends with an outcome end-tag
    outcomes = [e["args"]["outcome"] for e in obj["traceEvents"]
                if e["ph"] == "e" and e.get("cat") == "round"]
    assert outcomes and set(outcomes) <= {"adopt", "fallback", "discard"}


def test_async_census_parity_charges_discarded_specs(ctx):
    """Every byte the async scheduler moves is charged like the sync path:
    the packed readback of a *discarded* speculative round still crossed
    the wire, so it appears in the d2h census (the pre-obs code dropped
    it)."""
    (eng_s, res_s, _), (eng_a, res_a, _) = _sync_async_pair(ctx, mrganter)
    assert _keys(res_a.intents) == _keys(res_s.intents)
    s = eng_a.stats
    # mrganter async: first closure readback (2 transfers) + exactly one
    # packed readback per speculative round — reconciled AND discarded
    assert s.d2h_transfers == 2 + s.spec_rounds
    assert s.spec_discarded >= 1  # the walk always over-speculates its end
    # each ganter spec packs [done, next_valid, Y_next] = (2 + W) words
    packed_bytes = s.spec_rounds * (2 + ctx.W) * 4
    assert s.d2h_bytes >= packed_bytes
    # the modeled collective traffic is mode-independent (same rounds run)
    assert s.modeled_comm_bytes == eng_s.stats.modeled_comm_bytes
    assert s.h2d_bytes == eng_s.stats.h2d_bytes


# -- query layer: stats view + extents charge --------------------------------


@pytest.fixture(scope="module")
def served(ctx):
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(2, block_n=16)
    store = ConceptStore.build(ctx, intents, plan=plan)
    return store, QueryEngine(store, QueryConfig(slots=8))


def test_query_stats_is_thin_view_over_census(served):
    import dataclasses

    _, qe = served
    assert isinstance(qe.stats, StatsBase)  # one census definition
    rng = np.random.default_rng(0)
    queries = qe.store.ctx.rows[rng.integers(0, qe.store.ctx.n_objects, 12)]
    qe.closure_batch(queries)
    d = dataclasses.asdict(qe.stats)
    # the public serve-JSON fields all survive, plus the percentile view
    for key in ("queries", "micro_batches", "collective_rounds",
                "modeled_comm_bytes", "by_type", "reduce_rounds",
                "auto_hop_bytes", "hop_calibrated", "latency_percentiles"):
        assert key in d, key
    assert set(d["latency_percentiles"]["micro_batch"]) == {"p50", "p95", "p99"}
    assert sum(d["reduce_rounds"].values()) == d["collective_rounds"]


def test_extents_allgather_is_charged(served):
    store, qe = served
    st = QueryStats()
    qe.stats = st
    ids = np.arange(5, dtype=np.int32)
    qe.extents_batch(ids)
    # one micro-batch (5 ≤ 8 slots): each of the k shards sends its
    # [Nl, slots] uint32 membership words to the other (k - 1) peers —
    # the whole-collective k·(k-1) convention modeled_comm_bytes uses
    k = qe.plan.n_parts
    n_local = store.state.N_padded // k
    expect = k * (k - 1) * n_local * qe.cfg.slots * 4
    assert st.modeled_comm_bytes == expect
    assert st.reduce_rounds == {"allgather": 1}
    assert st.collective_rounds == 1
    assert "micro_batch" in st.latency_percentiles


def test_extents_single_part_charges_nothing(ctx):
    intents = all_closures_batched(ctx)
    store = ConceptStore.build(ctx, intents, plan=ShardPlan.simulated(1))
    qe = QueryEngine(store, QueryConfig(slots=8))
    qe.extents_batch(np.arange(3, dtype=np.int32))
    assert qe.stats.modeled_comm_bytes == 0
    assert qe.stats.reduce_rounds == {}
