"""repro.rules — iceberg mining vs post-hoc filtering (property-tested
across drivers × shard counts × schedules), DG/Luxenburger bases vs host
brute-force oracles, and rule-query oracle equivalence."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import (
    ClosureEngine,
    all_closures_batched,
    bitset,
    mrcbo,
    mrganter,
    mrganter_plus,
)
from repro.core.closure import closure_np
from repro.core.context import FormalContext, paper_context
from repro.dist.shardplan import ShardPlan
from repro.query import ConceptStore, QueryEngine
from repro.query.engine import QueryConfig
from repro.query.store import host_supports
from repro.rules import (
    RuleBasis,
    RuleIndex,
    RuleSet,
    dg_basis,
    dg_basis_host,
    extract_bases,
    luxenburger_from_snapshot,
    luxenburger_host,
    mine_iceberg,
    resolve_min_support,
)

settings.register_profile("rules", deadline=None, max_examples=10)
settings.load_profile("rules")

DRIVERS = (mrganter, mrganter_plus, mrcbo)
PLANS = ((1, "rsag"), (2, "allgather"), (4, "auto"))


def _keys(intents):
    return {bitset.key_bytes(y) for y in np.asarray(intents, np.uint32)}


def _posthoc_ref(ctx, s):
    full = np.stack(all_closures_batched(ctx))
    sups = host_supports(ctx, full)
    return _keys(full[sups >= s])


# -- iceberg mining ----------------------------------------------------------


@given(
    st.integers(10, 40), st.integers(4, 12), st.floats(0.2, 0.5),
    st.integers(0, 10_000), st.floats(0.05, 0.6),
    st.integers(0, 2), st.integers(0, 2),
)
def test_iceberg_matches_posthoc_filter(n, m, density, seed, frac, di, pi):
    """Fused in-round pruning ≡ filtering the full lattice, for every
    driver, shard count, schedule, and pipeline."""
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    s = resolve_min_support(frac, n)
    ref = _posthoc_ref(ctx, s)
    driver = DRIVERS[di]
    n_parts, impl = PLANS[pi]
    for pipeline in ("device", "host"):
        eng = ClosureEngine(
            ctx,
            plan=ShardPlan.simulated(n_parts, reduce_impl=impl, block_n=8),
            backend="jnp",
        )
        res = driver(ctx, eng, pipeline=pipeline, min_support=s)
        assert _keys(res.intents) == ref
        assert res.min_support == s


def test_iceberg_prunes_rounds_and_bytes():
    """The acceptance shape: same concepts as post-hoc filtering, with
    fewer closures computed, fewer reduce bytes, and no more rounds."""
    ctx = FormalContext.synthetic(80, 16, 0.3, seed=11)
    s = resolve_min_support(0.25, ctx.n_objects)
    plan = ShardPlan.simulated(8, reduce_impl="rsag", block_n=8)
    e_full = ClosureEngine(ctx, plan=plan, backend="jnp")
    r_full = mrganter_plus(ctx, e_full, local_prune=True)
    e_ice = ClosureEngine(ctx, plan=plan, backend="jnp")
    r_ice = mrganter_plus(ctx, e_ice, local_prune=True, min_support=s)
    full = np.stack(r_full.intents)
    sups = host_supports(ctx, full)
    assert _keys(r_ice.intents) == _keys(full[sups >= s])
    assert len(r_ice.intents) < len(r_full.intents)
    assert e_ice.stats.closures_computed < e_full.stats.closures_computed
    assert e_ice.stats.modeled_comm_bytes < e_full.stats.modeled_comm_bytes
    assert r_ice.n_iterations <= r_full.n_iterations


def test_mrganter_iceberg_preserves_lectic_order():
    """The iceberg walk emits exactly the frequent subsequence of the full
    lectic enumeration, in the same order."""
    ctx = FormalContext.synthetic(30, 10, 0.35, seed=3)
    s = 5
    eng = ClosureEngine(ctx, plan=ShardPlan.simulated(2, block_n=8),
                        backend="jnp")
    full = mrganter(ctx, eng).intents
    sups = host_supports(ctx, np.stack(full))
    ref = [y for y, sp in zip(full, sups) if sp >= s]
    eng2 = ClosureEngine(ctx, plan=ShardPlan.simulated(2, block_n=8),
                         backend="jnp")
    ice = mrganter(ctx, eng2, min_support=s).intents
    assert len(ice) == len(ref)
    for a, b in zip(ice, ref):
        np.testing.assert_array_equal(a, b)


def test_min_support_validation():
    ctx = paper_context()
    eng = ClosureEngine(ctx, plan=ShardPlan.simulated(1), backend="jnp")
    with pytest.raises(ValueError, match="min_support"):
        mrganter_plus(ctx, eng, min_support=0)
    with pytest.raises(ValueError, match="min_support"):
        mrcbo(ctx, eng, min_support=2.5)
    assert resolve_min_support(0.5, 10) == 5
    assert resolve_min_support(0.001, 10) == 1  # fraction floor
    assert resolve_min_support(7, 10) == 7
    with pytest.raises(ValueError):
        resolve_min_support(-1, 10)
    with pytest.raises(ValueError):
        resolve_min_support(3.5, 10)
    # threshold above |O|: nothing is frequent, result is empty
    res = mine_iceberg(ctx, eng, min_support=ctx.n_objects + 1)
    assert res.n_concepts == 0


def test_store_iceberg_filter_matches_posthoc():
    ctx = FormalContext.synthetic(50, 14, 0.3, seed=6)
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(2, block_n=8)
    store = ConceptStore.build(ctx, intents, plan=plan)
    s = 8
    keep = store.snapshot.supports_np >= s
    ref = _keys(store.snapshot.intents_np[keep])
    ice = store.iceberg(s)
    assert _keys(ice.snapshot.intents_np) == ref
    np.testing.assert_array_equal(
        ice.snapshot.supports_np,
        host_supports(ctx, ice.snapshot.intents_np),
    )
    built = ConceptStore.build(ctx, intents, plan=plan, min_support=s)
    assert _keys(built.snapshot.intents_np) == ref


def test_empty_iceberg_family_end_to_end():
    """A threshold above |O| mines nothing; the store, bases and rule
    index must still build (multi-word contexts included — W > 1)."""
    ctx = FormalContext.synthetic(20, 40, 0.3, seed=5)  # 40 attrs → W = 2
    assert ctx.W > 1
    plan = ShardPlan.simulated(2, block_n=8)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mine_iceberg(ctx, eng, min_support=ctx.n_objects + 1)
    assert res.n_concepts == 0
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    assert store.snapshot.n_concepts == 0
    basis = extract_bases(store, min_conf=0.5)
    # with no family, ∅ already closes to M — exactly one implication
    assert basis.n_implications == 1 and basis.n_partial == 0
    assert basis.implications.premise.shape[1] == ctx.W
    index = RuleIndex.build(basis, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=8))
    ids, scores, cons = qe.rules_batch(index, ctx.rows[:3], k=2)
    assert ids.shape == (3, 2)
    # the ∅→M implication fires on every query
    assert np.all(ids[:, 0] == 0)


# -- Duquenne–Guigues base ---------------------------------------------------


@given(
    st.integers(5, 22), st.integers(3, 8), st.floats(0.2, 0.6),
    st.integers(0, 10_000), st.booleans(),
)
def test_dg_basis_matches_host_oracle(n, m, density, seed, iceberg):
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    intents = np.stack(all_closures_batched(ctx))
    sups = host_supports(ctx, intents)
    if iceberg:
        s = max(1, int(0.2 * n))
        intents, sups = intents[sups >= s], sups[sups >= s]
    dev = dg_basis(intents, sups, ctx.n_attrs, n_objects=ctx.n_objects)
    host = dg_basis_host(intents, ctx.n_attrs)
    np.testing.assert_array_equal(dev.premise, host.premise)
    np.testing.assert_array_equal(dev.added, host.added)


def test_dg_basis_sound_and_complete():
    """Sound: every implication holds in the context.  Complete: saturating
    any attrset under the base reproduces the context's '' closure."""
    ctx = FormalContext.synthetic(35, 9, 0.4, seed=1)
    intents = np.stack(all_closures_batched(ctx))
    sups = host_supports(ctx, intents)
    dg = dg_basis(intents, sups, ctx.n_attrs, n_objects=ctx.n_objects)
    mask = ctx.attr_mask()
    for p, a in zip(dg.premise, dg.added):
        ext_p = bitset.is_subset(p[None, :], ctx.rows).sum()
        ext_pa = bitset.is_subset((p | a)[None, :], ctx.rows).sum()
        assert ext_p == ext_pa  # premise and conclusion share the extent
        assert not np.any(p & a)  # added is disjoint from the premise

    def saturate(X):
        X = X.copy()
        changed = True
        while changed:
            changed = False
            for p, a in zip(dg.premise, dg.added):
                if bool(bitset.is_subset(p, X)) and not bool(
                    bitset.is_subset(a, X)
                ):
                    X |= a
                    changed = True
        return X

    rng = np.random.default_rng(0)
    for _ in range(25):
        X = bitset.pack_bool(rng.random(ctx.n_attrs) < 0.3, ctx.W)
        c_ref, _ = closure_np(ctx.rows, X, mask)
        np.testing.assert_array_equal(saturate(X), c_ref)


def test_dg_basis_premises_in_lectic_order_and_empty_family():
    ctx = paper_context()
    intents = np.stack(all_closures_batched(ctx))
    sups = host_supports(ctx, intents)
    dg = dg_basis(intents, sups, ctx.n_attrs, n_objects=ctx.n_objects)
    # lectic enumeration ⇒ premise popcounts never... (not monotone) but
    # premises are distinct and every conclusion is nonempty
    assert len({bitset.key_bytes(p) for p in dg.premise}) == len(dg)
    assert np.all(bitset.popcount(dg.added) > 0)
    empty = dg_basis(
        np.zeros((0, ctx.W), np.uint32), np.zeros((0,), np.int32),
        ctx.n_attrs, n_objects=ctx.n_objects,
    )
    # with no family, ∅ already closes to M: one implication ∅ → M
    assert len(empty) == 1
    assert bitset.popcount(empty.premise)[0] == 0


# -- Luxenburger base --------------------------------------------------------


@given(
    st.integers(6, 24), st.integers(3, 8), st.floats(0.2, 0.6),
    st.integers(0, 10_000), st.floats(0.0, 0.8), st.booleans(),
)
def test_luxenburger_matches_host_oracle(n, m, density, seed, min_conf, ice):
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    intents = all_closures_batched(ctx)
    store = ConceptStore.build(
        ctx, intents, plan=ShardPlan.simulated(2, block_n=8),
        min_support=max(1, int(0.15 * n)) if ice else None,
    )
    snap = store.snapshot
    dev = luxenburger_from_snapshot(snap, ctx.n_objects, min_conf=min_conf)
    host = luxenburger_host(
        snap.intents_np, snap.supports_np, ctx.n_objects, min_conf=min_conf
    )
    for f in ("premise", "added", "support", "confidence", "lift"):
        np.testing.assert_array_equal(getattr(dev, f), getattr(host, f))
    # basis semantics: strictly partial rules above the floor, correct conf
    assert np.all(dev.confidence < 1.0)
    assert np.all(dev.confidence >= np.float32(min_conf))
    for p, a, sp, cf in zip(
        dev.premise, dev.added, dev.support, dev.confidence
    ):
        s_p = bitset.is_subset(p[None, :], ctx.rows).sum()
        s_pa = bitset.is_subset((p | a)[None, :], ctx.rows).sum()
        assert s_pa == sp
        assert cf == np.float32(np.float64(s_pa) / np.float64(s_p))


# -- rule serving ------------------------------------------------------------


@pytest.fixture(scope="module")
def served_rules():
    ctx = FormalContext.synthetic(45, 12, 0.35, seed=8)
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(2, block_n=8)
    store = ConceptStore.build(ctx, intents, plan=plan)
    basis = extract_bases(store, min_conf=0.1)
    index = RuleIndex.build(basis, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=8))
    return ctx, basis, index, qe


def _rule_oracle(index, q, k, min_conf, metric):
    app = [
        r
        for r in range(index.n_rules)
        if bool(bitset.is_subset(index.premise_np[r], q))
        and index.confidence_np[r] >= np.float32(min_conf)
    ]
    ranked = sorted(app, key=lambda r: (-metric[r], r))[:k]
    ids = ranked + [-1] * (k - len(ranked))
    union = np.zeros(index.premise_np.shape[1], np.uint32)
    for r in app:
        union |= index.added_np[r]
    return ids, union


@pytest.mark.parametrize("rank_by", ["confidence", "lift"])
def test_rules_batch_vs_oracle(served_rules, rank_by):
    ctx, basis, index, qe = served_rules
    rng = np.random.default_rng(4)
    qs = ctx.rows[rng.integers(0, ctx.n_objects, 11)] & bitset.pack_bool(
        rng.random((11, ctx.n_attrs)) < 0.5, ctx.W
    )  # odd batch: exercises slot padding
    qs[0] = index.premise_np[0]  # guaranteed hit
    metric = (
        index.confidence_np if rank_by == "confidence" else index.lift_np
    )
    before_rounds = qe.stats.collective_rounds
    ids, scores, cons = qe.rules_batch(
        index, qs, k=4, min_conf=0.4, rank_by=rank_by
    )
    assert qe.stats.collective_rounds == before_rounds  # table read only
    for b, q in enumerate(qs):
        ref_ids, ref_union = _rule_oracle(index, q, 4, 0.4, metric)
        assert list(ids[b]) == ref_ids
        np.testing.assert_array_equal(cons[b], ref_union)
        for slot, r in enumerate(ids[b]):
            if r >= 0:
                assert scores[b, slot] == np.float32(metric[r])
            else:
                assert scores[b, slot] == -1.0


def test_rules_batch_edge_cases(served_rules):
    ctx, basis, index, qe = served_rules
    # empty batch: no dispatch, shapes preserved
    ids, scores, cons = qe.rules_batch(index, np.zeros((0, ctx.W), np.uint32))
    assert ids.shape == (0, 5) and cons.shape == (0, ctx.W)
    # min_conf above every rule: all misses, empty consequents
    qs = ctx.rows[:3]
    ids, scores, cons = qe.rules_batch(index, qs, k=3, min_conf=1.1)
    assert np.all(ids == -1) and np.all(scores == -1.0)
    assert not cons.any()
    with pytest.raises(ValueError, match="rank_by"):
        qe.rules_batch(index, qs, rank_by="support")
    # implications lead the combined table and rank first by confidence
    full_q = np.full((1, ctx.W), 0xFFFFFFFF, np.uint32)
    ids, scores, _ = qe.rules_batch(index, full_q, k=1, min_conf=0.0)
    if index.n_exact:
        assert scores[0, 0] == 1.0


def test_rule_index_shapes_and_pads(served_rules):
    _, basis, index, _ = served_rules
    assert index.n_rules == basis.n_implications + basis.n_partial
    assert index.cap >= index.n_rules and index.cap % 8 == 0
    assert np.all(index.confidence_np[: index.n_exact] == 1.0)
    assert np.all(index.confidence_np[index.n_exact :] < 1.0)


# -- end-to-end over the iceberg store --------------------------------------


def test_extract_bases_on_iceberg_store_consistent():
    """The iceberg family is intersection-closed, so φ is a closure
    operator and both bases stay well-defined; spot-check that rule math
    agrees with raw-context counting."""
    ctx = FormalContext.synthetic(60, 14, 0.3, seed=12)
    eng = ClosureEngine(ctx, plan=ShardPlan.simulated(4, block_n=8),
                        backend="jnp")
    res = mine_iceberg(ctx, eng, min_support=0.15, local_prune=True)
    store = ConceptStore.build(ctx, res.intents, plan=eng.plan)
    basis = extract_bases(store, min_conf=0.3)
    for p, a, sp in zip(
        basis.partial.premise, basis.partial.added, basis.partial.support
    ):
        assert bitset.is_subset((p | a)[None, :], ctx.rows).sum() == sp
    s = resolve_min_support(0.15, ctx.n_objects)
    assert np.all(basis.partial.support >= s)


# -- fraction→count boundary (the ceil-vs-floor off-by-one sweep) ------------


def test_resolve_min_support_exact_fraction_boundary():
    """A fraction that lands exactly on an integer support must resolve to
    that integer.  ``0.07 * 100 == 7.000000000000001`` in binary floating
    point, so a naive ceil resolved to 8 and silently dropped every
    concept with support exactly 7."""
    assert resolve_min_support(0.07, 100) == 7
    # exhaustive small grid: k/n · n == k for every representable pair
    for n in range(1, 60):
        for k in range(1, n):
            assert resolve_min_support(k / n, n) == k, (k, n)
    # non-boundary fractions still round UP (ceil semantics intact)
    assert resolve_min_support(0.071, 100) == 8
    assert resolve_min_support(0.55, 10) == 6


@given(
    st.integers(10, 36), st.integers(4, 10), st.floats(0.2, 0.5),
    st.integers(0, 10_000), st.integers(0, 2),
)
def test_fraction_equals_preresolved_count_across_drivers(
    n, m, density, seed, di
):
    """Mining with a fractional threshold ≡ mining with its pre-resolved
    absolute count, including fractions sitting exactly on a support
    boundary (k/n), for every driver."""
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    k = max(1, n // 3)
    frac = k / n  # exact boundary: resolves to k, never k+1
    s = resolve_min_support(frac, ctx.n_objects)
    assert s == k
    driver = DRIVERS[di]
    e_frac = ClosureEngine(ctx, plan=ShardPlan.simulated(2, block_n=8),
                           backend="jnp")
    r_frac = mine_iceberg(ctx, e_frac, min_support=frac,
                          algorithm=("mrganter", "mrganter+", "mrcbo")[di])
    e_abs = ClosureEngine(ctx, plan=ShardPlan.simulated(2, block_n=8),
                          backend="jnp")
    r_abs = driver(ctx, e_abs, min_support=s)
    assert _keys(r_frac.intents) == _keys(r_abs.intents)
    assert r_frac.min_support == s
    # and the boundary concepts are really kept: ≡ post-hoc filter at k
    assert _keys(r_abs.intents) == _posthoc_ref(ctx, k)


# -- rule-ranking determinism (tie-break by rule id) -------------------------


def _tied_index(plan=None):
    """A tiny hand-built index where ranks tie on purpose: three rules
    with identical confidence/lift firing on the same query."""
    W = 1
    prem = np.zeros((3, W), np.uint32)  # ∅ premise: fires everywhere
    added = np.array([[1], [2], [4]], np.uint32)
    rs = RuleSet(
        premise=prem,
        added=added,
        support=np.full((3,), 5, np.int32),
        confidence=np.full((3,), 0.5, np.float32),
        lift=np.full((3,), 1.25, np.float32),
    )
    basis = RuleBasis(
        n_objects=10, n_attrs=3, min_conf=0.0,
        implications=RuleSet.empty(W), partial=rs,
    )
    return RuleIndex.build(basis, plan=plan)


def test_rules_batch_breaks_ties_by_rule_id(served_rules):
    ctx, _, _, qe = served_rules
    assert ctx.W == 1  # the hand-built index shares the packed width
    index = _tied_index()
    q = np.zeros((1, ctx.W), np.uint32)
    ids, scores, _ = qe.rules_batch(index, q, k=3, min_conf=0.0,
                                    rank_by="lift")
    # all three tie on lift 1.25 → deterministic ascending rule id
    assert list(ids[0]) == [0, 1, 2]
    assert np.all(scores[0] == np.float32(1.25))


def test_rules_batch_invariant_to_slot_padding_and_plan(served_rules):
    """The ranked answer must not depend on the micro-batch slot width
    (query padding) or on the plan the index tables were placed through."""
    ctx, basis, _, _ = served_rules
    rng = np.random.default_rng(7)
    qs = ctx.rows[rng.integers(0, ctx.n_objects, 13)] & bitset.pack_bool(
        rng.random((13, ctx.n_attrs)) < 0.5, ctx.W
    )
    results = []
    for slots, plan in (
        (4, ShardPlan.simulated(1)),
        (13, ShardPlan.simulated(2, cand_parts=2)),
        (64, ShardPlan.simulated(4, reduce_impl="allgather")),
    ):
        store = ConceptStore.build(
            ctx, all_closures_batched(ctx),
            plan=ShardPlan.simulated(2, block_n=8),
        )
        index = RuleIndex.build(basis, plan=plan)
        qe = QueryEngine(store, QueryConfig(slots=slots))
        ids, scores, cons = qe.rules_batch(
            index, qs, k=5, min_conf=0.2, rank_by="lift"
        )
        results.append((ids, scores, cons))
    for ids, scores, cons in results[1:]:
        np.testing.assert_array_equal(ids, results[0][0])
        np.testing.assert_array_equal(scores, results[0][1])
        np.testing.assert_array_equal(cons, results[0][2])
