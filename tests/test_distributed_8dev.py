"""Multi-device tests — run in a subprocess with 8 fake CPU devices
(jax locks the device count at first init, so the main pytest process
cannot host these)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=420) -> str:
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_fca_mesh_matches_centralized():
    """Legacy (mesh=, axis_names=) engine kwargs route through a ShardPlan
    and still match the centralized oracle on a real pod×data mesh."""
    out = _run("""
        from repro.core import FormalContext, ClosureEngine, mrganter_plus, all_closures, bitset
        from repro.dist.shardplan import ShardPlan
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        fc = FormalContext.synthetic(300, 48, 0.2, seed=3)
        ref = {bitset.key_bytes(y) for y in all_closures(fc)}
        for impl in ("allgather", "rsag", "pmin"):
            eng = ClosureEngine(fc, mesh=mesh, axis_names=("pod", "data"), reduce_impl=impl, block_n=64)
            assert isinstance(eng.plan, ShardPlan) and eng.plan.n_parts == 8
            assert eng.plan.axis_names == ("pod", "data")
            res = mrganter_plus(fc, eng, dedupe_candidates=True)
            got = {bitset.key_bytes(y) for y in res.intents}
            assert got == ref, impl
        print("OK", len(ref))
    """)
    assert "OK" in out


def test_shardplan_local_pruning_matches_host_oracle():
    """MRGanter+ with per-partition local pruning on an 8-device ShardPlan:
    same concept set as the host-loop oracle, fewer reduce bytes than the
    no-pruning plan (the pruned candidates never enter the AND-allreduce),
    and bit-identical to the simulated plan of the same geometry."""
    out = _run("""
        from repro.core import FormalContext, ClosureEngine, mrganter_plus, bitset
        from repro.dist.shardplan import ShardPlan
        fc = FormalContext.synthetic(280, 40, 0.22, seed=11)
        mesh = jax.make_mesh((8,), ("data",))
        plan = ShardPlan.over_mesh(mesh, reduce_impl="rsag", block_n=64)
        assert plan.n_parts == 8 and plan.axis_names == ("data",)

        # host-loop oracle (same partition count, simulated)
        e_host = ClosureEngine(fc, n_parts=8, block_n=64, backend="jnp")
        ref = {bitset.key_bytes(y) for y in
               mrganter_plus(fc, e_host, pipeline="host").intents}

        e_on = ClosureEngine(fc, plan=plan, backend="jnp")
        r_on = mrganter_plus(fc, e_on, local_prune=True)
        assert {bitset.key_bytes(y) for y in r_on.intents} == ref

        e_off = ClosureEngine(fc, plan=plan, backend="jnp")
        r_off = mrganter_plus(fc, e_off, local_prune=False)
        assert {bitset.key_bytes(y) for y in r_off.intents} == ref
        assert e_on.stats.modeled_comm_bytes < e_off.stats.modeled_comm_bytes, (
            e_on.stats.modeled_comm_bytes, e_off.stats.modeled_comm_bytes)

        # mesh plan ≡ simulated plan, bit for bit
        e_sim = ClosureEngine(
            fc, plan=ShardPlan.simulated(8, reduce_impl="rsag", block_n=64),
            backend="jnp")
        r_sim = mrganter_plus(fc, e_sim, local_prune=True)
        a = sorted(y.tobytes() for y in r_on.intents)
        b = sorted(y.tobytes() for y in r_sim.intents)
        assert a == b
        print("OK", len(ref), e_off.stats.modeled_comm_bytes,
              "->", e_on.stats.modeled_comm_bytes)
    """)
    assert "OK" in out


def test_cand_axis_2d_mesh_matches_oracle():
    """Frontier-axis sharding on a real cand×data mesh (2 candidate blocks
    × 4 object shards over 8 devices): same concept set as the host-loop
    oracle, bit-identical to the simulated 2-D twin, modeled reduce bytes
    below the 1-D 8-shard plan at the same total device count, and a
    frontier far beyond max_batch mined completely (the _adopt truncation
    regression, on the mesh path)."""
    out = _run("""
        from repro.core import FormalContext, ClosureEngine, mrganter_plus, mrcbo, bitset
        from repro.dist.shardplan import ShardPlan
        from repro.query.store import host_supports

        fc = FormalContext.synthetic(280, 40, 0.22, seed=11)
        mesh = jax.make_mesh((2, 4), ("cand", "data"))
        plan = ShardPlan.over_mesh(mesh, reduce_impl="rsag", block_n=64,
                                   max_batch=128)
        assert plan.n_parts == 4 and plan.cand_parts == 2
        assert plan.axis_names == ("data",) and plan.cand_axis_names == ("cand",)

        # host-loop oracle
        e_host = ClosureEngine(fc, n_parts=4, block_n=64, backend="jnp")
        ref = {bitset.key_bytes(y) for y in
               mrganter_plus(fc, e_host, pipeline="host").intents}

        e_2d = ClosureEngine(fc, plan=plan, backend="jnp")
        r_2d = mrganter_plus(fc, e_2d, local_prune=True)
        assert {bitset.key_bytes(y) for y in r_2d.intents} == ref
        # the peak frontier really exceeded one device's chunk budget
        assert len(ref) > plan.max_batch

        # bit-identical to the simulated 2-D twin, modeled bytes included
        e_sim = ClosureEngine(
            fc, plan=ShardPlan.simulated(4, cand_parts=2, block_n=64,
                                         max_batch=128), backend="jnp")
        r_sim = mrganter_plus(fc, e_sim, local_prune=True)
        assert sorted(y.tobytes() for y in r_2d.intents) == sorted(
            y.tobytes() for y in r_sim.intents)
        assert e_2d.stats.modeled_comm_bytes == e_sim.stats.modeled_comm_bytes

        # 2-D beats the 1-D plan over the same 8 devices on modeled bytes
        mesh1d = jax.make_mesh((8,), ("data",))
        e_1d = ClosureEngine(
            fc, plan=ShardPlan.over_mesh(mesh1d, reduce_impl="rsag",
                                         block_n=64, max_batch=256),
            backend="jnp")
        r_1d = mrganter_plus(fc, e_1d, local_prune=True)
        assert {bitset.key_bytes(y) for y in r_1d.intents} == ref
        assert e_2d.stats.modeled_comm_bytes < e_1d.stats.modeled_comm_bytes, (
            e_2d.stats.modeled_comm_bytes, e_1d.stats.modeled_comm_bytes)

        # mrcbo + fused iceberg on the 2-D mesh
        full = np.stack(r_2d.intents)
        sups = host_supports(fc, full)
        want = {bitset.key_bytes(y) for y in full[sups >= 30]}
        e_ice = ClosureEngine(fc, plan=plan, backend="jnp")
        r_ice = mrcbo(fc, e_ice, min_support=30)
        assert {bitset.key_bytes(y) for y in r_ice.intents} == want
        print("OK", len(ref), e_1d.stats.modeled_comm_bytes,
              "->", e_2d.stats.modeled_comm_bytes)
    """)
    assert "OK" in out


def test_async_rounds_on_real_mesh():
    """The speculative double-buffered scheduler on real 8-device meshes —
    1-D (8×1 object shards) and 2-D (2 cand × 4 obj): identical concept
    sets and iteration counts to the sync oracle, for every driver, with
    the speculative machinery demonstrably engaged.  The sim twin of this
    test is tests/test_async_rounds.py."""
    out = _run("""
        from repro.core import (FormalContext, ClosureEngine, mrcbo,
                                mrganter, mrganter_plus, bitset)
        from repro.dist.shardplan import ShardPlan

        fc = FormalContext.synthetic(280, 40, 0.22, seed=11)
        mesh1d = jax.make_mesh((8,), ("data",))
        mesh2d = jax.make_mesh((2, 4), ("cand", "data"))
        plans = [
            ShardPlan.over_mesh(mesh1d, reduce_impl="rsag", block_n=64),
            ShardPlan.over_mesh(mesh2d, reduce_impl="rsag", block_n=64,
                                max_batch=128),
        ]
        host = ClosureEngine(fc, n_parts=8, block_n=64, backend="jnp")
        ref = {bitset.key_bytes(y) for y in
               mrganter_plus(fc, host, pipeline="host").intents}
        grid = [(mrganter_plus, {"local_prune": True}), (mrcbo, {}),
                (mrganter, {"max_iterations": 40})]
        for plan in plans:
            for algo, kw in grid:
                es = ClosureEngine(fc, plan=plan, backend="jnp")
                ea = ClosureEngine(fc, plan=plan, backend="jnp")
                rs = algo(fc, es, rounds="sync", **kw)
                ra = algo(fc, ea, rounds="async", **kw)
                ks = {bitset.key_bytes(y) for y in rs.intents}
                ka = {bitset.key_bytes(y) for y in ra.intents}
                assert ka == ks, (algo.__name__, plan.cand_parts)
                assert ra.n_iterations == rs.n_iterations
                assert ea.stats.spec_rounds > 0
                if algo is mrganter_plus:
                    assert ks == ref
        # 2-D async under a tiny chunk budget: fallback path on the mesh
        tiny = ShardPlan.over_mesh(mesh2d, reduce_impl="rsag", block_n=64,
                                   max_batch=16)
        e_t = ClosureEngine(fc, plan=tiny, backend="jnp")
        r_t = mrganter_plus(fc, e_t, rounds="async", local_prune=True)
        assert {bitset.key_bytes(y) for y in r_t.intents} == ref
        assert e_t.stats.spec_fallbacks >= 1
        print("OK", len(ref), e_t.stats.spec_fallbacks)
    """, timeout=560)
    assert "OK" in out


def test_collectives_and_allreduce_property():
    """allgather/rsag/pmin are bit-identical AND-reductions across shard
    counts {2, 4, 8} and ragged batch sizes, on real device meshes."""
    out = _run("""
        from functools import partial
        from repro.dist import collectives
        from repro.dist.shardplan import ShardPlan
        from jax.sharding import Mesh

        rng = np.random.default_rng(0)
        devices = jax.devices()
        W = 3
        for k in (2, 4, 8):
            mesh = Mesh(np.asarray(devices[:k]), ("data",))
            plan = ShardPlan.over_mesh(mesh)
            sim = ShardPlan.simulated(k)
            for B in (1, 5, 16, 33):   # ragged: exercises the rsag pad path
                x = rng.integers(0, 1 << 32, size=(k, B, W), dtype=np.uint32)
                ref = x[0].copy()
                for i in range(1, k):
                    ref &= x[i]
                # shard the k blocks over the k devices: [k*B, W] with
                # rows sharded → each shard sees its own [B, W] block
                flat = jnp.asarray(x.reshape(k * B, W))
                for impl in ("allgather", "rsag", "pmin"):
                    body = partial(
                        collectives.and_allreduce, impl=impl,
                        n_attrs=W * 32 - 7)
                    got = jax.jit(plan.spmd(
                        lambda xi: body(xi, plan.reduce_axes), n_rep=0))(flat)
                    got_sim = jax.jit(sim.spmd(
                        lambda xi: body(xi, sim.reduce_axes), n_rep=0))(
                        jnp.asarray(x))
                    want = ref
                    if impl == "pmin":  # pmin masks to the n_attrs bound
                        mask = np.zeros(W * 32, np.uint32)
                        mask[: W * 32 - 7] = 1
                        weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))
                        want = ((((ref[..., None] >> np.arange(32, dtype=np.uint32))
                                  & 1).reshape(B, W * 32) * mask
                                 ).reshape(B, W, 32) * weights
                                ).sum(-1).astype(np.uint32)
                    np.testing.assert_array_equal(np.asarray(got), want, err_msg=f"{impl} k={k} B={B}")
                    np.testing.assert_array_equal(np.asarray(got_sim), want, err_msg=f"sim {impl} k={k} B={B}")
        print("OK")
    """)
    assert "OK" in out


def test_iceberg_and_rules_on_real_mesh():
    """Fused iceberg pruning + the rules subsystem on a real 8-device
    shard_map mesh: identical to post-hoc filtering on the simulated plan,
    device extent build (mixed out-specs) matches the host oracle, and the
    rule bases agree with the brute-force oracles."""
    out = _run("""
        from repro.core import FormalContext, ClosureEngine, mrganter_plus, mrcbo, bitset
        from repro.core.closure import extent_np
        from repro.dist.shardplan import ShardPlan
        from repro.query import ConceptStore
        from repro.query.store import host_supports
        from repro.rules import (dg_basis, dg_basis_host, extract_bases,
                                 luxenburger_host)
        fc = FormalContext.synthetic(160, 24, 0.25, seed=5)
        mesh = jax.make_mesh((8,), ("data",))
        plan = ShardPlan.over_mesh(mesh, reduce_impl="rsag", block_n=16)
        s = 24
        e_full = ClosureEngine(fc, plan=plan, backend="jnp")
        full = np.stack(mrganter_plus(fc, e_full, local_prune=True).intents)
        sups = host_supports(fc, full)
        ref = {bitset.key_bytes(y) for y in full[sups >= s]}
        for driver in (mrganter_plus, mrcbo):
            e_ice = ClosureEngine(fc, plan=plan, backend="jnp")
            r = driver(fc, e_ice, min_support=s)
            assert {bitset.key_bytes(y) for y in r.intents} == ref, driver
        assert e_ice.stats.modeled_comm_bytes < e_full.stats.modeled_comm_bytes

        store = ConceptStore.build(fc, r.intents, plan=plan)
        snap = store.snapshot
        np.testing.assert_array_equal(
            snap.supports_np, host_supports(fc, snap.intents_np))
        # device-side extent build on the mesh vs host oracle
        from repro.query import QueryEngine
        from repro.query.engine import QueryConfig
        qe = QueryEngine(store, QueryConfig(slots=16))
        packed = qe.extents_batch(np.arange(snap.n_concepts, dtype=np.int32))
        for c in range(snap.n_concepts):
            got = bitset.unpack_bits(packed[c], store.N_padded)
            assert np.array_equal(got[:fc.n_objects],
                                  extent_np(fc.rows, snap.intents_np[c]))
        basis = extract_bases(store, min_conf=0.4)
        host_dg = dg_basis_host(snap.intents_np, fc.n_attrs)
        np.testing.assert_array_equal(basis.implications.premise, host_dg.premise)
        np.testing.assert_array_equal(basis.implications.added, host_dg.added)
        host_lux = luxenburger_host(
            snap.intents_np, snap.supports_np, fc.n_objects, min_conf=0.4)
        np.testing.assert_array_equal(basis.partial.premise, host_lux.premise)
        np.testing.assert_array_equal(basis.partial.confidence, host_lux.confidence)
        print("OK", len(ref), basis.n_implications, basis.n_partial)
    """)
    assert "OK" in out


def test_moe_ep_shardmap_matches_pjit():
    out = _run("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import moe, transformer
        from repro.dist.partition import Partitioner
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("arctic-480b").reduced()
        # capacity_factor 8 ⇒ no token drops on either path (exact compare);
        # exact=False so the EP shard_map path is the one exercised.
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=8, capacity_factor=8.0))
        params_tree = transformer.init_model(cfg, jax.random.key(0))
        from repro.models.layers import split_params
        params, _ = split_params(params_tree)
        p = params["layers"]["block0"]["moe"]
        p = jax.tree_util.tree_map(lambda v: v[0], p)  # un-stack one layer
        x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model), jnp.float32)
        y_ref, aux_ref = moe.moe_fwd(p, x, cfg, shard=None, exact=False)
        part = Partitioner(mesh)
        y_ep, aux_ep = jax.jit(lambda p_, x_: moe.moe_fwd(p_, x_, cfg, shard=part, exact=False))(p, x)
        err = float(jnp.max(jnp.abs(y_ep - y_ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    out = _run("""
        import tempfile
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, tree)
        sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored = restore_checkpoint(d, 1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
        assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_and_compression():
    out = _run("""
        from repro.dist.pipeline import pipeline_apply
        from repro.dist.compression import make_ddp_step
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        # pipeline equivalence
        Ws = jax.random.normal(jax.random.key(0), (2, 8, 8)) * 0.3
        stage_fn = lambda W, x: jnp.tanh(x @ W)
        x = jax.random.normal(jax.random.key(1), (6, 4, 8))
        outp = pipeline_apply(stage_fn, Ws, x, mesh, axis_name="model")
        ref = x
        for s in range(2):
            ref = jax.vmap(lambda xi: stage_fn(Ws[s], xi))(ref)
        assert jnp.allclose(outp, ref, atol=1e-5)
        # compressed DDP convergence
        target = jax.random.normal(jax.random.key(2), (32,))
        def vag(params, batch):
            f = lambda p: jnp.mean((batch["x"] @ p["w"] - batch["x"] @ target) ** 2)
            return jax.value_and_grad(f)(params)
        step, init_err = make_ddp_step(vag, mesh, lr=0.03, axis_name="data")
        params = {"w": jnp.zeros((32,))}
        err = init_err(params)
        rng = np.random.default_rng(0)
        for _ in range(400):
            X = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
            params, err, loss = step(params, err, {"x": X})
        assert float(loss) < 1e-4, float(loss)
        print("OK", float(loss))
    """)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a small mesh + FCA cell."""
    out = _run("""
        from repro.launch.dryrun_lib import run_fca_cell
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(data=4, model=2)
        r = run_fca_cell(mesh, "4x2", n_objects=1 << 14, n_attrs=512, batch=256)
        assert r["status"] == "ok", r
        assert r["flops_per_device"] > 0
        assert r["collective_bytes_per_device"] > 0
        print("OK", int(r["flops_per_device"]))
    """)
    assert "OK" in out


def test_train_step_sharded_end_to_end():
    """Real sharded train steps on an 8-device mesh: loss decreases."""
    out = _run("""
        from repro.configs import get_config
        from repro.models import transformer
        from repro.models.config import ShapeConfig
        from repro.dist.partition import Partitioner
        from repro.train import step as tstep
        from repro.train.optim import get_optimizer, warmup_cosine
        from repro.data.lm_data import make_batch_iterator

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("mamba2-370m").reduced()
        shape = ShapeConfig("t", "train", 32, 8)
        part = Partitioner(mesh, fsdp=True)
        params, axes = transformer.init_params(cfg, seed=0)
        opt = get_optimizer("adamw", warmup_cosine(2e-2, 2, 60))
        state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
        sh = tstep.state_shardings(part, axes, jax.tree_util.tree_map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params), opt)
        state = jax.device_put(state, sh)
        step_fn = jax.jit(tstep.make_train_step(cfg, opt, part), in_shardings=(sh, None), donate_argnums=0)
        it = make_batch_iterator(cfg, shape, seed=0)
        losses = []
        for _ in range(25):
            _, batch = next(it)
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.1, (first, last)
        print("OK", first, "->", last)
    """)
    assert "OK" in out
