"""Incremental object addition == batch remining (paper §1.1 motivation)."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import all_closures_batched, bitset
from repro.core.context import FormalContext
from repro.core.incremental import (
    add_object,
    add_objects,
    add_objects_sequential,
    row_intersections,
)

settings.register_profile("inc", deadline=None, max_examples=25)
settings.load_profile("inc")


def _keys(intents):
    return {bitset.key_bytes(y) for y in np.asarray(intents, dtype=np.uint32)}


def test_paper_example_grown_incrementally():
    """Build Table 1 row by row; final lattice == Table 2's 21 concepts."""
    from repro.core.context import paper_context

    full = paper_context()
    ctx = FormalContext(rows=full.rows[:1], n_objects=1, n_attrs=7)
    intents = np.stack(all_closures_batched(ctx))
    ctx, intents = add_objects(ctx, intents, full.rows[1:])
    assert ctx.n_objects == 6
    assert _keys(intents) == _keys(all_closures_batched(full))
    assert len(intents) == 21


@given(
    st.integers(2, 40), st.integers(1, 16), st.floats(0.1, 0.6),
    st.integers(0, 10_000), st.integers(1, 6),
)
def test_incremental_equals_batch(n, m, density, seed, k_new):
    full = FormalContext.synthetic(n + k_new, m, density, seed=seed)
    base = FormalContext(rows=full.rows[:n], n_objects=n, n_attrs=m)
    intents = np.stack(all_closures_batched(base))
    grown_ctx, grown = add_objects(base, intents, full.rows[n:])
    assert _keys(grown) == _keys(all_closures_batched(full))
    assert np.array_equal(grown_ctx.rows, full.rows)


@given(
    st.integers(2, 30), st.integers(1, 14), st.floats(0.1, 0.6),
    st.integers(0, 10_000), st.integers(1, 6),
)
def test_batched_equals_sequential_oracle(n, m, density, seed, k_new):
    """The one-pass batched ``add_objects`` must match the per-row Godin
    loop exactly — including on *non-closed* seed intent sets, where the
    full-attribute intent M is absent."""
    full = FormalContext.synthetic(n + k_new, m, density, seed=seed)
    base = FormalContext(rows=full.rows[:n], n_objects=n, n_attrs=m)
    intents = np.stack(all_closures_batched(base))
    c1, g1 = add_objects(base, intents, full.rows[n:])
    c2, g2 = add_objects_sequential(base, intents, full.rows[n:])
    assert _keys(g1) == _keys(g2)
    assert np.array_equal(c1.rows, c2.rows)
    # non-closed seed: just the base rows themselves
    seed_set = np.unique(base.rows, axis=0)
    _, g3 = add_objects(base, seed_set, full.rows[n:])
    _, g4 = add_objects_sequential(base, seed_set, full.rows[n:])
    assert _keys(g3) == _keys(g4)


@given(
    st.integers(1, 6), st.integers(1, 10), st.floats(0.2, 0.7),
    st.integers(0, 10_000),
)
def test_row_intersections_is_all_subset_meets(k, m, density, seed):
    rows = FormalContext.synthetic(k, m, density, seed=seed).rows
    P = row_intersections(rows)
    ref = set()
    for mask in range(1, 2**k):
        sel = [rows[i] for i in range(k) if (mask >> i) & 1]
        ref.add(bitset.key_bytes(np.bitwise_and.reduce(np.stack(sel), axis=0)))
    assert _keys(P) == ref
    assert P.shape[0] == len(ref)  # deduped


def test_incremental_much_cheaper_than_remine():
    """The point of incrementality: adding one object touches O(|F|·W)
    words, not a full NextClosure pass."""
    ctx = FormalContext.synthetic(300, 40, 0.2, seed=1)
    intents = np.stack(all_closures_batched(ctx))
    new_row = FormalContext.synthetic(1, 40, 0.2, seed=2).rows[0]
    import time

    t0 = time.perf_counter()
    ctx2, grown = add_object(ctx, intents, new_row)
    t_inc = time.perf_counter() - t0
    t0 = time.perf_counter()
    remined = all_closures_batched(ctx2)
    t_full = time.perf_counter() - t0
    assert _keys(grown) == _keys(remined)
    assert t_inc < t_full / 5, (t_inc, t_full)
