"""MR* drivers vs centralized baselines on random contexts (simulated
partitions; the real mesh path is exercised in test_distributed_8dev.py)."""

import numpy as np
import pytest

from repro.core import (
    ClosureEngine,
    all_closures,
    all_closures_batched,
    bitset,
    close_by_one,
    mrcbo,
    mrganter,
    mrganter_plus,
)
from repro.core.context import FormalContext


def _keyset(intents):
    return {bitset.key_bytes(y) for y in intents}


@pytest.fixture(scope="module")
def ctxs():
    return [
        FormalContext.synthetic(50, 12, 0.3, seed=1),
        FormalContext.synthetic(120, 24, 0.15, seed=2),
        FormalContext.synthetic(33, 17, 0.5, seed=3),
    ]


def test_nextclosure_matches_brute_force():
    ctx = FormalContext.synthetic(20, 8, 0.4, seed=5)
    mask = ctx.attr_mask()
    from repro.core.closure import closure_np

    brute = set()
    for s in range(1 << ctx.n_attrs):
        y = bitset.from_indices({a for a in range(8) if (s >> a) & 1}, 8)
        c, _ = closure_np(ctx.rows, y, mask)
        brute.add(bitset.key_bytes(c))
    assert _keyset(all_closures(ctx)) == brute


def test_batched_equals_scalar_nextclosure(ctxs):
    for ctx in ctxs:
        a = all_closures(ctx)
        b = all_closures_batched(ctx)
        assert len(a) == len(b)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))


def test_closebyone_matches_nextclosure(ctxs):
    for ctx in ctxs:
        assert _keyset(close_by_one(ctx).intents) == _keyset(all_closures(ctx))


@pytest.mark.parametrize("n_parts", [1, 3, 4])
@pytest.mark.parametrize("impl", ["allgather", "rsag", "pmin"])
def test_mrganter_plus_matches(ctxs, n_parts, impl):
    ctx = ctxs[1]
    ref = _keyset(all_closures_batched(ctx))
    eng = ClosureEngine(ctx, n_parts=n_parts, reduce_impl=impl, block_n=64)
    res = mrganter_plus(ctx, eng)
    assert _keyset(res.intents) == ref
    assert res.n_concepts == len(ref)


def test_mrganter_lectic_order_preserved(ctxs):
    """MRGanter must emit concepts in exactly NextClosure's lectic order."""
    ctx = ctxs[0]
    ref = all_closures_batched(ctx)
    res = mrganter(ctx, ClosureEngine(ctx, n_parts=3, block_n=64))
    assert len(res.intents) == len(ref)
    assert all(np.array_equal(a, b) for a, b in zip(res.intents, ref))


def test_mrcbo_levels_match_closebyone(ctxs):
    for ctx in ctxs:
        cbo = close_by_one(ctx)
        res = mrcbo(ctx, ClosureEngine(ctx, n_parts=2, block_n=64))
        assert _keyset(res.intents) == _keyset(cbo.intents)
        # +1 for the ∅'' round; ±1 depending on where the empty frontier
        # is detected (before vs after the final expansion round).
        assert res.n_iterations in (cbo.n_iterations, cbo.n_iterations + 1)


def test_dedupe_candidates_same_output_fewer_closures(ctxs):
    ctx = ctxs[1]
    e1 = ClosureEngine(ctx, n_parts=2, block_n=64)
    r1 = mrganter_plus(ctx, e1, dedupe_candidates=False)
    e2 = ClosureEngine(ctx, n_parts=2, block_n=64)
    r2 = mrganter_plus(ctx, e2, dedupe_candidates=True)
    assert _keyset(r1.intents) == _keyset(r2.intents)
    assert r2.n_closures_computed <= r1.n_closures_computed


def test_object_shuffle_balances_density():
    """Paper §5.2's suggested improvement: shuffled partitions have more
    even density than contiguous ones on a sorted-by-density context."""
    rng = np.random.default_rng(0)
    dense = rng.random((400, 30)) < np.linspace(0.05, 0.6, 400)[:, None]
    ctx = FormalContext.from_dense(dense)
    spread = lambda parts: np.ptp([p.density for p in parts])
    assert spread(ctx.partition(4, shuffle=True, seed=1)) < spread(ctx.partition(4))


def test_engine_stats_accounting(ctxs):
    ctx = ctxs[0]
    eng = ClosureEngine(ctx, n_parts=4, reduce_impl="allgather", block_n=64)
    res = mrganter_plus(ctx, eng, dedupe_candidates=True)
    assert eng.stats.closure_calls > 0
    assert eng.stats.closures_computed >= res.n_concepts - 1
    assert res.modeled_comm_bytes > 0
