"""Concept-lattice query service: mine once, serve forever, update in place.

    PYTHONPATH=src python examples/fca_query_service.py \
        --dataset mushroom --scale 0.01 --parts 4 --reduce auto

Demonstrates the repro.query subsystem end to end:

  1. mine the dataset with MRGanter+ on a ShardPlan (device pipeline);
  2. build the device-resident ConceptStore on the *same* plan — intent
     table + two-level hash index replicated, context rows and extent
     table object-sharded, covering relation from the subset-test matmul;
  3. serve micro-batched queries (closure-of-attrset with concept lookup,
     top-k-by-support, covering-relation traversal, packed extents) —
     each micro-batch is one SPMD collective round;
  4. stream a batch of new objects through the Godin-style device
     insertion: queries keep working between ``stage()`` and ``commit()``,
     and after the swap the grown lattice serves bit-identically to a
     from-scratch remine (asserted below).
"""

import argparse
import time

import numpy as np

from repro.core import ClosureEngine, all_closures_batched, bitset, mrganter_plus
from repro.data import fca_datasets
from repro.dist.shardplan import ShardPlan
from repro.query import ConceptStore, QueryEngine, StreamUpdater
from repro.query.engine import QueryConfig


def main(dataset="mushroom", scale=0.01, parts=4, reduce_impl="auto",
         queries=256, updates=6, seed=0):
    ctx, spec = fca_datasets.load(dataset, scale=scale)
    print(f"{dataset}: {spec.n_objects} objects × {spec.n_attrs} attrs "
          f"@ {spec.density:.3f} density")

    plan = ShardPlan.simulated(parts, reduce_impl=reduce_impl)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    print(f"mined {res.n_concepts} concepts in {res.n_iterations} rounds "
          f"({res.wall_time_s:.2f}s)")

    t0 = time.perf_counter()
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=64, backend="jnp"))
    print(f"store built in {time.perf_counter() - t0:.2f}s: "
          f"{store.describe()}")

    rng = np.random.default_rng(seed)
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=queries)]
    keep = bitset.pack_bool(rng.random((queries, ctx.n_attrs)) < 0.25, ctx.W)
    attrsets = base & keep

    qe.closure_batch(attrsets[:64])  # warm the compiled steps
    t0 = time.perf_counter()
    closures, supports, ids = qe.closure_batch(attrsets)
    dt = time.perf_counter() - t0
    print(f"closure×{queries}: {queries / dt:,.0f} q/s, "
          f"hit rate {(ids >= 0).mean():.2f}, "
          f"{qe.stats.collective_rounds} collective rounds "
          f"(schedule: {qe.stats.reduce_rounds})")

    tops, tvals = qe.topk_batch(attrsets[:32], k=5)
    kids = qe.children(ids[ids >= 0][:5])
    print(f"top-5 support of query 0: {tvals[0].tolist()}; "
          f"children counts sample: {[len(k) for k in kids]}")

    # streaming: stage, query mid-flight, commit, verify vs remine
    upd = StreamUpdater(store)
    new_rows = bitset.pack_bool(
        rng.random((updates, ctx.n_attrs)) < max(0.05, spec.density), ctx.W)
    receipt = upd.stage(new_rows)
    mid_ids = qe.lookup_batch(closures)  # still serving the OLD snapshot
    assert np.array_equal(mid_ids, ids), "stage must not disturb serving"
    upd.commit()
    print(f"streamed {updates} objects: {receipt.n_concepts_before} → "
          f"{receipt.n_concepts_after} concepts, "
          f"staged in {receipt.stage_wall_s:.2f}s "
          f"(|P|={receipt.n_intersections})")

    ref = all_closures_batched(store.ctx)
    same = {bitset.key_bytes(y) for y in ref} == {
        bitset.key_bytes(y) for y in store.snapshot.intents_np
    }
    print(f"grown lattice == batch NextClosure remine: {same}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="mushroom",
                   choices=list(fca_datasets.PAPER_DATASETS))
    p.add_argument("--scale", type=float, default=0.01)
    p.add_argument("--parts", type=int, default=4)
    p.add_argument("--reduce", default="auto")
    p.add_argument("--queries", type=int, default=256)
    p.add_argument("--updates", type=int, default=6)
    a = p.parse_args()
    main(dataset=a.dataset, scale=a.scale, parts=a.parts,
         reduce_impl=a.reduce, queries=a.queries, updates=a.updates)
