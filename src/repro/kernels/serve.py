"""Fused serving kernels — the QueryEngine's subset test → mask → top-k
as one VMEM-resident Pallas pass (ISSUE 6 tentpole, serving side).

``QueryEngine.topk_batch`` and ``rules_batch`` both run the same shape of
computation over a replicated table: a bitwise subset test per (query,
table-row) pair, a validity/threshold mask, then k unrolled selection
passes.  As jnp ops the ``[slots, rows]`` score matrix and the
``[slots, rows, W]`` subset intermediate round-trip through HBM between
stages; these kernels keep the query block and the whole table VMEM-
resident from the subset test to the packed top-k result.

``contains_topk_call``
    ``topk_batch``'s post stage: concepts whose intent ⊇ the (closed)
    query == subconcepts of closure(attrset), masked top-k by support.

``rules_topk_call``
    ``rules_batch``: premise ⊆ query test, confidence/validity mask, the
    firing rules' consequent union, and metric top-k with the rule-id
    tie-break.

Both mirror the jnp steps in :mod:`repro.query.engine` bit-for-bit (the
unrolled argmax/max passes use the identical mask-and-repeat recurrence;
the in-kernel ``where(iota == pos)`` scatter equals ``.at[rows, pos].set``
because ``pos`` is unique per row).  Oversized tables fall back to the jnp
step — see :func:`supports_serve`.  Interpret-mode equivalence is asserted
in tests/test_fused_frontier.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro import compat
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.closure import MAX_W

# Queries per grid step (the slot axis is blocked; tables ride whole).
DEFAULT_S_BLK = 8

# Table-size ceiling for the VMEM-resident path: rows × words of the
# replicated table a single grid step holds.  ~16 MiB of uint32 at the
# cap — beyond it the jnp step is the right tool (its score matrix tiles
# naturally under XLA), so callers fall back rather than thrash VMEM.
MAX_TABLE_CELLS = 1 << 22


def supports_serve(backend: str, n_rows: int, W: int, slots: int) -> bool:
    """Whether the fused serving kernels can serve this table/batch shape."""
    return (
        backend == "kernel"
        and W <= MAX_W
        and n_rows * max(W, 1) <= MAX_TABLE_CELLS
        and slots % DEFAULT_S_BLK == 0
    )


def _topk_int(scores, k):
    """k unrolled argmax passes over int scores [S, C] → (idx, vals).

    Same order as lax.top_k (desc value, asc index on ties); the repeat
    recurrence masks the taken cell with -2 < every live score ≥ -1.
    """
    C = scores.shape[1]
    col = lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    ids, vals = [], []
    for _ in range(k):
        idx = jnp.argmax(scores, axis=1).astype(jnp.int32)
        val = jnp.max(scores, axis=1)  # == scores[row, argmax] by definition
        ids.append(idx)
        vals.append(val)
        scores = jnp.where(col == idx[:, None], jnp.int32(-2), scores)
    vals = jnp.stack(vals, axis=1)
    idx = jnp.stack(ids, axis=1)
    idx = jnp.where(vals >= 0, idx, -1)
    return idx, jnp.maximum(vals, -1)


def _contains_topk_kernel(k, s_ref, gc_ref, int_ref, sup_ref,
                          out_i_ref, out_v_ref):
    gc = gc_ref[...]  # [bs, W]
    intents = int_ref[...]  # [C, W]
    C = intents.shape[0]
    contains = jnp.all((gc[:, None, :] & ~intents[None, :, :]) == 0, axis=-1)
    valid = lax.broadcasted_iota(jnp.int32, (1, C), 1) < s_ref[0]
    scores = jnp.where(contains & valid, sup_ref[...], jnp.int32(-1))
    idx, vals = _topk_int(scores, k)
    out_i_ref[...] = idx
    out_v_ref[...] = vals


@functools.partial(
    jax.jit, static_argnames=("k", "block_s", "interpret")
)
def contains_topk_call(
    gc: jax.Array,
    intents: jax.Array,
    supports: jax.Array,
    n_concepts: jax.Array,
    *,
    k: int,
    block_s: int = DEFAULT_S_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused top-k-by-support over concepts containing each closed query.

    gc [S, W] closed queries, intents [C, W] + supports [C] the snapshot
    tables, n_concepts the live row count (traced).  Returns
    (ids [S, k], supports [S, k]) with -1 pads, bit-identical to the jnp
    post in ``QueryEngine._topk_step``.
    """
    S, W = gc.shape
    C = intents.shape[0]
    if S % block_s:
        raise ValueError(f"slots S={S} not a multiple of block_s={block_s}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, W), lambda b, s: (b, 0)),
            pl.BlockSpec((C, W), lambda b, s: (0, 0)),
            pl.BlockSpec((1, C), lambda b, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, k), lambda b, s: (b, 0)),
            pl.BlockSpec((block_s, k), lambda b, s: (b, 0)),
        ],
    )
    out_i, out_v = pl.pallas_call(
        functools.partial(_contains_topk_kernel, k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, k), jnp.int32),
            jax.ShapeDtypeStruct((S, k), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(
        jnp.asarray(n_concepts, jnp.int32)[None],
        gc,
        intents,
        supports.astype(jnp.int32)[None, :],
    )
    return out_i, out_v


def _tree_or(x: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR reduce along ``axis`` via a log2 tree (static shapes)."""
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    while n > 1:
        half = n // 2
        paired = x[: 2 * half]
        x = jnp.concatenate([paired[0::2] | paired[1::2], x[2 * half :]], axis=0)
        n = x.shape[0]
    return x[0]


def _rules_topk_kernel(k, s_ref, q_ref, prem_ref, add_ref, conf_ref,
                       met_ref, rid_ref, minc_ref,
                       out_i_ref, out_v_ref, out_u_ref):
    queries = q_ref[...]  # [bs, W]
    prem = prem_ref[...]  # [R, W]
    added = add_ref[...]  # [R, W]
    R = prem.shape[0]
    rid = rid_ref[...]  # [1, R]
    app = jnp.all((prem[None, :, :] & ~queries[:, None, :]) == 0, axis=-1)
    live = lax.broadcasted_iota(jnp.int32, (1, R), 1) < s_ref[0]
    ok = app & (conf_ref[...] >= minc_ref[...]) & live  # [bs, R]
    # premise→consequent closure: OR-union of every firing conclusion
    fired = jnp.where(ok[:, :, None], added[None], jnp.uint32(0))
    out_u_ref[...] = _tree_or(fired, axis=1)
    # metric top-k with rule-id tie-break (lowest id wins), mirroring
    # QueryEngine._rules_step: the where(iota == pos) scatter equals
    # .at[rows, pos].set(-2.0) because pos is unique per row.
    score = jnp.where(ok, met_ref[...], jnp.float32(-1.0))
    col = lax.broadcasted_iota(jnp.int32, score.shape, 1)
    ids, vals = [], []
    for _ in range(k):
        best = jnp.max(score, axis=1)
        is_best = score == best[:, None]
        sel = jnp.min(
            jnp.where(is_best, rid, jnp.int32(0x7FFFFFFF)), axis=1
        )
        pos = jnp.argmax(is_best & (rid == sel[:, None]), axis=1)
        ids.append(sel)
        vals.append(best)
        score = jnp.where(col == pos[:, None], jnp.float32(-2.0), score)
    vals = jnp.stack(vals, axis=1)
    idx = jnp.stack(ids, axis=1)
    out_i_ref[...] = jnp.where(vals >= 0, idx, -1)
    out_v_ref[...] = jnp.maximum(vals, -1.0)


@functools.partial(
    jax.jit, static_argnames=("k", "block_s", "interpret")
)
def rules_topk_call(
    prem: jax.Array,
    added: jax.Array,
    conf: jax.Array,
    metric: jax.Array,
    rid: jax.Array,
    n_rules: jax.Array,
    queries: jax.Array,
    min_conf: jax.Array,
    *,
    k: int,
    block_s: int = DEFAULT_S_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused rule lookup: premise ⊆ query → conf/validity mask → consequent
    union → metric top-k with rule-id tie-break, one pass per query block.

    Operand order matches ``QueryEngine._rules_step``'s jnp ``run`` so the
    engine can route by backend without reshuffling: rule tables
    prem/added [R, W], conf/metric [R] f32, rid [R] i32, traced n_rules,
    queries [S, W], traced min_conf.  Returns (rule ids [S, k] (-1 pads),
    scores [S, k], consequent unions [S, W]).
    """
    S, W = queries.shape
    R = prem.shape[0]
    if S % block_s:
        raise ValueError(f"slots S={S} not a multiple of block_s={block_s}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, W), lambda b, s: (b, 0)),
            pl.BlockSpec((R, W), lambda b, s: (0, 0)),
            pl.BlockSpec((R, W), lambda b, s: (0, 0)),
            pl.BlockSpec((1, R), lambda b, s: (0, 0)),
            pl.BlockSpec((1, R), lambda b, s: (0, 0)),
            pl.BlockSpec((1, R), lambda b, s: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, k), lambda b, s: (b, 0)),
            pl.BlockSpec((block_s, k), lambda b, s: (b, 0)),
            pl.BlockSpec((block_s, W), lambda b, s: (b, 0)),
        ],
    )
    out_i, out_v, out_u = pl.pallas_call(
        functools.partial(_rules_topk_kernel, k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, k), jnp.int32),
            jax.ShapeDtypeStruct((S, k), jnp.float32),
            jax.ShapeDtypeStruct((S, W), jnp.uint32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(
        jnp.asarray(n_rules, jnp.int32)[None],
        queries,
        prem,
        added,
        conf.astype(jnp.float32)[None, :],
        metric.astype(jnp.float32)[None, :],
        rid.astype(jnp.int32)[None, :],
        jnp.asarray(min_conf, jnp.float32)[None, None],
    )
    return out_i, out_v, out_u
