"""gemma2-9b [dense] — local+global alternating attention, logit softcaps,
GeGLU, post-norms, tied embeddings [arXiv:2408.00118]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256_000,
    head_dim=256,
    rope_kind="standard",
    rope_theta=10_000.0,
    layer_pattern=("attn_local", "attn_global"),
    attn_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_kind="geglu",
    post_norm=True,
    emb_scale=True,
    tie_embeddings=True,
    # NOTE: global layers are full quadratic attention → long_500k skipped
    # (DESIGN.md §Arch-applicability).
    subquadratic=False,
)
