"""Incremental concept maintenance (Godin-style object addition).

The paper's §1.1 motivates incremental algorithms: "batch algorithms …
require that the entire lattice is reconstructed from scratch if the
database changes."  This module closes that gap for the streaming case:

    intents' = intents ∪ { B ∩ Y_g : B ∈ intents }

— adding object ``g`` with intent ``Y_g`` can only create concepts whose
intents are intersections of old intents with ``Y_g`` (every other closure
is unchanged; extents of intents ⊆ Y_g silently gain ``g``).  One pass,
O(|F|·W) word-ops, vectorized over the whole intent set — no mining rerun.

``add_objects`` is the batched one-pass version: the K new rows contribute
at most ``|P|`` distinct *subset intersections* (``P = {⋂ S : ∅ ≠ S ⊆ R}``,
computed by a K-step fold over the small ``P`` set), and the grown intent
set is exactly ``unique(intents ∪ (intents ∩ P) ∪ P)`` — one all-pairs
intersect (chunked to bound the temporary) and one ``np.unique``, instead
of K sequential passes over the full intent table.  (For a *closed* seed
set the ``∪ P`` term is already covered: ``M`` is always an intent and
``M ∩ p = p``.)  The per-row
``add_object`` loop is kept as the oracle (``add_objects_sequential``);
equivalence with it and with batch NextClosure on the grown context is
property-tested (tests/test_incremental.py).  The device twin lives in
:mod:`repro.query.stream`.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitset
from repro.core.context import FormalContext


def add_object(
    ctx: FormalContext, intents: np.ndarray, new_row: np.ndarray
) -> tuple[FormalContext, np.ndarray]:
    """intents [C, W] (any order) + one packed row [W] → updated pair."""
    new_row = np.asarray(new_row, dtype=np.uint32)
    if np.any(new_row & ~ctx.attr_mask()):
        raise ValueError("new object has attribute bits above n_attrs")

    inter = intents & new_row[None, :]  # candidate new intents
    combined = np.concatenate([intents, inter, new_row[None, :]], axis=0)
    new_intents = np.unique(combined, axis=0)

    new_ctx = FormalContext(
        rows=np.concatenate([ctx.rows, new_row[None, :]], axis=0),
        n_objects=ctx.n_objects + 1,
        n_attrs=ctx.n_attrs,
        attr_names=ctx.attr_names,
    )
    return new_ctx, new_intents


def row_intersections(rows: np.ndarray) -> np.ndarray:
    """All distinct non-empty-subset intersections ``{⋂ S : ∅ ≠ S ⊆ rows}``.

    The fold dedupes after every row, so the result never exceeds the
    number of *distinct* intersections — bounded by the concept count of
    the K-row subcontext, not 2^K.  Returns [P, W] uint32.
    """
    rows = np.asarray(rows, dtype=np.uint32)
    P = rows[:1]
    for i in range(1, rows.shape[0]):
        r = rows[i][None, :]
        P = np.unique(np.concatenate([P, P & r, r]), axis=0)
    return P


def as_intent_array(intents) -> np.ndarray:
    return np.asarray(
        np.stack(intents) if isinstance(intents, list) else intents,
        dtype=np.uint32,
    )


def add_objects(
    ctx: FormalContext, intents, rows: np.ndarray
) -> tuple[FormalContext, np.ndarray]:
    """Batched object addition: one all-pairs intersect + one ``np.unique``.

    Equivalent to streaming ``rows`` through ``add_object`` one at a time
    (``add_objects_sequential``, the property-test oracle) — the grown
    intent set is ``intents ∪ (intents ∩ P) ∪ P`` with ``P`` the new rows'
    subset intersections — but the full intent table is touched once, not
    K times.
    """
    cur = as_intent_array(intents)
    rows = np.asarray(rows, dtype=np.uint32)
    if rows.shape[0] == 0:
        return ctx, cur
    if np.any(rows & ~ctx.attr_mask()):
        raise ValueError("new objects have attribute bits above n_attrs")
    P = row_intersections(rows)
    # Chunk the |F|×|P| product so the temporary stays ~64 MB regardless
    # of intent-table size; per-chunk np.unique keeps the final merge
    # bounded by (distinct per chunk) × n_chunks, not the raw product.
    chunk = max(1, int(16e6 // max(1, P.shape[0] * ctx.W)))
    parts = [cur, P]
    for lo in range(0, cur.shape[0], chunk):
        cand = (cur[lo : lo + chunk, None, :] & P[None, :, :]).reshape(
            -1, ctx.W
        )
        parts.append(np.unique(cand, axis=0))
    new_intents = np.unique(np.concatenate(parts, axis=0), axis=0)
    new_ctx = FormalContext(
        rows=np.concatenate([ctx.rows, rows], axis=0),
        n_objects=ctx.n_objects + rows.shape[0],
        n_attrs=ctx.n_attrs,
        attr_names=ctx.attr_names,
    )
    return new_ctx, new_intents


def add_objects_sequential(
    ctx: FormalContext, intents, rows: np.ndarray
) -> tuple[FormalContext, np.ndarray]:
    """Stream a batch of packed rows [K, W] through ``add_object`` one at a
    time — the paper-literal path, kept as ``add_objects``'s oracle."""
    cur = as_intent_array(intents)
    for i in range(rows.shape[0]):
        ctx, cur = add_object(ctx, cur, rows[i])
    return ctx, cur
