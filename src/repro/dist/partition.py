"""Logical-axis partitioner: maps the models' *logical* axis names (carried
on :class:`repro.models.layers.Param` leaves) onto mesh axes.

The rules are Megatron-style:

  * ``batch``/activation leading dims   → the data axes (``pod``, ``data``)
  * tensor-parallel dims (``vocab``, ``ffn``, ``heads``, ``kv``,
    ``experts``, ``inner``, ``lru``, ``moe_d``, ``seq_model``) → ``model``
  * ``embed`` → the data axes when ``fsdp=True`` (ZeRO-3-style parameter
    sharding along the reduction dim), replicated otherwise
  * anything else (``layers``, ``head_dim``, ``conv``, ``seq_kv``, None)
    → replicated

A dim is only sharded when the mesh-axis product divides its size, and each
mesh axis is used at most once per array (first dim wins) — so reduced test
configs with tiny head counts degrade gracefully to replication instead of
erroring.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_DATA_AXES = ("pod", "data")
_MODEL_AXES = ("model",)


def object_axes(mesh) -> tuple[str, ...]:
    """The mesh axes that carry an object/batch partition, pod-major.

    Shared vocabulary between the LM data-parallel path and the FCA
    ShardPlan (whose context rows shard over the same axes).
    """
    return tuple(a for a in _DATA_AXES if a in mesh.shape)

RULES: dict[str, tuple[str, ...]] = {
    "batch": _DATA_AXES,
    "vocab": _MODEL_AXES,
    "ffn": _MODEL_AXES,
    "heads": _MODEL_AXES,
    "kv": _MODEL_AXES,
    "experts": _MODEL_AXES,
    "inner": _MODEL_AXES,
    "lru": _MODEL_AXES,
    "moe_d": _MODEL_AXES,
    "seq_model": _MODEL_AXES,
}


class Partitioner:
    def __init__(
        self,
        mesh: Mesh | None,
        *,
        fsdp: bool | None = False,
        constrain_attention: bool = True,
    ):
        self.mesh = mesh
        self.fsdp = bool(fsdp)
        self.constrain_attention = constrain_attention

    # -- rule resolution ---------------------------------------------------

    def _axes_for(self, name) -> tuple[str, ...]:
        if name is None:
            return ()
        if name == "embed":
            return _DATA_AXES if self.fsdp else ()
        return RULES.get(name, ())

    def _present(self, mesh_axes: tuple[str, ...]) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in mesh_axes if a in self.mesh.shape)

    def axis_size(self, mesh_axes: tuple[str, ...]) -> int:
        axes = self._present(mesh_axes)
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    def dim_shards(self, name: str, size: int) -> int:
        """Shard count a dim of ``size`` named ``name`` would get (1 = none)."""
        k = self.axis_size(self._axes_for(name))
        return k if k > 1 and size % k == 0 else 1

    def spec(self, names, shape) -> P:
        """PartitionSpec for logical ``names`` (len == ndim), divisibility-
        and reuse-checked against ``shape``."""
        used: set[str] = set()
        entries = []
        for name, size in zip(names, shape):
            axes = self._present(self._axes_for(name))
            if axes and not (used & set(axes)):
                k = int(np.prod([self.mesh.shape[a] for a in axes]))
                if k > 1 and size % k == 0:
                    used.update(axes)
                    entries.append(axes if len(axes) > 1 else axes[0])
                    continue
            entries.append(None)
        return P(*entries)

    # -- public API --------------------------------------------------------

    def __call__(self, x: jax.Array, *names) -> jax.Array:
        """Activation sharding constraint by logical dim names (None = any)."""
        if self.mesh is None:
            return x
        spec = self.spec(names, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def sharding(self, names, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def batch_spec(self, shape, batch_dim: int = 0) -> NamedSharding:
        names = [None] * len(shape)
        names[batch_dim] = "batch"
        return NamedSharding(self.mesh, self.spec(names, shape))

    def tree_shardings(self, axes_tree, abstract_tree):
        """Tree of NamedShardings from a logical-axes tree + abstract tree.

        ``axes_tree`` leaves are tuples of logical names (as produced by
        ``layers.split_params``); ``abstract_tree`` leaves anything with
        ``.shape``.
        """
        is_axes_leaf = lambda x: isinstance(x, tuple) and all(
            isinstance(a, str) or a is None for a in x
        )
        flat_axes, treedef = jax.tree_util.tree_flatten(
            axes_tree, is_leaf=is_axes_leaf
        )
        flat_abs = treedef.flatten_up_to(abstract_tree)
        out = [
            self.sharding(names, leaf.shape)
            for names, leaf in zip(flat_axes, flat_abs)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)
