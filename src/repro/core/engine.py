"""Distributed closure engine — the MapReduce substrate for the MR* miners.

The engine owns the *static data* (the object-partitioned context, resident
on device across iterations — Twister's defining feature) and executes the
paper's map/reduce round:

    map    : per-shard batched closure (Pallas kernel, fused-jnp or MXU
             matmul backend)
    reduce : bitwise-AND all-reduce of local closures across the object
             partition + psum of supports   (paper Theorem 2)

There is exactly one partitioned execution path: every round goes through
the engine's :class:`repro.dist.ShardPlan`, whose ``spmd`` primitive runs
the shard body under ``shard_map`` on a real mesh or under a named-axis
``vmap`` for simulated partitions on one device — same body, same
collectives, bit-identical arithmetic (see repro/dist/shardplan.py).

``spmd_step`` additionally lets callers fuse a *post* stage (canonicity,
feasibility, on-device dedupe) into the same SPMD region as the closure
map + AND-allreduce — the frontier pipeline builds its per-round fused
steps this way, so under a real mesh the whole iteration executes on the
partitions.

Supports are corrected globally: all-ones padding rows match every
candidate, so ``supports -= n_pad_total`` after the psum.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from repro.core import bitset
from repro.core.context import FormalContext
from repro.dist import collectives
from repro.dist.shardplan import AUTO_IMPLS, ShardPlan
from repro.kernels import frontier as fkern
from repro.kernels import ops
from repro.obs import StatsBase
from repro.obs import trace as obs


BACKENDS = ("kernel", "jnp", "matmul")


@dataclasses.dataclass
class EngineStats(StatsBase):
    """Per-run mining ledger.  Inherits the schedule census
    (``reduce_rounds``/``auto_hop_bytes``/``hop_calibrated``) and the
    latency-percentile view (``latency_percentiles`` + the histogram
    registry behind it) from :class:`repro.obs.StatsBase`, shared with the
    serving tier's QueryStats so both record the autotuner identically."""

    closure_calls: int = 0
    closures_computed: int = 0
    modeled_comm_bytes: int = 0
    rounds: int = 0
    # host↔device traffic census (the frontier pipeline's whole point):
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0
    # async speculative-round ledger (wall seconds the host spent enqueueing
    # device work vs blocked waiting on device results, the α/β split of the
    # modeled reduce cost, and the speculation outcome census).  The timing
    # fields are populated by the sync paths too, so sync-vs-async A/Bs
    # compare like with like.
    dispatch_s: float = 0.0
    host_blocked_s: float = 0.0
    modeled_dispatch_bytes: int = 0
    modeled_collective_bytes: int = 0
    spec_rounds: int = 0
    spec_fallbacks: int = 0
    spec_discarded: int = 0


class ClosureEngine:
    def __init__(
        self,
        ctx: FormalContext,
        *,
        plan: ShardPlan | None = None,
        mesh: Mesh | None = None,
        axis_names: tuple[str, ...] = ("data",),
        n_parts: int | None = None,
        backend: str | None = None,
        use_kernel: bool = True,
        reduce_impl: str | None = None,
        block_n: int | None = None,
        max_batch: int | None = None,
        interpret: bool = True,
    ):
        # ``backend`` supersedes the old ``use_kernel`` flag:
        #   kernel — Pallas closure kernel (interpret-mode on CPU)
        #   jnp    — fused-jnp reference (fastest on CPU/XLA)
        #   matmul — MXU complement-counting closure (§Perf C2)
        if backend is None:
            backend = "kernel" if use_kernel else "jnp"
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose {BACKENDS}")
        # ``plan`` supersedes the legacy (mesh, axis_names) / n_parts pair;
        # both legacy spellings build the same ShardPlan.  Kwarg precedence
        # is uniform: geometry (mesh/n_parts) conflicts with an explicit
        # plan and raises; the scalar knobs (reduce_impl/block_n/max_batch)
        # override the plan's values when passed.
        if plan is None:
            if mesh is not None:
                plan = ShardPlan.over_mesh(
                    mesh,
                    axis_names=tuple(axis_names),
                    reduce_impl=reduce_impl or "rsag",
                )
            else:
                plan = ShardPlan.simulated(
                    n_parts or 1, reduce_impl=reduce_impl or "rsag"
                )
        elif mesh is not None or n_parts is not None or tuple(axis_names) != ("data",):
            raise ValueError(
                "pass either plan= or the legacy mesh=/axis_names=/n_parts= "
                "geometry, not both"
            )
        overrides = {
            k: v
            for k, v in (
                ("reduce_impl", reduce_impl),
                ("block_n", block_n),
                ("max_batch", max_batch),
            )
            if v is not None
        }
        if overrides:
            plan = dataclasses.replace(plan, **overrides)
        self.plan = plan
        self.ctx = ctx
        self.mesh = plan.mesh
        self.axis_names = plan.axis_names
        self.backend = backend
        self.use_kernel = backend == "kernel"
        self.reduce_impl = plan.reduce_impl
        self.block_n = plan.block_n
        self.max_batch = plan.max_batch
        self.interpret = interpret
        self.stats = EngineStats(
            auto_hop_bytes=plan.auto_hop_bytes,
            hop_calibrated=plan.hop_calibrated,
        )
        self.n_parts = plan.n_parts

        # Pad rows so every shard is block-aligned: N % (k * block_n) == 0.
        rows, n_pad = ctx.padded_rows(plan.row_alignment)
        self.n_pad_rows = n_pad
        self.N_padded = rows.shape[0]
        self._mask_np = ctx.attr_mask()
        self.rows = plan.place_rows(rows)

        # Guards the lazily-built ``_frontier_cache`` (set by
        # DeviceFrontier): the cache is reachable from both the main
        # thread and the admission dispatcher thread, and a concurrent
        # first-miss would otherwise build the same jitted step twice.
        self._frontier_lock = threading.Lock()

        self._step = self.spmd_step(with_supports=True)

    # -- the one partitioned execution path --------------------------------

    def _local_closure(self):
        """Per-shard map phase for the configured backend."""
        ctx = self.ctx
        backend, block_n, interp = self.backend, self.block_n, self.interpret

        if backend == "matmul":

            def local_closure(rows_local, cands):
                return ops.closure_matmul(
                    rows_local,
                    cands,
                    ctx.n_attrs,
                    n_valid_rows=rows_local.shape[0],  # global pad corrected later
                )

        else:

            def local_closure(rows_local, cands):
                return ops.batched_closure(
                    rows_local,
                    cands,
                    ctx.n_attrs,
                    n_valid_rows=rows_local.shape[0],  # global pad corrected later
                    block_n=block_n,
                    use_kernel=backend == "kernel",
                    interpret=interp,
                )

        return local_closure

    def spmd_step(self, post=None, *, with_supports: bool = False, n_extra: int = 0):
        """Build one jitted plan-SPMD round: map → AND-allreduce [→ post].

        The returned callable is ``step(rows, cands, *extras)``.  Each
        shard computes local closures, the reduce runs the plan's
        collective schedule, and — when given — ``post`` consumes the
        *global* closures (masked to real attributes) plus the ``n_extra``
        replicated extras.  The plan places ``post``: fused into the same
        SPMD region on a mesh, applied once past the vmap on a simulated
        plan (its input is shard-invariant, so both are bit-identical).
        Without ``post`` the step returns the masked global closures, plus
        pad-corrected supports when ``with_supports``.
        """
        plan, ctx = self.plan, self.ctx
        local_closure = self._local_closure()
        axes = plan.reduce_axes
        mask_np, n_pad = self._mask_np, self.n_pad_rows

        def make(impl):
            def body(rows_local, cands):
                lc, ls = local_closure(rows_local, cands)
                gc = collectives.and_allreduce(
                    lc, axes, impl=impl, n_attrs=ctx.n_attrs
                )
                gc = gc & jnp.asarray(mask_np)
                if with_supports:
                    return gc, lax.psum(ls, axes) - n_pad
                return gc

            return jax.jit(
                plan.spmd(body, n_rep=1, post=post, n_post_rep=n_extra)
            )

        if plan.reduce_impl != "auto":
            return make(plan.reduce_impl)

        # Schedule autotuning: one jitted step per candidate schedule; the
        # dispatcher resolves the round's schedule from the padded batch
        # size (the AND semigroup makes every schedule bit-identical, so
        # the choice only moves wire cost).  ``charge_round`` sees the same
        # (cap, plan) pair and ledgers the matching bytes + choice.
        steps = {impl: make(impl) for impl in AUTO_IMPLS}

        def dispatch(rows, cands, *extras):
            impl = plan.resolve_impl(cands.shape[0], ctx.W, ctx.n_attrs)
            return steps[impl](rows, cands, *extras)

        return dispatch

    def spmd_step_cand(
        self,
        post,
        merge,
        *,
        with_supports: bool = False,
        n_cand: int = 1,
        n_post_rep: int = 0,
        n_merge_rep: int = 0,
    ):
        """2-D twin of :meth:`spmd_step` for candidate-sharded chunks.

        The returned callable is ``step(rows, *cand_ops, *extras)``: the
        ``n_cand`` candidate operands (seeds first, then lineage like
        parents/gens) are blocked over the plan's candidate axis, each
        block runs map → AND-allreduce over the *object* axes at the block
        batch size, ``post(cand_idx, gc[, gs], *passthrough, *extras)``
        filters block-locally, and only then are survivors all-gathered
        along ``cand`` and handed to ``merge``.  Pruned candidates never
        replicate across the candidate axis.  Lineage operands beyond the
        seeds ride through ``body`` untouched so the block-local filter
        sees its own block's rows.
        """
        plan, ctx = self.plan, self.ctx
        local_closure = self._local_closure()
        axes = plan.reduce_axes
        mask_np, n_pad = self._mask_np, self.n_pad_rows

        def make(impl):
            def body(rows_local, *cand_ops):
                lc, ls = local_closure(rows_local, cand_ops[0])
                gc = collectives.and_allreduce(
                    lc, axes, impl=impl, n_attrs=ctx.n_attrs
                )
                gc = gc & jnp.asarray(mask_np)
                if with_supports:
                    return (gc, lax.psum(ls, axes) - n_pad, *cand_ops[1:])
                return (gc, *cand_ops[1:])

            return jax.jit(
                plan.spmd_cand(
                    body,
                    n_cand=n_cand,
                    n_rep=0,
                    post=post,
                    n_post_rep=n_post_rep,
                    merge=merge,
                    n_merge_rep=n_merge_rep,
                )
            )

        if plan.reduce_impl != "auto":
            return make(plan.reduce_impl)

        steps = {impl: make(impl) for impl in AUTO_IMPLS}

        def dispatch(rows, cands, *extras):
            block = cands.shape[0] // plan.cand_parts
            impl = plan.resolve_impl(block, ctx.W, ctx.n_attrs)
            return steps[impl](rows, cands, *extras)

        return dispatch

    # -- fused-kernel step builders (backend="kernel") ----------------------
    #
    # Twin builders for the frontier pipeline's step variants that replace
    # the jnp closure→mask→filter op chain with the fused Pallas kernels in
    # repro.kernels.frontier.  Two placements, chosen by plan geometry:
    #
    #   n_parts == 1 — the local closure IS the global closure, so ONE
    #     ``fused_closure_call`` computes closure → support → driver filter
    #     without the block ever leaving VMEM; no collective runs (the
    #     size-1 AND-allreduce is the identity).
    #   n_parts > 1 — the filter needs the *global* closure, which only
    #     exists after the AND-allreduce, so the round is map kernel (the
    #     attr mask folded in-kernel: AND distributes over the mask, so
    #     masked locals allreduce to the masked global) → collectives →
    #     fused filter kernel (pad correction + iceberg cut + canonicity in
    #     one pass).
    #
    # Survivor *compaction* stays jnp in both placements: the argsort
    # permutation is XLA's job and consumes only the kernel's keep mask —
    # identical masks in, identical order out, which is what makes the
    # fused steps bit-identical to the jnp builders (tests/
    # test_fused_frontier.py).  Call signatures match the jnp builders
    # exactly, so DeviceFrontier routes by name alone.

    def _fused_ctx(self, LOW):
        from repro.core.frontier import _compact, _sort_unique

        return (
            jnp.asarray(self._mask_np[None, :]),
            jnp.asarray(LOW),
            self.n_pad_rows,
            dict(block_n=self.plan.block_n, interpret=self.interpret),
            _compact,
            _sort_unique,
        )

    def spmd_step_fused(self, variant: str, LOW):
        """Fused-kernel 1-D step for ``variant`` ∈ ``fkern.VARIANTS``."""
        iceberg, cbo, unique = fkern.VARIANTS[variant]
        plan, ctx = self.plan, self.ctx
        mask, LOW_c, n_pad, kw, _compact, _sort_unique = self._fused_ctx(LOW)
        axes = plan.reduce_axes

        def compact_out(keep, gc):
            n, gc = _sort_unique(gc, keep) if unique else _compact(keep, gc)
            return gc, n

        if plan.n_parts == 1:
            if variant == "plain":

                def body(rows_local, cands):
                    gc, _, _ = fkern.fused_closure_call(
                        rows_local, cands, mask,
                        fkern.pack_scalars(0, 0, n_pad, 0), **kw,
                    )
                    return gc

                return jax.jit(plan.spmd(body, n_rep=1))

            if cbo:

                def body(rows_local, cands, parents, gens, n_valid, *ms):
                    sc = fkern.pack_scalars(
                        n_valid, ms[0] if iceberg else 0, n_pad, 0
                    )
                    gc, _, keep = fkern.fused_closure_call(
                        rows_local, cands, mask, sc,
                        parent=parents, lowrow=LOW_c[gens],
                        iceberg=iceberg, cbo=True, **kw,
                    )
                    return gc, keep, gens

                def post(gc, keep, gens):
                    n, gc, gens = _compact(keep, gc, gens)
                    return gc, gens, n

                return jax.jit(
                    plan.spmd(body, n_rep=5 if iceberg else 4, post=post)
                )

            def body(rows_local, cands, n_valid, *ms):
                sc = fkern.pack_scalars(
                    n_valid, ms[0] if iceberg else 0, n_pad, 0
                )
                gc, _, keep = fkern.fused_closure_call(
                    rows_local, cands, mask, sc, iceberg=iceberg, **kw,
                )
                return gc, keep

            return jax.jit(
                plan.spmd(
                    body,
                    n_rep=3 if iceberg else 2,
                    post=lambda gc, keep: compact_out(keep, gc),
                )
            )

        # multi-shard: map kernel → collectives → fused filter kernel
        interp = self.interpret
        with_sup = iceberg

        def make(impl):
            def body(rows_local, cands):
                lc, ls = fkern.map_closure_call(rows_local, cands, mask, **kw)
                gc = collectives.and_allreduce(
                    lc, axes, impl=impl, n_attrs=ctx.n_attrs
                )
                if with_sup:
                    return gc, lax.psum(ls, axes) - n_pad
                return gc

            if variant == "plain":
                return jax.jit(plan.spmd(body, n_rep=1))

            if cbo:
                if iceberg:

                    def post(gc, gs, parents, gens, n_valid, min_sup):
                        _, keep = fkern.filter_call(
                            gc, gs,
                            fkern.pack_scalars(n_valid, min_sup, 0, 0),
                            parent=parents, lowrow=LOW_c[gens],
                            iceberg=True, cbo=True, interpret=interp,
                        )
                        n, gc, gens = _compact(keep, gc, gens)
                        return gc, gens, n

                    n_extra = 4
                else:

                    def post(gc, parents, gens, n_valid):
                        _, keep = fkern.filter_call(
                            gc, jnp.zeros(gc.shape[0], jnp.int32),
                            fkern.pack_scalars(n_valid, 0, 0, 0),
                            parent=parents, lowrow=LOW_c[gens],
                            cbo=True, interpret=interp,
                        )
                        n, gc, gens = _compact(keep, gc, gens)
                        return gc, gens, n

                    n_extra = 3
            elif iceberg:

                def post(gc, gs, n_valid, min_sup):
                    _, keep = fkern.filter_call(
                        gc, gs, fkern.pack_scalars(n_valid, min_sup, 0, 0),
                        iceberg=True, interpret=interp,
                    )
                    return compact_out(keep, gc)

                n_extra = 2
            else:  # unique — validity-only mask needs no filter kernel

                def post(gc, n_valid):
                    keep = jnp.arange(gc.shape[0]) < n_valid
                    return compact_out(keep, gc)

                n_extra = 1

            return jax.jit(
                plan.spmd(body, n_rep=1, post=post, n_post_rep=n_extra)
            )

        if plan.reduce_impl != "auto":
            return make(plan.reduce_impl)
        steps = {impl: make(impl) for impl in AUTO_IMPLS}

        def dispatch(rows, cands, *extras):
            impl = plan.resolve_impl(cands.shape[0], ctx.W, ctx.n_attrs)
            return steps[impl](rows, cands, *extras)

        return dispatch

    def spmd_step_cand_fused(self, variant: str, LOW, merge, *, n_merge_rep=0):
        """Fused-kernel 2-D twin: ``variant`` per candidate block, filters
        block-local (``row_off = cand_index · Bc`` rides the kernels'
        scalar operand), survivors gathered along ``cand`` into ``merge``.
        """
        iceberg, cbo, unique = fkern.VARIANTS[variant]
        plan, ctx = self.plan, self.ctx
        mask, LOW_c, n_pad, kw, _compact, _sort_unique = self._fused_ctx(LOW)
        axes = plan.reduce_axes

        def compact_out(keep, gc):
            n, gc = _sort_unique(gc, keep) if unique else _compact(keep, gc)
            return gc, n

        if plan.n_parts == 1:
            if variant == "plain":

                def body(rows_local, cands):
                    gc, _, _ = fkern.fused_closure_call(
                        rows_local, cands, mask,
                        fkern.pack_scalars(0, 0, n_pad, 0), **kw,
                    )
                    return gc

                return jax.jit(
                    plan.spmd_cand(body, n_cand=1, merge=merge)
                )

            if cbo:

                def body(rows_local, cands, parents, gens, n_valid, *ms):
                    sc = fkern.pack_scalars(
                        n_valid, ms[0] if iceberg else 0, n_pad,
                        plan.cand_index() * cands.shape[0],
                    )
                    gc, _, keep = fkern.fused_closure_call(
                        rows_local, cands, mask, sc,
                        parent=parents, lowrow=LOW_c[gens],
                        iceberg=iceberg, cbo=True, **kw,
                    )
                    return gc, keep, gens

                def post(idx, gc, keep, gens):
                    n, gc, gens = _compact(keep, gc, gens)
                    return gc, gens, n

                return jax.jit(
                    plan.spmd_cand(
                        body, n_cand=3, n_rep=2 if iceberg else 1,
                        post=post, merge=merge, n_merge_rep=n_merge_rep,
                    )
                )

            def body(rows_local, cands, n_valid, *ms):
                sc = fkern.pack_scalars(
                    n_valid, ms[0] if iceberg else 0, n_pad,
                    plan.cand_index() * cands.shape[0],
                )
                gc, _, keep = fkern.fused_closure_call(
                    rows_local, cands, mask, sc, iceberg=iceberg, **kw,
                )
                return gc, keep

            return jax.jit(
                plan.spmd_cand(
                    body, n_cand=1, n_rep=2 if iceberg else 1,
                    post=lambda idx, gc, keep: compact_out(keep, gc),
                    merge=merge, n_merge_rep=n_merge_rep,
                )
            )

        interp = self.interpret
        with_sup = iceberg

        def make(impl):
            def body(rows_local, *cand_ops):
                lc, ls = fkern.map_closure_call(
                    rows_local, cand_ops[0], mask, **kw
                )
                gc = collectives.and_allreduce(
                    lc, axes, impl=impl, n_attrs=ctx.n_attrs
                )
                if with_sup:
                    return (gc, lax.psum(ls, axes) - n_pad, *cand_ops[1:])
                return (gc, *cand_ops[1:])

            if variant == "plain":
                return jax.jit(plan.spmd_cand(body, n_cand=1, merge=merge))

            if cbo:
                if iceberg:

                    def post(idx, gc, gs, parents, gens, n_valid, min_sup):
                        sc = fkern.pack_scalars(
                            n_valid, min_sup, 0, idx * gc.shape[0]
                        )
                        _, keep = fkern.filter_call(
                            gc, gs, sc, parent=parents, lowrow=LOW_c[gens],
                            iceberg=True, cbo=True, interpret=interp,
                        )
                        n, gc, gens = _compact(keep, gc, gens)
                        return gc, gens, n

                    n_extra = 2
                else:

                    def post(idx, gc, parents, gens, n_valid):
                        sc = fkern.pack_scalars(n_valid, 0, 0, idx * gc.shape[0])
                        _, keep = fkern.filter_call(
                            gc, jnp.zeros(gc.shape[0], jnp.int32), sc,
                            parent=parents, lowrow=LOW_c[gens],
                            cbo=True, interpret=interp,
                        )
                        n, gc, gens = _compact(keep, gc, gens)
                        return gc, gens, n

                    n_extra = 1
                return jax.jit(
                    plan.spmd_cand(
                        body, n_cand=3, post=post, n_post_rep=n_extra,
                        merge=merge, n_merge_rep=n_merge_rep,
                    )
                )

            if iceberg:

                def post(idx, gc, gs, n_valid, min_sup):
                    sc = fkern.pack_scalars(
                        n_valid, min_sup, 0, idx * gc.shape[0]
                    )
                    _, keep = fkern.filter_call(
                        gc, gs, sc, iceberg=True, interpret=interp
                    )
                    return compact_out(keep, gc)

                n_extra = 2
            else:  # unique — validity-only mask needs no filter kernel

                def post(idx, gc, n_valid):
                    keep = (jnp.arange(gc.shape[0]) + idx * gc.shape[0]) < n_valid
                    return compact_out(keep, gc)

                n_extra = 1

            return jax.jit(
                plan.spmd_cand(
                    body, n_cand=1, post=post, n_post_rep=n_extra,
                    merge=merge, n_merge_rep=n_merge_rep,
                )
            )

        if plan.reduce_impl != "auto":
            return make(plan.reduce_impl)
        steps = {impl: make(impl) for impl in AUTO_IMPLS}

        def dispatch(rows, cands, *extras):
            block = cands.shape[0] // plan.cand_parts
            impl = plan.resolve_impl(block, ctx.W, ctx.n_attrs)
            return steps[impl](rows, cands, *extras)

        return dispatch

    # -- stats accounting ---------------------------------------------------

    def charge_round(self, cap: int, n_valid: int, *, count_round: bool = True):
        """Ledger one SPMD closure dispatch of a ``cap``-padded batch."""
        self.stats.closure_calls += 1
        if count_round:
            self.stats.rounds += 1
        self.stats.closures_computed += n_valid
        hops, vol = self.plan.modeled_latency_split(
            cap, self.ctx.W, self.ctx.n_attrs
        )
        self.stats.modeled_comm_bytes += vol
        self.stats.modeled_dispatch_bytes += hops
        self.stats.modeled_collective_bytes += vol
        impl = self.plan.resolve_impl(cap, self.ctx.W, self.ctx.n_attrs)
        self.stats.record_reduce(impl)

    def charge_round_cand(
        self, block_cap: int, n_valid: int, *, count_round: bool = True
    ):
        """Ledger one 2-D dispatch: ``cand_parts`` blocks of ``block_cap``
        candidates each (object reduce per block + the cand-axis survivor
        gather — see ShardPlan.modeled_round_bytes_cand)."""
        self.stats.closure_calls += 1
        if count_round:
            self.stats.rounds += 1
        self.stats.closures_computed += n_valid
        hops, vol = self.plan.modeled_latency_split_cand(
            block_cap, self.ctx.W, self.ctx.n_attrs
        )
        self.stats.modeled_comm_bytes += vol
        self.stats.modeled_dispatch_bytes += hops
        self.stats.modeled_collective_bytes += vol
        impl = self.plan.resolve_impl(block_cap, self.ctx.W, self.ctx.n_attrs)
        self.stats.record_reduce(impl)

    # -- public API ----------------------------------------------------------

    @property
    def min_bucket(self) -> int:
        return max(8, self.n_parts)

    def closure(self, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Global closures + supports for a host candidate batch [B, W]."""
        B = cands.shape[0]
        if B == 0:
            return (
                np.zeros((0, self.ctx.W), np.uint32),
                np.zeros((0,), np.int32),
            )
        out_c = np.empty((B, self.ctx.W), np.uint32)
        out_s = np.empty((B,), np.int32)
        self.stats.rounds += 1
        with obs.current().span("engine/closure", batch=B):
            for lo in range(0, B, self.max_batch):
                chunk = cands[lo : lo + self.max_batch]
                b = chunk.shape[0]
                cap = ops.bucket_size(b, minimum=self.min_bucket)
                if cap != b:  # pad with all-ones candidates; outputs dropped
                    pad = np.full((cap - b, self.ctx.W), 0xFFFFFFFF, np.uint32)
                    chunk = np.concatenate([chunk, pad], axis=0)
                gc, gs = self._step(self.rows, jnp.asarray(chunk))
                out_c[lo : lo + b] = np.asarray(gc)[:b]
                out_s[lo : lo + b] = np.asarray(gs)[:b]
                self.charge_round(cap, b, count_round=False)
                self.stats.h2d_transfers += 1
                self.stats.h2d_bytes += cap * self.ctx.W * 4
                self.stats.d2h_transfers += 2
                self.stats.d2h_bytes += cap * (self.ctx.W + 1) * 4
        return out_c, out_s

    def closure_dev(
        self, cands, n_valid: int, *, count_round: bool = True
    ):
        """Device-to-device closure for an already bucket-padded batch.

        ``cands`` is a device array [cap, W]; rows past ``n_valid`` are
        padding whose outputs the caller ignores.  Nothing crosses the
        host boundary — this is the frontier pipeline's map+reduce step.
        """
        cap = cands.shape[0]
        gc, gs = self._step(self.rows, cands)
        self.charge_round(cap, n_valid, count_round=count_round)
        return gc, gs

    def first_closure(self) -> tuple[np.ndarray, int]:
        """``∅''`` and its support ``|O|`` via a full map/reduce round."""
        empty = np.zeros((1, self.ctx.W), np.uint32)
        c, s = self.closure(empty)
        return c[0], int(s[0])
