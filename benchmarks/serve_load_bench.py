"""Sustained-load serving benchmark: offered QPS vs latency percentiles
through the continuous admission queue (§Serving-load).

Protocol:

1. mine mushroom at the CPU-budget scale, build the ConceptStore, warm
   every query kind's jit cache;
2. **calibrate** the back-to-back service rate *through the admission
   queue* (queries/s with zero queueing delay — every dispatch fires on
   "full") — the grid is expressed as fractions of this measured
   ceiling so the bench adapts to the host.  Calibrating through the
   queue, not raw engine batches, charges the per-ticket admission
   overhead; the engine alone batches several times faster than the
   serving path can feed it;
3. **offered-load grid** — open-loop Poisson arrivals at 25% … 110% of
   the calibrated ceiling, a fresh admission queue per point, each point
   reporting p50/p95/p99 end-to-end latency, admission wait, shed rate,
   slot occupancy and an SLO verdict;
4. **knee detection** — the first grid point whose p99 exceeds 3× the
   lightest point's p99 (or that sheds) marks the saturation knee;
5. **update churn** — a separate record with a *fixed count* of
   streaming commits mixed into a moderate query load: a commit's cost
   is the staged snapshot's O(C²) order-table rebuild (the first query
   after the swap blocks on it — StreamUpdater's row-padding slack
   already keeps step *recompiles* off the commit path), so its latency
   is reported on its own line instead of polluting the query-only grid;
6. **bit-identity** — the same query set through the queue and as one
   pre-formed batch must agree exactly (the acceptance criterion; the
   flag lands in the headline and the SLO gate pins it).

Writes BENCH_serve_load.json; the headline is the largest offered load
sustained with <1% shed and ≥90% delivery, with its p99.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import row
from repro.core import ClosureEngine, mrganter_plus
from repro.data import fca_datasets
from repro.dist.shardplan import ShardPlan
from repro.obs.slo import SLO
from repro.query import ConceptStore, QueryEngine, StreamUpdater
from repro.query.engine import QueryConfig
from repro.serve import (
    AdmissionConfig,
    AdmissionQueue,
    make_workload,
    poisson_arrivals,
    run_load,
)

QUERY_MIX = {"closure": 0.6, "topk": 0.3, "lookup": 0.1}
KNEE_RATIO = 3.0  # p99 multiple of the lightest point that marks the knee
CHURN_UPDATES = 3  # snapshot commits in the churn record — each one costs
# an O(C²) order-table rebuild on device, so the count is fixed, not a
# fraction of the offered load


def _calibrate(qe, ctx, cfg_kwargs, rng, reps: int = 3) -> float:
    """Max throughput through the queue path with zero queueing delay:
    back-to-back submits, every dispatch firing on "full",
    best-of-``reps``.  This is the serving ceiling the grid fractions
    scale from — it includes per-ticket admission overhead, which on a
    fast engine dominates the raw micro-batch rate."""
    n = qe.cfg.slots * 8
    events = make_workload(ctx, n, rng, mix={"closure": 1.0})
    best = float("inf")
    for _ in range(reps):
        queue = _fresh_queue(qe, cfg_kwargs)
        t0 = time.perf_counter()
        for kind, payload in events:
            queue.submit(kind, payload)
        queue.flush()
        best = min(best, time.perf_counter() - t0)
    return n / best


def _fresh_queue(qe, cfg_kwargs) -> AdmissionQueue:
    return AdmissionQueue(qe, AdmissionConfig(**cfg_kwargs))


def _point(qe, ctx, qps, seconds, mix, seed, cfg_kwargs, updater=None):
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(qps, seconds, rng)
    events = make_workload(ctx, len(arrivals), rng, mix=mix)
    queue = _fresh_queue(qe, cfg_kwargs)
    rep = run_load(queue, arrivals, events, updater=updater, slo=SLO())
    return rep


def _bit_identity(qe, ctx, cfg_kwargs, seed: int) -> bool:
    """Queue answers == one pre-formed batch, element-exact."""
    rng = np.random.default_rng(seed)
    events = make_workload(ctx, 48, rng, mix={"closure": 1.0})
    payloads = [p for _, p in events]
    queue = _fresh_queue(qe, cfg_kwargs)
    tickets = [queue.submit("closure", p) for p in payloads]
    queue.flush()
    c, s, i = qe.closure_batch(np.stack(payloads))
    for t, (ec, es, ei) in zip(tickets, zip(c, s, i)):
        tc, ts, ti = t.result
        if not (
            np.array_equal(np.asarray(tc), ec)
            and int(ts) == int(es)
            and int(ti) == int(ei)
        ):
            return False
    return True


def run(
    dataset: str = "mushroom",
    scale: float = 0.01,
    slots: int = 32,
    load_seconds: float = 2.0,
    fractions=(0.25, 0.5, 0.75, 0.9, 1.1),
    max_wait_ms: float = 2.0,
    depth: int = 256,
    out_path: str = "BENCH_serve_load.json",
) -> list[str]:
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)
    plan = ShardPlan.simulated(1)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    qe = QueryEngine(store, QueryConfig(slots=slots, backend="jnp"))
    cfg_kwargs = {"max_wait_s": max_wait_ms / 1000.0, "depth": depth}

    rng = np.random.default_rng(0)
    warm = ctx.rows[rng.integers(0, ctx.n_objects, size=slots)]
    qe.closure_batch(warm)
    qe.topk_batch(warm, k=5)
    qe.lookup_batch(warm)

    ceiling_qps = _calibrate(qe, ctx, cfg_kwargs, rng)
    bit_identical = _bit_identity(qe, ctx, cfg_kwargs, seed=11)
    if not bit_identical:
        raise AssertionError("queue results diverge from pre-formed batches")

    grid = []
    for frac in fractions:
        qps = ceiling_qps * frac
        rep = _point(
            qe, ctx, qps, load_seconds, QUERY_MIX, seed=int(frac * 100),
            cfg_kwargs=cfg_kwargs,
        )
        grid.append({
            "offered_fraction": frac,
            "offered_qps": round(rep.offered_qps, 1),
            "achieved_qps": rep.achieved_qps,
            "submitted": rep.submitted,
            "shed_rate": round(rep.shed_rate, 6),
            "occupancy_mean": rep.occupancy_mean,
            "dispatch_causes": rep.dispatch_causes,
            "e2e": rep.e2e,
            "admission_wait": rep.admission_wait,
            "max_lag_s": round(rep.max_lag_s, 4),
            "slo": rep.slo,
        })

    # saturation knee: p99 blow-up or the first shed
    base_p99 = grid[0]["e2e"].get("p99", 0.0) or 1e-9
    knee = None
    for g in grid:
        if g["shed_rate"] > 0 or g["e2e"].get("p99", 0.0) > KNEE_RATIO * base_p99:
            knee = g["offered_fraction"]
            break

    # the largest offered load we actually sustained
    sustained = [
        g for g in grid
        if g["shed_rate"] < 0.01
        and g["achieved_qps"] >= 0.9 * g["offered_qps"]
    ]
    head = max(sustained, key=lambda g: g["offered_qps"]) if sustained else grid[0]

    # update churn: snapshot swaps measured separately.  A commit's cost
    # is the staged snapshot's O(C²) order-table rebuild (the first
    # query after the swap blocks on it), so the record fixes the commit
    # COUNT — an update *fraction* of the offered load would make the
    # run length proportional to QPS.
    # a light query trickle: the record's p99 is dominated by the commit
    # stalls either way, and a heavier rate just sheds backlog behind them
    churn_qps = min(20.0, 0.25 * ceiling_qps)
    n_events = max(CHURN_UPDATES + 1, int(churn_qps * load_seconds))
    w_update = CHURN_UPDATES / (n_events - CHURN_UPDATES)
    churn_mix = {**QUERY_MIX, "update": w_update * sum(QUERY_MIX.values())}
    churn_rep = _point(
        qe, ctx, churn_qps, load_seconds, churn_mix,
        seed=23, cfg_kwargs=cfg_kwargs, updater=StreamUpdater(store),
    )
    churn = {
        "offered_qps": round(churn_rep.offered_qps, 1),
        "achieved_qps": churn_rep.achieved_qps,
        "updates": churn_rep.updates,
        "update_latency": churn_rep.update_latency,
        "e2e": churn_rep.e2e,
        "shed_rate": round(churn_rep.shed_rate, 6),
        "snapshot_version": store.snapshot.version,
    }

    payload = {
        "dataset": dataclasses.asdict(spec),
        "concepts": res.n_concepts,
        "workload": {
            "slots": slots,
            "mix": QUERY_MIX,
            "churn_updates": CHURN_UPDATES,
            "load_seconds": load_seconds,
            "max_wait_ms": max_wait_ms,
            "depth": depth,
            "arrival": "poisson",
        },
        "calibrated_ceiling_qps": round(ceiling_qps, 1),
        "grid": grid,
        "saturation_knee_fraction": knee,
        "update_churn": churn,
        "headline": {
            "sustained_qps": head["achieved_qps"],
            "offered_fraction": head["offered_fraction"],
            "e2e_p99_s": head["e2e"].get("p99"),
            "shed_rate": head["shed_rate"],
            "bit_identical": bit_identical,
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = [row(
        "serve_load/ceiling", 1e6 / ceiling_qps,
        f"qps={payload['calibrated_ceiling_qps']}",
    )]
    for g in grid:
        out.append(row(
            f"serve_load/offered={g['offered_fraction']:g}",
            1e6 * (g["e2e"].get("p99") or 0.0),
            f"qps={g['achieved_qps']}|shed={g['shed_rate']}"
            f"|occ={g['occupancy_mean']}",
        ))
    out.append(row(
        "serve_load/update_churn",
        1e6 * (churn["e2e"].get("p99") or 0.0),
        f"updates={churn['updates']}|qps={churn['achieved_qps']}",
    ))
    out.append(row(
        "serve_load/headline_sustained_qps",
        payload["headline"]["sustained_qps"],
        f"p99_s={payload['headline']['e2e_p99_s']}"
        f"|knee={knee}|json={out_path}",
    ))
    return out
