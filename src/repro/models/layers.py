"""Shared building blocks: params-with-axes, norms, RoPE/M-RoPE, MLPs.

Parameters are built through :class:`Param` leaves carrying *logical axis
names* alongside the value; ``split_params`` separates the two trees so the
partitioner (``repro.dist.partition``) can map logical axes → mesh axes
without fragile path-regex matching.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Param(NamedTuple):
    value: jax.Array
    axes: tuple  # logical axis names, len == value.ndim


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Param-tree → (values-tree, axes-tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_params(trees: list):
    """Stack per-period Param-trees along a new leading 'layers' axis."""
    def _stack(*ps):
        v0 = ps[0].value
        if isinstance(v0, jax.ShapeDtypeStruct):  # abstract (dry-run) path
            stacked = jax.ShapeDtypeStruct((len(ps),) + v0.shape, v0.dtype)
        else:
            stacked = jnp.stack([p.value for p in ps])
        return Param(stacked, ("layers",) + ps[0].axes)

    return jax.tree_util.tree_map(_stack, *trees, is_leaf=is_param)


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


class ParamBuilder:
    """Splits keys and materializes Param leaves with sane default scales.

    ``abstract=True`` yields ShapeDtypeStruct values (zero allocation, no
    RNG) — the dry-run path for full-size configs.
    """

    def __init__(self, key: jax.Array | None, dtype=jnp.bfloat16, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract

    def _next(self):
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, shape, axes, fan_in: int | None = None, scale=None):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), axes)
        fan_in = fan_in if fan_in is not None else shape[0]
        scale = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
        return Param(normal_init(self._next(), shape, scale, self.dtype), axes)

    def embed(self, shape, axes, scale=0.02):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), self.dtype), axes)
        return Param(normal_init(self._next(), shape, scale, self.dtype), axes)

    def zeros(self, shape, axes, dtype=None):
        dt = jnp.dtype(dtype or self.dtype)
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(shape), dt), axes)
        return Param(jnp.zeros(shape, dt), axes)

    def value(self, arr, axes):
        if self.abstract:
            return Param(jax.ShapeDtypeStruct(tuple(arr.shape), self.dtype), axes)
        return Param(arr.astype(self.dtype), axes)

    def fork(self) -> "ParamBuilder":
        return ParamBuilder(self._next(), self.dtype, self.abstract)


# ---------------------------------------------------------------------------
# Norms & misc
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """(1 + w) convention (init w = 0); accumulation in fp32."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(pb: ParamBuilder, dim: int, axis: str = "embed"):
    return {"scale": pb.zeros((dim,), (axis,), dtype=jnp.float32)}


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard rotate-half RoPE.  x [..., S, H, hd], positions [..., S]."""
    hd = x.shape[-1]
    inv = _rope_inv_freq(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * inv  # [..., S, hd/2]
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): positions [3, ..., S] — (t, h, w) streams.

    The hd/2 frequency dims are split into ``sections`` (t/h/w); each slice
    rotates with its own position stream.  For text, all three streams are
    identical and M-RoPE degenerates to standard RoPE.
    """
    hd = x.shape[-1]
    if sum(sections) != hd // 2:
        raise ValueError(f"mrope sections {sections} must sum to head_dim/2={hd // 2}")
    inv = _rope_inv_freq(hd, theta)  # [hd/2]
    # Select which position stream drives each frequency dim.
    sel = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # [hd/2]
    pos = positions.astype(jnp.float32)  # [3, ..., S]
    pos_per_freq = jnp.take(pos, sel, axis=0)  # [hd/2, ..., S] — stream per freq
    pos_per_freq = jnp.moveaxis(pos_per_freq, 0, -1)  # [..., S, hd/2]
    angles = (pos_per_freq * inv)[..., None, :]  # [..., S, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(pb: ParamBuilder, d_model: int, d_ff: int, kind: str):
    if kind in ("swiglu", "geglu"):
        return {
            "gate": pb.dense((d_model, d_ff), ("embed", "ffn")),
            "up": pb.dense((d_model, d_ff), ("embed", "ffn")),
            "down": pb.dense((d_ff, d_model), ("ffn", "embed")),
        }
    if kind == "gelu":
        return {
            "up": pb.dense((d_model, d_ff), ("embed", "ffn")),
            "down": pb.dense((d_ff, d_model), ("ffn", "embed")),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_fwd(params, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else lambda g: jax.nn.gelu(g, approximate=True)
        g = act(x @ params["gate"])
        return (g * (x @ params["up"])) @ params["down"]
    return jax.nn.gelu(x @ params["up"], approximate=True) @ params["down"]
