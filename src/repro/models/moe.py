"""Mixture-of-Experts with sort-based capacity dispatch (EP over 'model').

Tokens are routed top-k, sorted by expert id, and scattered into a
``[E, C, d]`` buffer (capacity ``C = N·k/E·capacity_factor``, overflow
dropped — standard capacity-based MoE).  Expert FFNs run as one grouped
einsum over the expert-sharded buffer; under GSPMD the token→expert
scatter/gather lowers to the all-to-all pattern of expert parallelism.

Supports the two assigned MoE flavours:
  * arctic-480b   — 128 experts top-2 with a *dense residual* FFN in
    parallel (the dense branch lives in the transformer block);
  * llama4-scout  — 16 experts top-1 plus an always-on *shared expert*.

Returns a load-balance auxiliary loss (Switch-style) for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


def init_moe(pb: layers.ParamBuilder, cfg: ModelConfig):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    p = {
        "router": pb.dense((d, e.n_experts), ("embed", "experts"), scale=0.02),
        "w_gate": pb.dense((e.n_experts, d, f), ("experts", "embed", "ffn"), fan_in=d),
        "w_up": pb.dense((e.n_experts, d, f), ("experts", "embed", "ffn"), fan_in=d),
        "w_down": pb.dense((e.n_experts, f, d), ("experts", "ffn", "embed"), fan_in=f),
    }
    if e.shared_expert:
        p["shared"] = layers.init_mlp(pb, d, f, "swiglu")
    return p


def _moe_ep_shardmap(params, xf, top_w, top_i, cfg: ModelConfig, shard, exact: bool):
    """§Perf B2: explicit expert parallelism over the 'model' axis.

    GSPMD lowers the global scatter/gather dispatch as buffer-sized
    all-reduces over 'model' (~60 GB/layer/device on arctic — EXPERIMENTS.md
    §Perf).  Here each model shard owns E/tp experts; tokens are already
    model-replicated between layers (Megatron-style activations), so
    dispatch is local masking and the combine is ONE psum of [N_loc, d] —
    the same cost as a dense-FFN TP all-reduce.
    """
    from jax import lax
    from jax.sharding import PartitionSpec as P

    e = cfg.moe
    mesh = shard.mesh
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    E, k = e.n_experts, e.top_k
    E_loc = E // tp
    N = xf.shape[0]
    d = xf.shape[1]
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    N_loc = N // dp
    C = N_loc * k if exact else max(1, int(round(N_loc * k / E * e.capacity_factor)))

    def body(xf_l, top_w_l, top_i_l, wg, wu, wd):
        r = lax.axis_index("model")
        eid = top_i_l.reshape(-1)  # [N_loc·k]
        order = jnp.argsort(eid, stable=True)
        eid_s = eid[order]
        tok_s = order // k
        w_s = top_w_l.reshape(-1)[order]
        counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
        starts = jnp.cumsum(counts) - counts
        slot = jnp.arange(N_loc * k, dtype=jnp.int32) - starts[eid_s]
        # Keep only this shard's experts; OOB indices drop in the scatter.
        eidx = eid_s - r * E_loc
        oob = (eidx < 0) | (eidx >= E_loc) | (slot >= C)
        eidx = jnp.where(oob, E_loc, eidx)  # force-drop
        buf = jnp.zeros((E_loc, C, d), xf_l.dtype)
        buf = buf.at[eidx, slot].set(xf_l[tok_s], mode="drop")
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = g * jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
        contrib = out_buf.at[eidx, slot].get(mode="fill", fill_value=0)
        contrib = contrib * w_s[:, None].astype(xf_l.dtype)
        y_r = jnp.zeros((N_loc, d), xf_l.dtype).at[tok_s].add(contrib)
        return lax.psum(y_r, "model")

    from repro import compat
    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp_axes, None), P(dp_axes, None), P(dp_axes, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=P(dp_axes, None),
        check_vma=False,
    )(xf, top_w, top_i, params["w_gate"], params["w_up"], params["w_down"])


def moe_fwd(
    params, x: jax.Array, cfg: ModelConfig, shard=None, exact: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x [B, S, d] → (y [B, S, d], aux_loss scalar fp32).

    ``exact=True`` sets capacity C = N·k so no token can be dropped —
    used for decode (tiny N) where capacity-dropping would corrupt single
    requests; train/prefill keep the standard capacity factor.
    """
    e = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, k = e.n_experts, e.top_k
    xf = x.reshape(N, d)

    logits = (xf @ params["router"].astype(jnp.float32)
              if params["router"].dtype != jnp.float32
              else xf.astype(jnp.float32) @ params["router"])  # [N, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)  # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux: E · Σ_e frac_tokens_e · mean_prob_e.
    frac = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    # §Perf B2: explicit-EP path when a mesh with a dividing 'model' axis
    # is active (production path); pjit scatter/gather otherwise (baseline,
    # and the single-device smoke-test path).  Decode (exact=True) keeps
    # the pjit path: with one token per slot the EP in_specs would
    # re-gather FSDP expert weights every step (~60 GB/token on arctic —
    # measured 0.37 s → 2.5 s regression before this guard).
    if (
        not exact
        and shard is not None
        and getattr(shard, "mesh", None) is not None
        and getattr(shard, "constrain_attention", True)
        and "model" in shard.mesh.shape
        and E % shard.mesh.shape["model"] == 0
    ):
        y = _moe_ep_shardmap(params, xf, top_w, top_i, cfg, shard, exact)
        if e.shared_expert:
            y = y + layers.mlp_fwd(params["shared"], xf, "swiglu")
        return y.reshape(B, S, d), aux

    # Sort token-expert assignments by expert id.
    eid = top_i.reshape(-1)  # [N*k]
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = order // k
    w_s = top_w.reshape(-1)[order]

    counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(N * k, dtype=jnp.int32) - starts[eid_s]

    C = N * k if exact else max(1, int(round(N * k / E * e.capacity_factor)))
    # Scatter tokens into the expert buffer; slot >= C drops (capacity).
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[eid_s, slot].set(xf[tok_s], mode="drop")
    if shard is not None:
        buf = shard(buf, "experts", None, None)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if shard is not None and getattr(shard, "constrain_attention", True):
        # §Perf B1: reshard expert-major → d-major BEFORE the combine
        # gather.  With ``out_buf`` expert-sharded, GSPMD lowers the
        # [N·k, d] gather/scatter as a full all-reduce over 'model'
        # (~60 GB/layer/device); d-sharding turns both into local ops +
        # one small all-to-all (measured in EXPERIMENTS.md §Perf).
        out_buf = shard(out_buf, None, None, "moe_d")

    gathered = out_buf.at[eid_s, slot].get(mode="fill", fill_value=0)  # [N*k, d]
    y = jnp.zeros((N, d), x.dtype).at[tok_s].add(gathered * w_s[:, None].astype(x.dtype))
    if shard is not None and getattr(shard, "constrain_attention", True):
        y = shard(y, None, "moe_d")

    if e.shared_expert:
        y = y + layers.mlp_fwd(params["shared"], xf, "swiglu")
    return y.reshape(B, S, d), aux
