"""Pallas closure kernel vs oracles: shape/density/block sweeps (interpret)."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import bitset
from repro.core.closure import batched_closure_np
from repro.core.context import FormalContext
from repro.kernels import ops, ref
from repro.kernels.closure import closure_pallas

settings.register_profile("kern", deadline=None, max_examples=20)
settings.load_profile("kern")


def _case(N, m, B, density, cand_density, seed):
    ctx = FormalContext.synthetic(N, m, density, seed=seed)
    rng = np.random.default_rng(seed + 1)
    cands = bitset.pack_bool(rng.random((B, m)) < cand_density)
    return ctx, cands


def _check(ctx, cands, block_b=8, block_n=256):
    rows_p, _ = ctx.padded_rows(block_n)
    kc, ks = ops.batched_closure(
        jnp.asarray(rows_p), jnp.asarray(cands), ctx.n_attrs,
        n_valid_rows=ctx.n_objects, block_b=block_b, block_n=block_n,
    )
    oc, os_ = batched_closure_np(ctx.rows, cands, ctx.attr_mask())
    np.testing.assert_array_equal(np.asarray(kc), oc)
    np.testing.assert_array_equal(np.asarray(ks), os_)


@pytest.mark.parametrize("N,m,B", [
    (1, 1, 1), (7, 3, 2), (255, 31, 5), (256, 32, 8), (257, 33, 9),
    (512, 125, 16), (100, 294, 3), (64, 1000, 4),
])
def test_kernel_shape_sweep(N, m, B):
    ctx, cands = _case(N, m, B, 0.3, 0.1, seed=N + m + B)
    _check(ctx, cands)


@pytest.mark.parametrize("density", [0.0, 0.02, 0.5, 0.98, 1.0])
def test_kernel_density_sweep(density):
    ctx, cands = _case(200, 64, 8, density, 0.2, seed=3)
    _check(ctx, cands)


@pytest.mark.parametrize("block_b,block_n", [(1, 64), (8, 64), (16, 512), (4, 128)])
def test_kernel_block_sweep(block_b, block_n):
    ctx, cands = _case(300, 50, 13, 0.25, 0.1, seed=9)
    _check(ctx, cands, block_b=block_b, block_n=block_n)


def test_kernel_empty_candidate_full_candidate():
    ctx, _ = _case(100, 40, 1, 0.3, 0.0, seed=5)
    empty = np.zeros((1, ctx.W), np.uint32)
    full = ctx.attr_mask()[None, :]
    for cands in (empty, full):
        _check(ctx, cands)


def test_kernel_matches_ref_raw():
    """Raw (padded) kernel contract matches ref.closure_ref bit-for-bit."""
    ctx, cands = _case(256, 64, 8, 0.3, 0.1, seed=11)
    rows_p, _ = ctx.padded_rows(256)
    kc, ks = closure_pallas(jnp.asarray(rows_p), jnp.asarray(cands))
    rc, rs = ref.closure_ref(jnp.asarray(rows_p), jnp.asarray(cands))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


def test_kernel_rejects_overwide():
    rows = jnp.zeros((256, 600), jnp.uint32)
    cands = jnp.zeros((8, 600), jnp.uint32)
    with pytest.raises(ValueError, match="MAX_W"):
        closure_pallas(rows, cands)


def test_wide_context_falls_back_to_ref():
    """ops.batched_closure silently uses the jnp path beyond MAX_W words."""
    m = 600 * 32  # > MAX_W words
    ctx = FormalContext.synthetic(40, m, 0.02, seed=2)
    cands = bitset.pack_bool(np.random.default_rng(0).random((2, m)) < 0.01)
    rows_p, _ = ctx.padded_rows(8)
    kc, ks = ops.batched_closure(
        jnp.asarray(rows_p), jnp.asarray(cands), m, n_valid_rows=ctx.n_objects
    )
    oc, os_ = batched_closure_np(ctx.rows, cands, ctx.attr_mask())
    np.testing.assert_array_equal(np.asarray(kc), oc)
    np.testing.assert_array_equal(np.asarray(ks), os_)


@pytest.mark.parametrize("W", [ops.MAX_W, ops.MAX_W + 1])
def test_max_w_boundary_matches_oracle(W):
    """Both sides of the silent W > MAX_W fallback agree with the numpy
    oracle — same closures AND identically-corrected supports.  W = MAX_W
    takes the Pallas kernel; W = MAX_W + 1 takes the jnp reference path;
    a caller cannot tell them apart."""
    m = W * 32 - 5  # exactly W packed words (bitset.n_words(m) == W)
    ctx = FormalContext.synthetic(10, m, 0.02, seed=W)
    cands = bitset.pack_bool(
        np.random.default_rng(W).random((2, m)) < 0.01
    )
    assert ctx.W == W
    rows_p, _ = ctx.padded_rows(64)
    kc, ks = ops.batched_closure(
        jnp.asarray(rows_p), jnp.asarray(cands), m,
        n_valid_rows=ctx.n_objects, block_n=64,
    )
    oc, os_ = batched_closure_np(ctx.rows, cands, ctx.attr_mask())
    np.testing.assert_array_equal(np.asarray(kc), oc)
    np.testing.assert_array_equal(np.asarray(ks), os_)


def test_pad_correction_exact_block_multiple():
    """N already an exact block_n multiple → zero all-ones pad rows are
    added, and the support correction must be exactly the external pad
    count (here 0), not an off-by-block constant."""
    for N, block_n in ((128, 64), (256, 256), (64, 64)):
        ctx, cands = _case(N, 40, 8, 0.4, 0.15, seed=N)
        rows_p, n_pad = ctx.padded_rows(block_n)
        assert n_pad == 0 and rows_p.shape[0] % block_n == 0
        _check(ctx, cands, block_n=block_n)


@given(
    st.integers(1, 300), st.integers(1, 130), st.integers(1, 12),
    st.floats(0.05, 0.9), st.integers(0, 10_000),
)
def test_kernel_hypothesis(N, m, B, density, seed):
    ctx, cands = _case(N, m, B, density, 0.15, seed)
    _check(ctx, cands, block_n=64)
