"""Frontier-axis sharding — the 2-D candidate × object decomposition.

Covers the ShardPlan ``cand_parts`` geometry, the ``spmd_cand`` primitive,
driver equivalence across candidate shard counts (every 2-D plan must mine
the exact host-oracle concept set), the headline regression — a frontier
larger than one device's ``max_batch`` budget mining correctly instead of
being silently truncated — and the 2-D wire accounting.  The real-mesh
twin of these assertions lives in tests/test_distributed_8dev.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClosureEngine,
    all_closures_batched,
    bitset,
    mrcbo,
    mrganter,
    mrganter_plus,
)
from repro.core.context import FormalContext
from repro.core.frontier import DeviceFrontier
from repro.dist.shardplan import SIM_CAND_AXIS, ShardPlan

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

settings.register_profile("cand", deadline=None, max_examples=8)
settings.load_profile("cand")


def _keys(intents):
    return {bitset.key_bytes(y) for y in intents}


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(90, 21, 0.25, seed=4)


@pytest.fixture(scope="module")
def ref(ctx):
    return _keys(all_closures_batched(ctx))


# -- geometry ----------------------------------------------------------------


def test_cand_geometry_simulated():
    plan = ShardPlan.simulated(4, cand_parts=3, block_n=64)
    assert plan.cand_parts == 3
    assert plan.cand_axes == SIM_CAND_AXIS
    assert plan.cand_axis_names == (SIM_CAND_AXIS,)
    d = plan.describe()
    assert d["cand_parts"] == 3 and d["cand_axes"] == [SIM_CAND_AXIS]
    # 1-D plans advertise no candidate axis
    one = ShardPlan.simulated(4)
    assert one.cand_parts == 1 and one.cand_axes is None
    assert one.describe()["cand_parts"] == 1


def test_cand_geometry_validation():
    with pytest.raises(ValueError, match="cand_parts"):
        ShardPlan.simulated(2, cand_parts=0)


def test_round_budget_scales_with_cand_parts(ctx):
    e1 = ClosureEngine(
        ctx, plan=ShardPlan.simulated(2, block_n=64, max_batch=128),
        backend="jnp",
    )
    e2 = ClosureEngine(
        ctx,
        plan=ShardPlan.simulated(2, cand_parts=4, block_n=64, max_batch=128),
        backend="jnp",
    )
    assert DeviceFrontier(e1).round_budget == 128
    assert DeviceFrontier(e2).round_budget == 4 * 128


# -- spmd_cand: the primitive ------------------------------------------------


def test_spmd_cand_blocks_and_gathers():
    """Candidate operands are blocked, the object reduce runs per block,
    and outputs come back as [cand_parts, ...] stacks ready to merge."""
    plan = ShardPlan.simulated(2, cand_parts=3, block_n=4)
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 1 << 32, size=(16, 3), dtype=np.uint32)
    cands = rng.integers(0, 1 << 32, size=(12, 3), dtype=np.uint32)
    placed = plan.place_rows(rows)

    from repro.dist import collectives

    def body(rows_local, cb):
        return collectives.and_allreduce(
            rows_local[:1] & cb, plan.reduce_axes, impl="rsag"
        )

    def post(idx, gc, n_valid):
        valid = (jnp.arange(gc.shape[0]) + idx * gc.shape[0]) < n_valid
        return jnp.where(valid[:, None], gc, 0), valid.sum(dtype=jnp.int32)

    fn = jax.jit(plan.spmd_cand(body, n_cand=1, post=post, n_post_rep=1))
    gcs, counts = fn(placed, jnp.asarray(cands), jnp.int32(10))
    assert gcs.shape == (3, 4, 3) and counts.shape == (3,)
    ref = (rows[0] & cands) & (rows[8] & cands)
    ref[10:] = 0
    np.testing.assert_array_equal(np.asarray(gcs).reshape(12, 3), ref)
    np.testing.assert_array_equal(np.asarray(counts), [4, 4, 2])


def test_spmd_cand_degenerates_at_one_block():
    """cand_parts == 1 runs the degenerate branch of both spmd_cand paths
    (length-1 gather stack on a mesh, single outer vmap lane simulated)
    and must be bit-identical to the multi-block result."""
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 1 << 32, size=(16, 3), dtype=np.uint32)
    cands = rng.integers(0, 1 << 32, size=(12, 3), dtype=np.uint32)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))

    from repro.dist import collectives

    def post(idx, gc, n_valid):
        valid = (jnp.arange(gc.shape[0]) + idx * gc.shape[0]) < n_valid
        return jnp.where(valid[:, None], gc, 0), valid.sum(dtype=jnp.int32)

    outs = []
    for plan in (
        ShardPlan.simulated(2, cand_parts=1, block_n=4),
        ShardPlan.over_mesh(mesh, block_n=4),  # mesh degenerate: cp == 1
        ShardPlan.simulated(2, cand_parts=3, block_n=4),
    ):
        assert (plan.cand_parts == 1) == (plan.cand_axes is None)

        def body(rows_local, cb, plan=plan):
            return collectives.and_allreduce(
                rows_local[:1] & cb, plan.reduce_axes, impl="rsag"
            )

        fn = jax.jit(plan.spmd_cand(body, n_cand=1, post=post, n_post_rep=1))
        gcs, counts = fn(
            plan.place_rows(rows), jnp.asarray(cands), jnp.int32(10)
        )
        assert gcs.shape[0] == plan.cand_parts
        assert int(np.asarray(counts).sum()) == 10
        outs.append(np.asarray(gcs).reshape(12, 3))
    np.testing.assert_array_equal(outs[0], outs[2])  # sim cp=1 ≡ cp=3
    # mesh plan has 1 object shard: rows[0] only (sim-2 ANDs rows[0]&rows[8])
    ref = rows[0] & cands
    ref[10:] = 0
    np.testing.assert_array_equal(outs[1], ref)


@pytest.mark.parametrize("cand_parts", [1, 2, 4])
def test_mesh_one_device_matches_simulated(ctx, cand_parts):
    """A 1-D one-device mesh (the only mesh the main pytest process can
    build) against simulated plans of every cand_parts: the mining result
    must be bit-identical regardless of the decomposition (the real
    cand×data mesh runs in tests/test_distributed_8dev.py)."""
    e_sim = ClosureEngine(
        ctx,
        plan=ShardPlan.simulated(1, cand_parts=cand_parts, block_n=64),
        backend="jnp",
    )
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    e_mesh = ClosureEngine(
        ctx, plan=ShardPlan.over_mesh(mesh, block_n=64), backend="jnp"
    )
    r_sim = mrganter_plus(ctx, e_sim, local_prune=True)
    r_mesh = mrganter_plus(ctx, e_mesh, local_prune=True)
    assert sorted(y.tobytes() for y in r_sim.intents) == sorted(
        y.tobytes() for y in r_mesh.intents
    )


# -- driver equivalence across candidate shard counts ------------------------


@pytest.mark.parametrize("cand_parts", [2, 3, 4])
def test_mrganter_plus_cand_sharded_matches_oracle(ctx, ref, cand_parts):
    plan = ShardPlan.simulated(
        3, cand_parts=cand_parts, block_n=64, max_batch=64
    )
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    assert _keys(res.intents) == ref


@pytest.mark.parametrize("dedupe_closures", [False, True])
def test_mrganter_plus_cand_sharded_dedupe_modes(ctx, ref, dedupe_closures):
    plan = ShardPlan.simulated(2, cand_parts=2, block_n=64, max_batch=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, dedupe_closures=dedupe_closures)
    assert _keys(res.intents) == ref


def test_mrcbo_cand_sharded_matches_oracle(ctx, ref):
    plan = ShardPlan.simulated(3, cand_parts=2, block_n=64, max_batch=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrcbo(ctx, eng)
    assert _keys(res.intents) == ref


def test_mrganter_cand_sharded_preserves_lectic_order(ctx):
    """MRGanter runs the 1-D step regardless (single-intent frontier); a
    cand-sharded plan must not disturb exact lectic emission order."""
    e1 = ClosureEngine(ctx, plan=ShardPlan.simulated(2, block_n=64),
                       backend="jnp")
    e2 = ClosureEngine(
        ctx, plan=ShardPlan.simulated(2, cand_parts=2, block_n=64),
        backend="jnp",
    )
    r1 = mrganter(ctx, e1, max_iterations=40)
    r2 = mrganter(ctx, e2, max_iterations=40)
    assert len(r1.intents) == len(r2.intents)
    for a, b in zip(r1.intents, r2.intents):
        np.testing.assert_array_equal(a, b)


def test_iceberg_cand_sharded_matches_posthoc(ctx):
    from repro.query.store import host_supports

    full = np.stack(all_closures_batched(ctx))
    sups = host_supports(ctx, full)
    s = 8
    want = _keys(full[sups >= s])
    for driver in (mrganter_plus, mrcbo):
        plan = ShardPlan.simulated(2, cand_parts=2, block_n=64, max_batch=64)
        eng = ClosureEngine(ctx, plan=plan, backend="jnp")
        res = driver(ctx, eng, min_support=s)
        assert _keys(res.intents) == want, driver.__name__


# -- the headline regression: frontier > max_batch ---------------------------


def test_frontier_exceeding_max_batch_mines_completely(ctx, ref):
    """The bug this sweep headlines: a frontier bigger than one device's
    ``max_batch`` chunk budget must mine the complete concept set — no
    silent truncation anywhere in the adopt/chunk path.  max_batch=16 is
    far below this context's peak frontier (hundreds of candidates)."""
    for cand_parts in (1, 2, 4):
        plan = ShardPlan.simulated(
            2, cand_parts=cand_parts, block_n=64, max_batch=16
        )
        eng = ClosureEngine(ctx, plan=plan, backend="jnp")
        res = mrganter_plus(ctx, eng, local_prune=True)
        assert _keys(res.intents) == ref, cand_parts
        eng2 = ClosureEngine(ctx, plan=plan, backend="jnp")
        res2 = mrcbo(ctx, eng2)
        assert _keys(res2.intents) == ref, cand_parts
        # the peak adopted frontier really did exceed the per-chunk budget
        assert res2.n_concepts > 16


def test_adopt_refuses_to_drop_rows(ctx):
    """_adopt raises instead of silently truncating live frontier rows."""
    eng = ClosureEngine(ctx, plan=ShardPlan.simulated(1), backend="jnp")
    fr = DeviceFrontier(eng)
    with pytest.raises(RuntimeError, match="cand-shards"):
        fr._adopt(jnp.zeros((4, ctx.W), jnp.uint32), None, 9)


# -- wire accounting ---------------------------------------------------------


def test_cand_round_bytes_model():
    from repro.dist import collectives

    plan = ShardPlan.simulated(4, cand_parts=2, reduce_impl="rsag")
    blk, W, m = 128, 3, 70
    obj = 2 * collectives.modeled_comm_bytes("rsag", 4, blk, W, m)
    gather = 4 * 2 * 1 * blk * W * 4
    assert plan.modeled_round_bytes_cand(blk, W, m) == obj + gather
    # 1-D degenerate: no cand gather, identical to the 1-D model
    one = ShardPlan.simulated(4, reduce_impl="rsag")
    assert one.modeled_round_bytes_cand(blk, W, m) == one.modeled_reduce_bytes(
        blk, W, m
    )


def test_cand_sharding_reduces_modeled_bytes_per_round(ctx, ref):
    """At equal total devices (8 = 8×1 vs 4×2), splitting the mesh between
    objects and candidates cuts the modeled reduce traffic: the object
    rings shrink and each runs at the block batch size."""
    e1 = ClosureEngine(
        ctx, plan=ShardPlan.simulated(8, block_n=8, max_batch=256),
        backend="jnp",
    )
    e2 = ClosureEngine(
        ctx,
        plan=ShardPlan.simulated(4, cand_parts=2, block_n=8, max_batch=128),
        backend="jnp",
    )
    r1 = mrganter_plus(ctx, e1, local_prune=True)
    r2 = mrganter_plus(ctx, e2, local_prune=True)
    assert _keys(r1.intents) == _keys(r2.intents) == ref
    assert e2.stats.modeled_comm_bytes < e1.stats.modeled_comm_bytes
    # every 2-D dispatch recorded a schedule choice
    assert sum(e2.stats.reduce_rounds.values()) == e2.stats.closure_calls


def test_auto_schedule_resolves_per_block(ctx, ref):
    plan = ShardPlan.simulated(
        4, cand_parts=2, reduce_impl="auto", block_n=64, max_batch=64
    )
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    assert _keys(res.intents) == ref
    assert set(eng.stats.reduce_rounds) <= {"allgather", "rsag"}


# -- hop-probe cache keys on the full plan geometry --------------------------


def test_hop_probe_cache_keys_on_cand_geometry():
    """A calibrated hop value must not leak between plans of different
    geometry: same object shard count but different candidate blocking
    gets a fresh probe (the old cache keyed on (n_parts, devices) only)."""
    from repro.dist import shardplan as sp

    sp._HOP_PROBE_CACHE.clear()
    try:
        ShardPlan.simulated(4, calibrate_hops=True)
        assert len(sp._HOP_PROBE_CACHE) == 1
        key = next(iter(sp._HOP_PROBE_CACHE))
        sp._HOP_PROBE_CACHE[key] = (999_999, True)  # poison the 4×1 entry
        plan2 = ShardPlan.simulated(4, cand_parts=2, calibrate_hops=True)
        # the 4×2 plan must NOT have read the poisoned 4×1 measurement
        assert plan2.auto_hop_bytes != 999_999
        assert len(sp._HOP_PROBE_CACHE) == 2
        # ... while the same geometry still hits its cache
        plan3 = ShardPlan.simulated(4, calibrate_hops=True)
        assert plan3.auto_hop_bytes == 999_999
    finally:
        sp._HOP_PROBE_CACHE.clear()


# -- randomized property sweep ----------------------------------------------


@given(
    st.integers(8, 50), st.integers(3, 18), st.floats(0.15, 0.5),
    st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 3),
)
def test_property_cand_sharded_equals_host(n, m, density, seed, n_parts, cp):
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    plan = ShardPlan.simulated(
        n_parts, cand_parts=cp, block_n=64, max_batch=32
    )
    eh = ClosureEngine(ctx, n_parts=n_parts, block_n=64, backend="jnp")
    ed = ClosureEngine(ctx, plan=plan, backend="jnp")
    rh = mrganter_plus(ctx, eh, pipeline="host", dedupe_candidates=True)
    rd = mrganter_plus(ctx, ed, pipeline="device", dedupe_candidates=True)
    assert _keys(rh.intents) == _keys(rd.intents)
    eh2 = ClosureEngine(ctx, n_parts=n_parts, block_n=64, backend="jnp")
    ed2 = ClosureEngine(ctx, plan=plan, backend="jnp")
    assert _keys(mrcbo(ctx, eh2, pipeline="host").intents) == _keys(
        mrcbo(ctx, ed2, pipeline="device").intents
    )
