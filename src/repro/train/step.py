"""The jitted train/serve steps with explicit shardings — shared by the
real trainer and the multi-pod dry-run (the dry-run lowers exactly these).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.partition import Partitioner
from repro.models import transformer
from repro.models.config import ModelConfig, ShapeConfig


def make_loss_fn(cfg: ModelConfig, partitioner: Partitioner | None):
    shard = partitioner if (partitioner and partitioner.mesh) else None

    def loss_fn(params, batch):
        return transformer.train_loss_fn(params, cfg, batch, shard=shard)

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, partitioner: Partitioner | None):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (un-jitted).

    state = {"params": ..., "opt": ..., "step": int32}
    batch = {"inputs": ..., "labels": ..., ["positions"]}
    """
    loss_fn = make_loss_fn(cfg, partitioner)

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        new_params, new_opt = optimizer.apply(grads, state["opt"], state["params"])
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, partitioner: Partitioner | None):
    shard = partitioner if (partitioner and partitioner.mesh) else None

    def prefill_step(params, inputs, caches, rope_positions=None):
        return transformer.prefill(
            params, cfg, inputs, caches, rope_positions=rope_positions, shard=shard
        )

    return prefill_step


def make_decode_step(cfg: ModelConfig, partitioner: Partitioner | None):
    shard = partitioner if (partitioner and partitioner.mesh) else None

    def decode_step(params, inputs, t, caches, rope_positions=None):
        return transformer.decode_step(
            params, cfg, inputs, t, caches, rope_positions=rope_positions, shard=shard
        )

    return decode_step


# ---------------------------------------------------------------------------
# Sharding trees for jit in_shardings / dry-run
# ---------------------------------------------------------------------------


def state_shardings(partitioner: Partitioner, params_axes, abstract_params, optimizer):
    p_sh = partitioner.tree_shardings(params_axes, abstract_params)
    opt_axes = optimizer.state_axes(params_axes)
    abstract_opt = jax.eval_shape(
        optimizer.init, abstract_params
    )
    o_sh = partitioner.tree_shardings(opt_axes, abstract_opt)
    return {"params": p_sh, "opt": o_sh, "step": partitioner.replicated()}


def batch_shardings(partitioner: Partitioner, abstract_batch):
    out = {}
    for k, v in abstract_batch.items():
        if k == "positions" and v.ndim == 3:  # mrope [3, B, S]
            out[k] = partitioner.batch_spec(v.shape, batch_dim=1)
        else:
            out[k] = partitioner.batch_spec(v.shape, batch_dim=0)
    return out


def cache_shardings(partitioner: Partitioner, cfg: ModelConfig, abstract_caches):
    """KV/state caches: batch over DP axes, kv-heads over model (when they
    divide) — from the logical-axes tree mirroring the cache structure."""
    axes = transformer.cache_axes(cfg)
    return partitioner.tree_shardings(axes, abstract_caches)
