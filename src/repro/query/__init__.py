"""repro.query — device-resident concept store, batched query engine, and
streaming updates.

Mining (repro.core.mr) produces the lattice; this package makes it a
first-class servable artifact, closing the paper's §1.1 gap ("batch
algorithms … require that the entire lattice is reconstructed from scratch
if the database changes") on the *serving* side:

  * :mod:`repro.query.store`  — ``ConceptStore``: plan-sharded context +
    extent tables, replicated intent table, the paper's two-level hash
    index (head-attr × popcount) as device arrays, and the covering
    relation materialized by a subset-test matmul.
  * :mod:`repro.query.engine` — ``QueryEngine``: fixed-slot micro-batched
    closure / lookup / traversal / top-k queries; each micro-batch is one
    ``ShardPlan.spmd`` round, so B queries cost one collective, not B.
  * :mod:`repro.query.stream` — ``StreamUpdater``: batched device-side
    Godin insertion with double-buffered snapshots; queries keep serving
    the active snapshot while an update batch stages, then ``commit()``
    swaps atomically.
"""

from repro.query.engine import QueryEngine, QueryStats
from repro.query.store import ConceptStore, Snapshot
from repro.query.stream import StreamUpdater

__all__ = [
    "ConceptStore",
    "Snapshot",
    "QueryEngine",
    "QueryStats",
    "StreamUpdater",
]
