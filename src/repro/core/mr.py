"""The MR* miners: MRGanter, MRGanter+ and MRCbo (paper §3), as iterative
drivers over a :class:`repro.core.engine.ClosureEngine`.

Each driver is the Twister control loop: the engine holds the static data
(context sharded by its :class:`repro.dist.ShardPlan`); the *dynamic data*
— the frontier of previous intents — crosses the host/device boundary once
per iteration, exactly like Twister re-configuring its long-running map
tasks with the previous iteration's closures.  Every closure round the
drivers issue executes through the engine's plan — one partitioned path
whether the partitions are a real device mesh or simulated on one chip.

Two frontier substrates (``pipeline=``):

  * ``"device"`` (default) — the device-resident pipeline of
    :mod:`repro.core.frontier`: seed expansion, dedupe/canonicity and
    feasibility all run as jitted bucket-shaped device ops; the host loop
    is convergence control plus the global registry.  O(1) bulk transfers
    per iteration.  The chunk geometry follows the plan: a 1-D plan
    chunks the candidate stream at ``max_batch``; a cand-sharded plan
    (``ShardPlan.cand_parts > 1``) absorbs ``cand_parts × max_batch``
    candidates per round by blocking each chunk over the candidate axis
    (MRGanter+/MRCbo; MRGanter's single-intent frontier stays 1-D).
  * ``"host"`` — the paper-literal host loop (per-intent Python seed
    building, per-row hash inserts).  Kept as the equivalence oracle and
    the baseline for EXPERIMENTS.md §Perf.

Both substrates produce bit-identical concept sets
(tests/test_frontier_pipeline.py); MRGanter additionally preserves exact
lectic emission order on both.

Iteration counts follow the paper's convention (Table 9): every map/reduce
round over the full context counts as one iteration, including the round
that computes ``∅''`` and, for MRGanter+/MRCbo, the final round that proves
the frontier is exhausted.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax.numpy as jnp
import numpy as np

from repro.core import bitset, lectic
from repro.core.engine import ClosureEngine
from repro.core.frontier import DeviceFrontier
from repro.core.hashindex import TwoLevelHash
from repro.obs import trace as obs

PIPELINES = ("device", "host")

# Round scheduling for the device pipeline.  ``"sync"`` blocks on every
# round's survivor count before dispatching the next (the bit-exact
# oracle); ``"async"`` speculatively dispatches round r+1 against round
# r's unreconciled survivor buffer while r's reduce is in flight, so the
# host never blocks between rounds (see DeviceFrontier.spec_*/reconcile_*
# and EXPERIMENTS.md §Async).  Concept sets and iteration counts are
# identical in both modes; per-round *stats* may differ (speculative
# chunks are padded to their coverage cap before the true count is known).
ROUNDS = ("sync", "async")


@dataclasses.dataclass
class MRResult:
    intents: list[np.ndarray]
    n_iterations: int
    n_closures_computed: int
    modeled_comm_bytes: int
    wall_time_s: float
    algorithm: str
    # iceberg runs record their (absolute) threshold; None == full lattice
    min_support: int | None = None

    @property
    def n_concepts(self) -> int:
        return len(self.intents)


def _traced_driver(algo: str):
    """Wrap a public MR* driver in the run's root trace span.

    The span carries the run configuration (pipeline / rounds mode) so a
    saved timeline is self-describing; with the no-op tracer installed
    (the default) the wrapper is one dict construction per *mine*."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with obs.current().span(
                f"mine/{algo}",
                pipeline=kwargs.get("pipeline", "device"),
                rounds=kwargs.get("rounds", "sync"),
            ):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def _seeds_for(Y: np.ndarray, tables: lectic.LecticTables) -> np.ndarray:
    seeds, valid = lectic.oplus_seeds_all(Y, tables)
    return seeds[valid]


def _check_pipeline(pipeline: str):
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; choose {PIPELINES}")


def _check_rounds(rounds: str, pipeline: str):
    if rounds not in ROUNDS:
        raise ValueError(f"unknown rounds mode {rounds!r}; choose {ROUNDS}")
    if rounds == "async" and pipeline != "device":
        raise ValueError(
            "rounds='async' requires pipeline='device' — the host loop has "
            "no device futures to overlap"
        )


def _result(
    engine: ClosureEngine, intents, n_iter, t0, algorithm, min_support=None
) -> MRResult:
    return MRResult(
        intents=intents,
        n_iterations=n_iter,
        n_closures_computed=engine.stats.closures_computed,
        modeled_comm_bytes=engine.stats.modeled_comm_bytes,
        wall_time_s=time.perf_counter() - t0,
        algorithm=algorithm,
        min_support=min_support,
    )


def _check_min_support(min_support: int | None) -> int | None:
    """Validate and normalize the iceberg threshold (absolute count)."""
    if min_support is None:
        return None
    s = int(min_support)
    if s != min_support or s < 1:
        raise ValueError(
            f"min_support must be a positive object count, got {min_support!r}"
            " (use repro.rules.resolve_min_support for fractional thresholds)"
        )
    return s


# ---------------------------------------------------------------------------
# MRGanter (Algorithms 4 + 5): strict lectic order, one concept/iteration.
# ---------------------------------------------------------------------------


@_traced_driver("mrganter")
def mrganter(
    ctx,
    engine: ClosureEngine,
    max_iterations: int | None = None,
    *,
    pipeline: str = "device",
    min_support: int | None = None,
    rounds: str = "sync",
) -> MRResult:
    """``min_support`` mines the iceberg lattice in strict lectic order:
    the Alg.-5 scan restricts to frequent successors (support psum ≥
    threshold, fused into the SPMD round).  The next *frequent* closure
    after Y is Y ⊕ a for the largest feasible frequent a — any frequent
    closure lectically between would be a subset of Y ⊕ a for the smallest
    differing attribute, hence itself of the form Y ⊕ i — so the jump
    skips infrequent closures without ever visiting them.

    ``rounds="async"`` (device pipeline) chains Alg.-5 steps entirely on
    device: each step's selected intent is broadcast into the frontier
    slot at dispatch time, step r+1 is dispatched before step r's packed
    readback is awaited, and a step dispatched past the true end of the
    walk is discarded unread.  Emission order stays exactly lectic."""
    _check_pipeline(pipeline)
    _check_rounds(rounds, pipeline)
    min_support = _check_min_support(min_support)
    t0 = time.perf_counter()
    full = ctx.attr_mask()
    Y, s0 = engine.first_closure()
    if min_support is not None and s0 < min_support:
        return _result(engine, [], 1, t0, "mrganter", min_support)
    intents = [Y]
    n_iter = 1

    if pipeline == "device" and rounds == "async":
        return _mrganter_async(
            engine, Y, full, intents, n_iter, t0,
            max_iterations=max_iterations, min_support=min_support,
        )

    if pipeline == "device":
        fr = DeviceFrontier(engine)
        fr.set_frontier(Y[None, :])
        if min_support is None:
            done = np.array_equal(Y, full)
            while not done:
                if max_iterations is not None and n_iter >= max_iterations:
                    break
                Y, done = fr.step_ganter()
                intents.append(Y)
                n_iter += 1
            return _result(engine, intents, n_iter, t0, "mrganter")
        while not np.array_equal(Y, full):
            if max_iterations is not None and n_iter >= max_iterations:
                break
            Y, exhausted = fr.step_ganter(min_support=min_support)
            n_iter += 1  # the exhausting scan is a map/reduce round too
            if exhausted:
                break
            intents.append(Y)
        return _result(engine, intents, n_iter, t0, "mrganter", min_support)

    tables = lectic.LecticTables(ctx.n_attrs)
    while not np.array_equal(Y, full):
        if max_iterations is not None and n_iter >= max_iterations:
            break
        # Map: local closures for every attribute p_i ∉ d (Alg. 4).
        seeds, valid = lectic.oplus_seeds_all(Y, tables)
        closures, sups = engine.closure(seeds)  # Reduce: Theorem-2 intersection
        # Feasibility ≤_{p_i} (Alg. 5): first success scanning p_m → p_1.
        ok = lectic.feasible_batch(closures, Y, tables) & valid
        if min_support is not None:
            ok &= sups >= min_support
        if min_support is not None and not ok.any():
            n_iter += 1  # the exhausting scan
            break
        # Alg.-5 selection on device: jitted argmax + dynamic-slice gather
        # (identical to ``closures[int(np.nonzero(ok)[0].max())]`` — the
        # lectic-max feasible generator; property-tested in
        # tests/test_async_rounds.py).
        Y_dev, found = lectic.select_lectic_jnp(
            jnp.asarray(closures), jnp.asarray(ok)
        )
        assert bool(found), "NextClosure invariant: a feasible successor exists"
        Y = np.asarray(Y_dev)
        intents.append(Y)
        n_iter += 1
    return _result(engine, intents, n_iter, t0, "mrganter", min_support)


def _mrganter_async(
    engine, Y, full, intents, n_iter, t0, *, max_iterations, min_support
):
    """MRGanter's round loop restructured around futures: reconcile round
    r only after round r+1 is in flight."""
    fr = DeviceFrontier(engine)
    fr.set_frontier(Y[None, :])
    at_top = np.array_equal(Y, full)
    capped = max_iterations is not None and n_iter >= max_iterations
    pending = (
        None if at_top or capped
        else fr.spec_ganter(min_support=min_support)
    )
    if min_support is None:
        while pending is not None:
            speculate = max_iterations is None or n_iter + 1 < max_iterations
            nxt = fr.spec_ganter() if speculate else None
            Y, done = fr.reconcile_ganter(pending)
            intents.append(Y)
            n_iter += 1
            if done or nxt is None:
                fr.discard_spec(nxt)
                break
            pending = nxt
        return _result(engine, intents, n_iter, t0, "mrganter")
    while pending is not None:
        speculate = max_iterations is None or n_iter + 1 < max_iterations
        nxt = fr.spec_ganter(min_support=min_support) if speculate else None
        Y, exhausted = fr.reconcile_ganter(pending)
        n_iter += 1  # the exhausting scan is a map/reduce round too
        if exhausted:
            fr.discard_spec(nxt)
            break
        intents.append(Y)
        if np.array_equal(Y, full) or nxt is None:
            fr.discard_spec(nxt)
            break
        pending = nxt
    return _result(engine, intents, n_iter, t0, "mrganter", min_support)


# ---------------------------------------------------------------------------
# MRGanter+ (Algorithms 4 + 6): keep all new closures, dedupe via the
# two-level hash; iterations collapse to ~lattice depth.
# ---------------------------------------------------------------------------


@_traced_driver("mrganter_plus")
def mrganter_plus(
    ctx,
    engine: ClosureEngine,
    *,
    dedupe_candidates: bool = False,
    dedupe_closures: bool = False,
    local_prune: bool | None = None,
    max_iterations: int | None = None,
    pipeline: str = "device",
    min_support: int | None = None,
    rounds: str = "sync",
) -> MRResult:
    """``dedupe_candidates=False`` is the paper-literal map phase (every
    frontier intent emits a candidate for every absent attribute).  ``True``
    drops duplicate *seeds* before the closure — the paper's per-partition
    local pruning: on the device pipeline the dedupe is the on-device
    lexsort+adjacent-unique stage, run partition-locally *before* the
    AND-allreduce is sized, so pruned candidates never cross the wire
    (EXPERIMENTS.md §Dist quantifies the reduce-byte savings); on the host
    loop it is ``np.unique``.  Same output either way.  ``local_prune`` is
    the paper-facing alias for the same switch (it wins when both are
    given).

    ``min_support`` mines the iceberg lattice: closures below the
    threshold are compacted away right after the support psum (device
    pipeline: inside the same SPMD region) and never join the frontier —
    every subsequent round's expansion and reduce is sized by the
    surviving frequent set.  Lossless: each frequent closed Z ≠ ∅'' equals
    closure(D ⊕ a) for D = closure of Z's attributes below some a ∈ Z —
    a frequent (D ⊆ Z) closed proper subset — so the frequent subset of
    the BFS reaches every frequent concept (tests/test_rules.py asserts
    equality with post-hoc filtering, property-tested).

    ``rounds="async"`` (device pipeline) keeps the round-r survivor buffer
    on device and dispatches round r+1's expansion against it before round
    r's counts are read back; the host registry reconciles novelty one
    round behind the device.  The async frontier is the round's *unique
    closure set* (novel + stale) rather than the novel subset — stale rows
    only regenerate closures registered in earlier rounds, so the novel
    set per round, the concept set, and the iteration count are identical
    to sync (EXPERIMENTS.md §Async has the completeness argument).
    ``dedupe_closures`` is implied in async mode (the adopted slot must be
    deduped to bound stale re-expansion).
    """
    _check_pipeline(pipeline)
    _check_rounds(rounds, pipeline)
    if local_prune is not None:
        dedupe_candidates = local_prune
    min_support = _check_min_support(min_support)
    t0 = time.perf_counter()
    H = TwoLevelHash()
    Y0, s0 = engine.first_closure()
    if min_support is not None and s0 < min_support:
        return _result(engine, [], 1, t0, "mrganter+", min_support)
    H.add(Y0)
    intents = [Y0]
    n_iter = 1

    if pipeline == "device" and rounds == "async":
        return _mrganter_plus_async(
            ctx, engine, H, Y0, intents, n_iter, t0,
            dedupe_candidates=dedupe_candidates,
            max_iterations=max_iterations, min_support=min_support,
        )

    if pipeline == "device":
        fr = DeviceFrontier(engine, dedupe_closures=dedupe_closures)
        fr.set_frontier(Y0[None, :])
        while len(fr):
            if max_iterations is not None and n_iter >= max_iterations:
                break
            rounds_before = engine.stats.rounds
            uniq = fr.step_oplus(
                dedupe=dedupe_candidates, min_support=min_support
            )
            if uniq.shape[0] == 0:
                # an iceberg round can run and prune every closure — that
                # exhausting map/reduce round still counts (host parity)
                if engine.stats.rounds > rounds_before:
                    n_iter += 1
                break
            n_iter += 1
            new_idx = H.add_batch(uniq)  # global registry (vectorized)
            new = uniq[new_idx]
            intents.extend(new)
            if new.shape[0]:
                fr.set_frontier(new)  # the Twister dynamic delta, one upload
            else:
                fr.set_frontier(np.zeros((0, ctx.W), np.uint32))
        return _result(engine, intents, n_iter, t0, "mrganter+", min_support)

    tables = lectic.LecticTables(ctx.n_attrs)
    frontier = [Y0]
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seed_list = [_seeds_for(Y, tables) for Y in frontier]
        seeds = (
            np.concatenate(seed_list, axis=0)
            if seed_list
            else np.zeros((0, ctx.W), np.uint32)
        )
        if seeds.shape[0] == 0:
            break
        if dedupe_candidates:
            seeds = np.unique(seeds, axis=0)
        n_iter += 1
        closures, sups = engine.closure(seeds)
        if min_support is not None:
            closures = closures[sups >= min_support]
        new_idx = H.add_batch(closures)
        frontier = [closures[i] for i in new_idx]
        intents.extend(frontier)
    return _result(engine, intents, n_iter, t0, "mrganter+", min_support)


def _mrganter_plus_async(
    ctx, engine, H, Y0, intents, n_iter, t0, *,
    dedupe_candidates, max_iterations, min_support,
):
    """MRGanter+'s round loop restructured around futures.

    Termination mirrors the sync loop exactly: a reconciled round counts
    iff its true seed count was nonzero (the charge already happened at
    reconcile), the walk stops when the registry finds no novel closure,
    and — because the async frontier includes stale rows that the sync
    frontier would not expand — the one case where sync's *next* expansion
    would be empty (the sole novel intent is the full attribute set, which
    has no ⊕-successors) is detected on the host so no extra round is
    counted."""
    full = ctx.attr_mask()
    fr = DeviceFrontier(engine, dedupe_closures=True)
    fr.set_frontier(Y0[None, :])
    capped = max_iterations is not None and n_iter >= max_iterations
    pending = (
        None if capped
        else fr.spec_oplus(dedupe=dedupe_candidates, min_support=min_support)
    )
    while pending is not None:
        speculate = max_iterations is None or n_iter + 1 < max_iterations
        nxt = (
            fr.spec_oplus(dedupe=dedupe_candidates, min_support=min_support)
            if speculate else None
        )
        rec = fr.reconcile_oplus(pending, min_support=min_support)
        if rec.n_seeds == 0:  # no closure round ran — uncounted, like sync
            fr.discard_spec(nxt)
            break
        n_iter += 1
        if rec.closures.shape[0] == 0:
            # iceberg round pruned every closure — the exhausting
            # map/reduce round still counts (sync parity)
            fr.discard_spec(nxt)
            break
        new = rec.closures[H.add_batch(rec.closures)]
        intents.extend(new)
        sync_would_stop = new.shape[0] == 0 or (
            new.shape[0] == 1 and np.array_equal(new[0], full)
        )
        if sync_would_stop or nxt is None:
            fr.discard_spec(nxt)
            break
        if rec.under_covered:
            # speculation ran on a partial frontier — discard it, restore
            # the true (novel) frontier, and re-dispatch synchronously
            fr.discard_spec(nxt)
            fr.set_frontier(new)
            nxt = fr.spec_oplus(
                dedupe=dedupe_candidates, min_support=min_support
            )
        pending = nxt
    return _result(engine, intents, n_iter, t0, "mrganter+", min_support)


# ---------------------------------------------------------------------------
# MRCbo: distributed CloseByOne under the same engine (paper §5 baseline).
# ---------------------------------------------------------------------------


@_traced_driver("mrcbo")
def mrcbo(
    ctx,
    engine: ClosureEngine,
    max_iterations: int | None = None,
    *,
    pipeline: str = "device",
    min_support: int | None = None,
    rounds: str = "sync",
) -> MRResult:
    """``min_support`` prunes the CbO tree at infrequent nodes (support
    filter fused after the psum): intents only grow along the canonical
    generation path, so every frequent concept's ancestors are frequent
    and pruning is lossless.

    ``rounds="async"`` (device pipeline) speculatively expands round r's
    canonical survivors while their count is still on device.  CbO's
    canonicity filter makes the survivor buffer *exactly* the next
    frontier (no registry lag), so covered speculation is exact; under-
    coverage re-closes the uncovered tail synchronously and re-adopts the
    full survivor set before re-speculating."""
    _check_pipeline(pipeline)
    _check_rounds(rounds, pipeline)
    min_support = _check_min_support(min_support)
    t0 = time.perf_counter()
    root, s0 = engine.first_closure()
    if min_support is not None and s0 < min_support:
        return _result(engine, [], 1, t0, "mrcbo", min_support)
    intents = [root]
    n_iter = 1

    if pipeline == "device" and rounds == "async":
        return _mrcbo_async(
            engine, root, intents, n_iter, t0,
            max_iterations=max_iterations, min_support=min_support,
        )

    if pipeline == "device":
        fr = DeviceFrontier(engine)
        fr.set_frontier(root[None, :], gens=np.array([-1], np.int32))
        while len(fr):
            if max_iterations is not None and n_iter >= max_iterations:
                break
            # canonicity filter IS the dedupe; iceberg adds the support cut
            new, n_seeds, _ = fr.step_cbo(min_support=min_support)
            if n_seeds == 0:  # frontier exhausted before any closure round
                break
            n_iter += 1
            intents.extend(new)
        return _result(engine, intents, n_iter, t0, "mrcbo", min_support)

    tables = lectic.LecticTables(ctx.n_attrs)
    frontier: list[tuple[np.ndarray, int]] = [(root, -1)]
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seeds, parents, gens = [], [], []
        for Y, g in frontier:
            member = bitset.unpack_bits(Y, ctx.n_attrs)
            for a in range(g + 1, ctx.n_attrs):
                if not member[a]:
                    seeds.append(Y | tables.BIT[a])
                    parents.append(Y)
                    gens.append(a)
        if not seeds:
            break
        n_iter += 1
        closures, sups = engine.closure(np.stack(seeds))
        next_frontier = []
        for i in range(closures.shape[0]):
            a, Y, Z = gens[i], parents[i], closures[i]
            if min_support is not None and sups[i] < min_support:
                continue
            if np.all(((Z ^ Y) & tables.LOW[a]) == 0):  # CbO canonicity
                intents.append(Z)
                next_frontier.append((Z, a))
        frontier = next_frontier
    return _result(engine, intents, n_iter, t0, "mrcbo", min_support)


def _mrcbo_async(
    engine, root, intents, n_iter, t0, *, max_iterations, min_support
):
    """MRCbo's round loop restructured around futures (see mrcbo)."""
    fr = DeviceFrontier(engine)
    fr.set_frontier(root[None, :], gens=np.array([-1], np.int32))
    capped = max_iterations is not None and n_iter >= max_iterations
    pending = None if capped else fr.spec_cbo(min_support=min_support)
    while pending is not None:
        speculate = max_iterations is None or n_iter + 1 < max_iterations
        nxt = fr.spec_cbo(min_support=min_support) if speculate else None
        rec = fr.reconcile_cbo(pending, min_support=min_support)
        if rec.n_seeds == 0:  # frontier exhausted before any closure round
            fr.discard_spec(nxt)
            break
        n_iter += 1
        intents.extend(rec.new_intents)
        if rec.n_new == 0 or nxt is None:
            fr.discard_spec(nxt)
            break
        if rec.under_covered:
            # the reconcile re-adopted the full survivor set; speculation
            # ran on a partial frontier — discard and re-dispatch
            fr.discard_spec(nxt)
            nxt = fr.spec_cbo(min_support=min_support)
        pending = nxt
    return _result(engine, intents, n_iter, t0, "mrcbo", min_support)
