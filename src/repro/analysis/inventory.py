"""``--inventory`` — import-graph reachability over ``src/repro``.

Builds the module import graph by AST (absolute ``repro.*`` imports and
relative imports, including ``from pkg import submodule`` edges), walks
reachability from the package's public surfaces, and reports what nothing
reaches — the dead-code census committed as ``ANALYSIS_inventory.json``
so a PR that orphans a module shows up as a diff on the report.

Roots are the FCA product surfaces: the tier package ``__init__``
re-exports (core/dist/query/rules/serve/kernels/obs), the CLI mains, and
the FCA launchers.  The LM seed stack (configs/models/train/data and its
launchers) predates the FCA growth and is reachable only through its own
entry points — it is listed separately, not mixed into the dead set.
"""

from __future__ import annotations

import ast
import json
import pathlib

ROOTS = (
    "repro.core",
    "repro.dist",
    "repro.query",
    "repro.rules",
    "repro.serve",
    "repro.kernels",
    "repro.obs",
    "repro.obs.__main__",
    "repro.analysis",
    "repro.analysis.__main__",
    "repro.launch.fca",
    "repro.launch.mesh",
)

# pre-FCA LM seed surfaces: reachable from their own mains, reported as
# their own tier so the dead-code list stays actionable
SEED_ROOTS = (
    "repro.launch.train",
    "repro.launch.serve",
    "repro.launch.data",
    "repro.launch.dryrun",
)

# packages that load their submodules dynamically (importlib registries):
# the static graph cannot see those edges, so a reachable package pulls in
# every submodule under it
DYNAMIC_PKGS = ("repro.configs",)


def _module_name(path: pathlib.Path, src: pathlib.Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _edges(tree: ast.Module, module: str, known: set) -> set:
    """Outgoing import edges of one module, resolved against the known
    module set (``from pkg import name`` links ``pkg.name`` when that is
    itself a module)."""
    pkg_parts = module.split(".")
    out = set()

    def add(name: str):
        if name in known:
            out.add(name)
            return
        # importing a symbol from a package/module: credit the container
        while "." in name:
            name = name.rsplit(".", 1)[0]
            if name in known:
                out.add(name)
                return

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[: len(pkg_parts) - node.level + 1]
                # relative from a module (not a package __init__): drop
                # the module segment
                prefix = ".".join(base[:-1] if node.level else base)
                prefix = ".".join(
                    pkg_parts[: len(pkg_parts) - node.level]
                )
                mod = f"{prefix}.{node.module}" if node.module else prefix
            else:
                mod = node.module or ""
            if mod:
                add(mod)
                for alias in node.names:
                    add(f"{mod}.{alias.name}")
    return out


def build_inventory(root=None) -> dict:
    root = pathlib.Path(root) if root else _repo_root()
    src = root / "src"
    files = sorted((src / "repro").rglob("*.py"))
    modules = {_module_name(p, src): p for p in files}
    known = set(modules)
    graph, stats = {}, {}
    for name, path in modules.items():
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        graph[name] = _edges(tree, name, known)
        defs = [
            n.name
            for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef))
        ]
        stats[name] = {
            "path": path.relative_to(root).as_posix(),
            "loc": source.count("\n") + 1,
            "defs": len(defs),
            "public_defs": sum(1 for d in defs if not d.startswith("_")),
        }

    def reach(roots) -> set:
        seen = set()
        frontier = [r for r in roots if r in known]
        while frontier:
            m = frontier.pop()
            if m in seen:
                continue
            seen.add(m)
            # a package reaches its __init__ imports; a module reaches its
            # containing package __init__ implicitly
            if "." in m:
                frontier.append(m.rsplit(".", 1)[0])
            if m in DYNAMIC_PKGS:
                frontier.extend(
                    k for k in known if k.startswith(m + ".")
                )
            frontier.extend(graph.get(m, ()))
        return seen

    fca = reach(ROOTS)
    seed = reach(SEED_ROOTS) - fca

    # modules the test suite imports: not product-reachable but exercised
    test_imports = set()
    for tpath in sorted((root / "tests").glob("**/*.py")):
        try:
            ttree = ast.parse(tpath.read_text(), filename=str(tpath))
        except SyntaxError:
            continue
        test_imports |= _edges(ttree, "tests", known)
    test_only = reach(test_imports) - fca - seed

    dead = sorted(known - fca - seed - test_only)
    return {
        "roots": list(ROOTS),
        "seed_roots": list(SEED_ROOTS),
        "n_modules": len(known),
        "n_reachable": len(fca),
        "n_seed_tier": len(seed),
        "n_test_only": len(test_only),
        "seed_tier": sorted(seed),
        "test_only": sorted(test_only),
        "dead": [dict(module=m, **stats[m]) for m in dead],
        "loc_total": sum(s["loc"] for s in stats.values()),
        "loc_dead": sum(stats[m]["loc"] for m in dead),
    }


def write_inventory(path, root=None) -> dict:
    inv = build_inventory(root)
    pathlib.Path(path).write_text(json.dumps(inv, indent=2) + "\n")
    return inv


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]
