"""Pass 3 — lock-discipline checker for the serve tier.

For each configured class the checker infers, from the AST alone:

* its **lock attributes** — ``self.X = threading.Lock()/RLock()``
  assignments in ``__init__``/``__post_init__``;
* its **guarded field set** — instance attributes accessed at least once
  inside a ``with self.<lock>:`` block outside ``__init__`` (the
  convention the serve tier documents: a field the code bothers to lock
  anywhere is a field the dispatcher thread can race on);
* **lock-held propagation** — a private method whose every in-class call
  site is lock-held (e.g. ``Registry._resolve``, documented "callers
  hold the lock") is analyzed with its body lock-held, to a fixpoint;
* its **entry points** — public methods/properties plus configured
  dispatcher-thread entries (``AdmissionQueue._run`` etc.).

A finding is an access to a guarded-and-mutated field that is (a) not
under any lock after propagation, (b) reachable from an entry point, and
(c) not annotated ``# lock: ok`` (the visible opt-out for benign racy
reads — GIL-atomic single reference/dict reads).

Only *mutated* fields are reported (assigned, subscript-assigned, or hit
with a known container mutator outside ``__init__``): an unguarded read
of a reference that is never rebound or mutated cannot race.  Accesses
through local aliases (``q = self._queues[k]; q.append(...)``) are
outside the checker's static reach — the schedule-fuzzing harness
(:mod:`repro.analysis.fuzz`) covers those dynamically.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

from repro.analysis.findings import Finding

# (repo-relative file, class, extra entry points run by other threads)
TARGETS = (
    ("src/repro/serve/admission.py", "AdmissionQueue",
     ("_run", "_dispatch", "_take")),
    ("src/repro/query/stream.py", "StreamUpdater", ("_stage",)),
    ("src/repro/query/engine.py", "QueryEngine",
     ("_closure_step", "_topk_step", "_extents_step", "_rules_step")),
    ("src/repro/obs/metrics.py", "Registry", ("_resolve",)),
    ("src/repro/obs/metrics.py", "Histogram", ()),
    ("src/repro/obs/metrics.py", "StatsBase", ("observe_latency",)),
)

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "Lock", "RLock"}
_MUTATORS = {
    "append", "appendleft", "extend", "insert", "add", "update",
    "setdefault", "pop", "popleft", "remove", "discard", "clear", "sort",
}
# "lock" at a word/underscore boundary — matches _lock, _dispatch_lock,
# _latency_lock, but not "clock"
_LOCKISH = re.compile(r"(?:^|_)lock", re.IGNORECASE)
_INIT_METHODS = {"__init__", "__post_init__"}


@dataclasses.dataclass
class _Access:
    attr: str
    lineno: int
    under_lock: bool
    is_write: bool
    method: str


@dataclasses.dataclass
class _Call:
    callee: str
    under_lock: bool
    method: str


def _dotted(node) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_lock(expr) -> bool:
    """True when a with-item context expression goes through an attribute
    or call whose name smells like a lock (``self._lock``,
    ``self.engine._frontier_lock``, ``self._latency_lock()``)."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and _LOCKISH.search(node.attr):
            return True
        if isinstance(node, ast.Name) and _LOCKISH.search(node.id):
            return True
    return False


class _MethodScan(ast.NodeVisitor):
    """Collects self-attribute accesses and self-method calls for one
    method body, tracking lexical ``with <lock>`` depth."""

    def __init__(self, method: str):
        self.method = method
        self.lock_depth = 0
        self.accesses: list[_Access] = []
        self.calls: list[_Call] = []

    def visit_With(self, node):
        locked = any(_mentions_lock(item.context_expr) for item in node.items)
        for item in node.items:
            self.visit(item.context_expr)
        if locked:
            self.lock_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if locked:
            self.lock_depth -= 1

    def _record(self, attrnode, is_write: bool):
        self.accesses.append(
            _Access(
                attr=attrnode.attr,
                lineno=attrnode.lineno,
                under_lock=self.lock_depth > 0,
                is_write=is_write,
                method=self.method,
            )
        )

    def visit_Attribute(self, node):
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            self._record(node, isinstance(node.ctx, (ast.Store, ast.Del)))
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        t = node.target
        if (
            isinstance(t, ast.Attribute)
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
        ):
            self._record(t, True)
            self.visit(node.value)
            return
        self.generic_visit(node)

    def visit_Assign(self, node):
        # self.X[...] = v mutates X even though X itself is a Load
        for t in node.targets:
            if (
                isinstance(t, ast.Subscript)
                and isinstance(t.value, ast.Attribute)
                and isinstance(t.value.value, ast.Name)
                and t.value.value.id == "self"
            ):
                self._record(t.value, True)
        self.generic_visit(node)

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute):
            base = f.value
            # self.method(...)
            if isinstance(base, ast.Name) and base.id == "self":
                self.calls.append(
                    _Call(f.attr, self.lock_depth > 0, self.method)
                )
            # self.X.append(...) and friends mutate X
            if (
                f.attr in _MUTATORS
                and isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id == "self"
            ):
                self._record(base, True)
        self.generic_visit(node)


@dataclasses.dataclass
class ClassAudit:
    """What the checker inferred for one class (also used by the tests)."""

    cls: str
    lock_attrs: set
    guarded: set
    mutated: set
    assumed_locked: set  # methods analyzed with a lock-held body
    reachable: set  # methods reachable from entry points
    findings: list


def _scan_class(node: ast.ClassDef):
    methods = {}
    for stmt in node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan = _MethodScan(stmt.name)
            for s in stmt.body:
                scan.visit(s)
            methods[stmt.name] = scan
    return methods


def _lock_attrs(methods) -> set:
    out = set()
    for name in _INIT_METHODS:
        scan = methods.get(name)
        if scan is None:
            continue
        # re-derive from the accesses + ctor calls is brittle; just look
        # for self.X = threading.Lock()/RLock() assignment pairs by
        # matching write accesses whose line also constructs a lock —
        # cheaper: any written attr with a lockish name
        for acc in scan.accesses:
            if acc.is_write and _LOCKISH.search(acc.attr):
                out.add(acc.attr)
    # locks lazily (re)created outside __init__ (StatsBase fallback)
    for scan in methods.values():
        for acc in scan.accesses:
            if acc.is_write and _LOCKISH.search(acc.attr):
                out.add(acc.attr)
    return out


def audit_class(
    tree: ast.Module, rel: str, cls_name: str, extra_entries, source_lines
) -> ClassAudit | None:
    cls = next(
        (
            n
            for n in ast.walk(tree)
            if isinstance(n, ast.ClassDef) and n.name == cls_name
        ),
        None,
    )
    if cls is None:
        return None
    methods = _scan_class(cls)
    locks = _lock_attrs(methods)

    # fixpoint: private methods only ever called with the lock held are
    # analyzed lock-held (Registry._resolve's documented contract)
    assumed = set()
    while True:
        changed = False
        for name, scan in methods.items():
            if name in assumed or name in _INIT_METHODS:
                continue
            sites = [
                c
                for m, s in methods.items()
                for c in s.calls
                if c.callee == name and m not in _INIT_METHODS
            ]
            if sites and all(
                c.under_lock or c.method in assumed for c in sites
            ):
                if name.startswith("_") and not name.startswith("__"):
                    assumed.add(name)
                    changed = True
        if not changed:
            break

    def held(acc: _Access) -> bool:
        return acc.under_lock or acc.method in assumed

    body_accesses = [
        a
        for s in methods.values()
        for a in s.accesses
        if a.method not in _INIT_METHODS and a.attr not in locks
    ]
    guarded = {a.attr for a in body_accesses if held(a)}
    mutated = {a.attr for a in body_accesses if a.is_write}

    # reachability from entry points over the self-call graph
    entries = {
        m for m in methods if not m.startswith("_") and m not in _INIT_METHODS
    } | (set(extra_entries) & set(methods))
    reachable = set(entries)
    frontier = list(entries)
    while frontier:
        m = frontier.pop()
        for c in methods[m].calls:
            if c.callee in methods and c.callee not in reachable:
                reachable.add(c.callee)
                frontier.append(c.callee)

    findings = []
    for acc in body_accesses:
        if (
            acc.attr in guarded
            and acc.attr in mutated
            and not held(acc)
            and acc.method in reachable
        ):
            line = (
                source_lines[acc.lineno - 1]
                if acc.lineno - 1 < len(source_lines)
                else ""
            )
            if "# lock: ok" in line:
                continue
            findings.append(
                Finding(
                    "locks",
                    "unguarded-access",
                    f"{rel}:{acc.lineno}",
                    f"{cls_name}.{acc.method} touches self.{acc.attr} "
                    f"without holding a lock, but self.{acc.attr} is "
                    "lock-guarded elsewhere and mutated — either lock it "
                    "or annotate '# lock: ok' for a benign atomic read",
                )
            )
    return ClassAudit(
        cls=cls_name,
        lock_attrs=locks,
        guarded=guarded,
        mutated=mutated,
        assumed_locked=assumed,
        reachable=reachable,
        findings=findings,
    )


def audit_file(path, rel: str, targets) -> list:
    source = pathlib.Path(path).read_text()
    tree = ast.parse(source, filename=str(path))
    lines = source.splitlines()
    audits = []
    for cls_name, extra in targets:
        audit = audit_class(tree, rel, cls_name, extra, lines)
        if audit is not None:
            audits.append(audit)
    return audits


def run(report, *, root=None, targets=TARGETS) -> list[Finding]:
    root = pathlib.Path(root) if root else _repo_root()
    by_file: dict = {}
    for rel, cls, extra in targets:
        by_file.setdefault(rel, []).append((cls, extra))
    findings = []
    for rel, classes in by_file.items():
        path = root / rel
        if not path.exists():
            findings.append(
                Finding("locks", "missing-target", rel,
                        "configured lock-audit target file not found")
            )
            continue
        for audit in audit_file(path, rel, classes):
            findings.extend(audit.findings)
            report.note_checked("locks", "classes")
    return findings


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]
