"""Serving engine: greedy generation, batching, stop handling."""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer
from repro.serve.engine import ServeConfig, ServeEngine


def _engine(greedy=True, eos=None):
    cfg = get_config("codeqwen1.5-7b").reduced()
    params, _ = transformer.init_params(cfg, seed=0)
    scfg = ServeConfig(max_len=96, batch_slots=4, greedy=greedy, eos_id=eos)
    return cfg, params, ServeEngine(cfg, params, scfg)


def test_generate_matches_manual_greedy():
    cfg, params, eng = _engine()
    prompt = [5, 9, 2, 14, 7]
    out = eng.generate([prompt], max_new=8)[0]
    assert len(out) == 8

    # manual greedy reference with full forward each step
    seq = list(prompt)
    for _ in range(8):
        hidden, _, _ = transformer.forward_hidden(
            params, cfg, jnp.asarray([seq], jnp.int32), mode="train"
        )
        logits = transformer.logits_for(params, cfg, hidden)
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert out == seq[len(prompt):]


def test_generate_batch_isolation():
    """Each slot decodes independently of the others (left-padding safe)."""
    _, _, eng = _engine()
    a = eng.generate([[3, 1, 4]], max_new=6)[0]
    b = eng.generate([[3, 1, 4], [9, 9, 9, 9]], max_new=6)[0]
    assert a == b


def test_eos_stops_early():
    cfg, params, eng = _engine()
    # find the first greedy token, then use it as eos → single-token output
    first = eng.generate([[1, 2, 3]], max_new=1)[0][0]
    cfg2, params2, eng2 = _engine(eos=first)
    out = eng2.generate([[1, 2, 3]], max_new=8)[0]
    assert out == [first]
