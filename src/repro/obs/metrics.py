"""Metrics registry — counters, gauges, and log-bucketed histograms.

The paper's unit of analysis is the *round*; the ROADMAP's serving tier
demands *latency percentiles, not just throughput*.  This module carries
both: a tiny label-aware :class:`Registry` (counters / gauges /
histograms) that `EngineStats` and `QueryStats` publish into, and an
HDR-style log-bucketed :class:`Histogram` whose p50/p95/p99 surface as
``QueryStats.latency_percentiles`` and in BENCH_query.json.

Design constraint: the stats dataclasses are public API — every existing
test and bench JSON field must survive bit-compatibly, and call sites
mutate fields directly (``st.h2d_transfers += 1``).  So the dataclasses
stay the source of truth for scalar counters; each stats object owns a
private registry (non-field, created in ``__post_init__`` so
``dataclasses.asdict`` never sees it) holding the latency histograms,
and :meth:`StatsBase.publish` exports the scalar fields into the
registry for unified export.  The previously copy-pasted schedule-census
triple (``reduce_rounds`` / ``auto_hop_bytes`` / ``hop_calibrated``)
lives once here as :class:`ScheduleCensus`, so the autotuner's census is
recorded identically in the mining and serving tiers.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# HDR-style log-bucketed histogram
# ---------------------------------------------------------------------------

# Bucket boundaries grow geometrically by 2**(1/8) (~9% relative error per
# bucket) from a 1 µs floor — sparse dict storage, so an idle histogram
# costs one empty dict.
_FACTOR = 2.0 ** 0.125
_LOG_FACTOR = math.log(_FACTOR)
_VMIN = 1e-6


class Histogram:
    """Log-bucketed latency histogram with percentile readout.

    Values are seconds.  ``record`` is O(1); ``percentile`` walks the
    sorted buckets (tens of entries for realistic latency ranges).
    Relative quantile error is bounded by the bucket factor (~9%), the
    standard HDR trade: constant memory, no sample retention.
    """

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0

    def record(self, value: float) -> None:
        v = max(float(value), 0.0)
        idx = 0 if v < _VMIN else int(math.log(v / _VMIN) / _LOG_FACTOR) + 1
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]); 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                if idx == 0:
                    return min(_VMIN, self.max)
                # bucket upper edge, clamped to observed extrema
                upper = _VMIN * _FACTOR ** idx
                return max(self.min, min(upper, self.max))
        return self.max

    def percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": 0.0 if self.count == 0 else self.min,
            "max": self.max,
            **self.percentiles(),
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _key(name: str, labels: dict | None) -> tuple:
    return (name, tuple(sorted((labels or {}).items())))


class Registry:
    """Counters, gauges, and histograms with optional labels.

    One registry per stats object (mining engine, query engine) — no
    global mutable state, so two engines in one process never alias.
    """

    def __init__(self):
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, Histogram] = {}

    def counter(self, name: str, inc: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + inc

    def gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[_key(name, labels)] = float(value)

    def histogram(self, name: str, **labels) -> Histogram:
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            h = self._hists[k] = Histogram()
        return h

    def observe(self, name: str, value: float, **labels) -> None:
        self.histogram(name, **labels).record(value)

    @staticmethod
    def _fmt(k: tuple) -> str:
        name, labels = k
        if not labels:
            return name
        body = ",".join(f"{lk}={lv}" for lk, lv in labels)
        return f"{name}{{{body}}}"

    def export(self) -> dict:
        """Flat ``{metric{label=...}: value-or-summary}`` snapshot."""
        out: dict = {}
        for k, v in sorted(self._counters.items()):
            out[self._fmt(k)] = v
        for k, v in sorted(self._gauges.items()):
            out[self._fmt(k)] = v
        for k, h in sorted(self._hists.items()):
            out[self._fmt(k)] = h.summary()
        return out


# ---------------------------------------------------------------------------
# shared stats base: schedule census + latency percentiles
# ---------------------------------------------------------------------------


@dataclass
class ScheduleCensus:
    """The autotuner's schedule census, shared by both stats tiers.

    ``reduce_rounds`` counts collective rounds by resolved reduce
    implementation (``allgather`` / ``rsag``); ``auto_hop_bytes`` and
    ``hop_calibrated`` record the wire-model calibration the `auto`
    resolver used.  Field order puts these first in subclass dataclasses
    — safe because every construction site passes keywords.
    """

    reduce_rounds: dict = field(default_factory=dict)
    auto_hop_bytes: int = 0
    hop_calibrated: bool = False

    def record_reduce(self, impl: str, n: int = 1) -> None:
        self.reduce_rounds[impl] = self.reduce_rounds.get(impl, 0) + n


@dataclass
class StatsBase(ScheduleCensus):
    """Census + latency view: dataclass fields stay the public API; the
    private registry (non-field — invisible to ``dataclasses.asdict``)
    holds the histograms behind ``latency_percentiles``."""

    latency_percentiles: dict = field(default_factory=dict)

    def __post_init__(self):
        # object.__setattr__-free: plain attr, excluded from asdict/fields
        self._registry = Registry()

    @property
    def registry(self) -> Registry:
        reg = getattr(self, "_registry", None)
        if reg is None:  # copy.replace / __reduce__ paths skip __post_init__
            reg = self._registry = Registry()
        return reg

    def observe_latency(self, kind: str, seconds: float) -> None:
        """Record one latency sample and refresh the percentile view.

        ``latency_percentiles[kind]`` is a real dict field so it rides
        ``dataclasses.asdict`` into every stats JSON for free.
        """
        h = self.registry.histogram("latency_s", kind=kind)
        h.record(seconds)
        self.latency_percentiles[kind] = {
            k: round(v, 9) for k, v in h.percentiles().items()
        }

    def publish(self) -> dict:
        """Export scalar dataclass fields + histograms as one flat dict."""
        reg = self.registry
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, bool):
                reg.gauge(f.name, float(v))
            elif isinstance(v, (int, float)):
                reg.gauge(f.name, v)
            elif isinstance(v, dict) and f.name == "reduce_rounds":
                for impl, n in v.items():
                    reg.gauge("reduce_rounds", n, impl=impl)
        return reg.export()
