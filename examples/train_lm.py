"""End-to-end LM training through the fault-tolerant trainer.

    PYTHONPATH=src python examples/train_lm.py                  # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch gemma2-9b

Uses a reduced config of the chosen architecture (full configs are
dry-run/pod territory), the synthetic Markov corpus, AdamW with warmup-
cosine, and periodic async checkpoints — kill it mid-run and restart to
see the restore path replay bit-identically.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.lm_data import make_batch_iterator
from repro.models import transformer
from repro.models.config import ShapeConfig
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import get_optimizer, warmup_cosine
from repro.train.step import make_train_step


def main(total_steps=60, ckpt_dir="/tmp/repro_train_lm", arch="gemma2-9b",
         seq_len=64, batch=8):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig("example", "train", seq_len, batch)
    opt = get_optimizer("adamw", warmup_cosine(5e-3, 10, total_steps))
    step_fn = jax.jit(make_train_step(cfg, opt, None), donate_argnums=0)

    def init_state():
        params, _ = transformer.init_params(cfg, seed=0)
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        print(f"{arch} (reduced): {n / 1e6:.2f}M params")
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    trainer = Trainer(
        step_fn=step_fn,
        init_state_fn=init_state,
        batch_iter_fn=lambda start: make_batch_iterator(cfg, shape, seed=0,
                                                        start_step=start),
        cfg=TrainerConfig(total_steps=total_steps, ckpt_every=20,
                          ckpt_dir=ckpt_dir, async_ckpt=True),
    )
    out = trainer.run()
    h = out["history"]
    print(f"steps={out['steps']} restarts={out['n_restarts']} "
          f"loss {h[0]['loss']:.3f} → {h[-1]['loss']:.3f} "
          f"({out['wall_time_s']:.1f}s)")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--arch", default="gemma2-9b")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    a = p.parse_args()
    main(total_steps=a.steps, ckpt_dir=a.ckpt_dir, arch=a.arch)
