import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count at first
# init).  512 placeholder CPU devices back both the 16×16 single-pod and
# the 2×16×16 multi-pod production meshes.

"""Multi-pod dry-run driver (deliverable e).

    python -m repro.launch.dryrun --arch <id> --shape <s> [--multi-pod]
    python -m repro.launch.dryrun --all [--multi-pod] --out dryrun.jsonl
    python -m repro.launch.dryrun --fca [--multi-pod]

For every cell this lowers + compiles the real train/prefill/decode step
against ShapeDtypeStruct inputs on the production mesh, prints
``memory_analysis()`` / ``cost_analysis()``, and appends a JSON record with
the §Roofline raw terms (while-aware FLOPs, HBM bytes, collective bytes).
"""

import argparse
import json
import sys


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--fca", action="store_true", help="paper's own technique cell")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default=None, help="append JSONL records here")
    p.add_argument("--fsdp", default=None, choices=["on", "off"])
    p.add_argument("--baseline", action="store_true",
                   help="disable §Perf optimizations (A/B baseline)")
    args = p.parse_args(argv)

    from repro.configs import ARCH_IDS, SHAPES
    from repro.launch.dryrun_lib import run_cell, run_fca_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_label = "2x16x16" if args.multi_pod else "16x16"
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    cells = []
    if args.fca:
        cells = ["__fca__"]
    elif args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required (or --all / --fca)")
        cells = [(args.arch, args.shape)]

    records = []
    for cell in cells:
        if cell == "__fca__":
            rec = run_fca_cell(mesh, mesh_label, baseline=args.baseline)
        else:
            arch, shape = cell
            rec = run_cell(arch, shape, mesh, mesh_label, fsdp=fsdp,
                           baseline=args.baseline)
        records.append(rec)
        rec["variant"] = "baseline" if args.baseline else "optimized"
        print(json.dumps(rec), flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    n_err = sum(r["status"] == "error" for r in records)
    print(f"# {len(records)} cells: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, "
          f"{n_err} errors", file=sys.stderr)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
