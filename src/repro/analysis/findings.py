"""Shared result types for the ``repro.analysis`` passes.

Every pass (SPMD audit, host-sync/recompile lint, lock discipline,
schedule fuzz) reports :class:`Finding` rows into one :class:`Report`;
the CLI gate (``python -m repro.analysis --strict``) exits nonzero iff
any finding of severity ``error`` survives the allowlist.
"""

from __future__ import annotations

import dataclasses
import json

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or informational note) from one pass."""

    pass_name: str  # "spmd" | "lint" | "locks" | "fuzz"
    rule: str  # stable rule id, e.g. "undeclared-axis", "host-sync"
    location: str  # "path:line" or a step label like "4x1/rsag/jnp/cbo2d"
    message: str
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def format(self) -> str:
        return f"[{self.pass_name}:{self.rule}] {self.location}: {self.message}"


@dataclasses.dataclass
class Report:
    """Aggregated findings across passes, plus per-pass run metadata
    (counts of artifacts checked — so "0 findings" is distinguishable
    from "pass never ran")."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    checked: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings) -> "Report":
        self.findings.extend(findings)
        return self

    def note_checked(self, pass_name: str, what: str, n: int = 1):
        key = f"{pass_name}.{what}"
        self.checked[key] = self.checked.get(key, 0) + n

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self) -> str:
        return json.dumps(
            {
                "ok": self.ok,
                "checked": dict(sorted(self.checked.items())),
                "findings": [dataclasses.asdict(f) for f in self.findings],
            },
            indent=2,
            sort_keys=False,
        )

    def format(self) -> str:
        lines = [f.format() for f in self.findings]
        n_err = len(self.errors)
        summary = (
            f"{len(self.findings)} finding(s), {n_err} error(s); "
            f"checked: "
            + ", ".join(f"{k}={v}" for k, v in sorted(self.checked.items()))
        )
        return "\n".join(lines + [summary])
