"""The paper's two-level hash table ``H`` (MRGanter+, Algorithm 6).

Level 1 keys on the *head attribute* of the closure (its smallest member);
level 2 keys on the closure's *length* (popcount).  Leaves are sets of the
packed intent bytes.  This mirrors the paper's reduce-side index used to
"fast index and search a specified closure".
"""

from __future__ import annotations

import numpy as np

from repro.core import bitset


class TwoLevelHash:
    def __init__(self):
        self._levels: dict[int, dict[int, set[bytes]]] = {}
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __contains__(self, row: np.ndarray) -> bool:
        head = bitset.head_attr(row)
        length = int(bitset.popcount(row))
        bucket = self._levels.get(head, {}).get(length)
        return bucket is not None and bitset.key_bytes(row) in bucket

    def add(self, row: np.ndarray) -> bool:
        """Insert; returns True iff the intent was new (Alg. 6 line 7)."""
        head = bitset.head_attr(row)
        length = int(bitset.popcount(row))
        bucket = self._levels.setdefault(head, {}).setdefault(length, set())
        key = bitset.key_bytes(row)
        if key in bucket:
            return False
        bucket.add(key)
        self._n += 1
        return True

    def add_batch(self, rows: np.ndarray) -> list[int]:
        """Insert a batch [B, W]; returns indices of the rows that were new."""
        return [i for i in range(rows.shape[0]) if self.add(rows[i])]

    def bucket_stats(self) -> dict[str, float]:
        sizes = [
            len(s) for lv2 in self._levels.values() for s in lv2.values()
        ]
        if not sizes:
            return {"buckets": 0, "max": 0, "mean": 0.0}
        return {
            "buckets": len(sizes),
            "max": max(sizes),
            "mean": float(np.mean(sizes)),
        }
