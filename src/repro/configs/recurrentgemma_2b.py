"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427].  Sub-quadratic → eligible for long_500k."""

from repro.models.config import GriffinConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,  # 8 × (rec, rec, attn_local) + (rec, rec) tail
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,  # MQA
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    rope_kind="standard",
    rope_theta=10_000.0,
    layer_pattern=("rec", "rec", "attn_local"),
    griffin=GriffinConfig(lru_width=2560, conv_width=4, attn_window=2048),
    mlp_kind="geglu",
    emb_scale=True,
    tie_embeddings=True,
    subquadratic=True,
)
