"""Shared helpers for the paper-table benchmarks.

The container is CPU-only and offline, so benchmarks run on *scaled*
synthetic datasets matched to Table 7's (objects, attributes, density) —
scale factors are printed with every row and recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import ClosureEngine, FormalContext
from repro.data import fca_datasets

# object-count scale per dataset (CPU budget); attrs & density untouched.
# Calibrated so each dataset yields O(10²–10³) concepts — the full 5-algorithm
# suite (incl. 1-concept-per-round MRGanter) stays within a CPU-minutes budget.
DEFAULT_SCALES = {
    "mushroom": 0.008,      # ~65 objects (dense → concept-rich)
    "anon-web": 0.008,      # ~262 objects (sparse)
    "census-income": 0.002,  # ~208 objects
}


def load_scaled(name: str, seed: int = 0):
    ctx, spec = fca_datasets.load(name, scale=DEFAULT_SCALES[name], seed=seed)
    return ctx, spec


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def make_engine(ctx: FormalContext, n_parts: int, reduce_impl: str = "rsag",
                use_kernel: bool = False) -> ClosureEngine:
    # use_kernel=False: Pallas interpret mode is a correctness tool (it
    # executes the kernel body per grid cell on CPU) — wall-time benches
    # use the fused-jnp path.  Kernel correctness is asserted separately:
    # kernel_bench.run_equivalence() and the fused_ab record both check the
    # Pallas paths (standalone + fused frontier step) bit-for-bit.
    return ClosureEngine(
        ctx, n_parts=n_parts, reduce_impl=reduce_impl,
        use_kernel=use_kernel, block_n=64,
    )
