"""Self-tests for the repro.analysis passes (ISSUE 10).

Three layers:

1. **Rule trip/silent pairs** — every lint and lock rule must fire on its
   seeded defect in ``tests/fixtures_analysis/*_bad.py`` and stay silent
   on the clean twin, so a rule that rots is caught by the suite, not by
   the next real regression.
2. **Gate reproduction** — the repo itself lints clean, the lock targets
   audit clean, the fuzz seeds run silent, the injected race fires
   deterministically, and the SPMD byte census matches the analytic model
   for every step variant under every geometry (the ``--strict`` CI gate,
   run in-process).
3. **Concurrency regressions** — the specific fixes this PR shipped
   (histogram snapshot-vs-record, ``observe_latency`` vs
   ``dataclasses.asdict``, single-build frontier step cache, injected
   clocks in StreamUpdater/QueryEngine) each get a pinning test.
"""

import dataclasses
import json
import pathlib
import threading

import numpy as np
import pytest

from repro.analysis import Report, findings as findings_mod
from repro.analysis import fuzz, inventory, lint, locks

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures_analysis"

LINT_RULES = (
    "host-sync", "wall-clock", "mutable-default", "jit-in-loop", "bare-except"
)


def _lint_fixture(monkeypatch, name, allow=None):
    rel = f"tests/fixtures_analysis/{name}"
    monkeypatch.setitem(lint.ASYNC_SCOPES, rel, (r".*_async$",))
    monkeypatch.setattr(lint, "CLOCK_SCOPES", lint.CLOCK_SCOPES + (rel,))
    return lint.lint_file(FIXTURES / name, rel, allow or {})


# ---------------------------------------------------------------------------
# lint: trip / silent / allowlist
# ---------------------------------------------------------------------------


def test_lint_bad_fixture_trips_every_rule(monkeypatch):
    found = _lint_fixture(monkeypatch, "lint_bad.py")
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == set(LINT_RULES)
    # the three seeded host syncs: np.asarray call, .block_until_ready
    # attribute, jax.device_get call
    assert len(by_rule["host-sync"]) == 3
    for rule in ("wall-clock", "mutable-default", "jit-in-loop", "bare-except"):
        assert len(by_rule[rule]) == 1, rule
    assert all(f.severity == "error" for f in found)


def test_lint_good_fixture_is_silent(monkeypatch):
    assert _lint_fixture(monkeypatch, "lint_good.py") == []


def test_lint_allowlist_suppresses_by_qualname(monkeypatch):
    allow = {
        "host-sync": {"tests/fixtures_analysis/lint_bad.py::rounds_async"}
    }
    found = _lint_fixture(monkeypatch, "lint_bad.py", allow)
    assert not any(f.rule == "host-sync" for f in found)
    # the other rules are untouched by a host-sync allowlist entry
    assert {f.rule for f in found} == set(LINT_RULES) - {"host-sync"}


def test_lint_repo_is_clean():
    report = Report()
    assert lint.run(report) == []
    assert report.checked["lint.files"] >= 80


# ---------------------------------------------------------------------------
# locks: trip / silent / fixpoint / repo gate
# ---------------------------------------------------------------------------


def test_locks_bad_fixture_trips():
    (audit,) = locks.audit_file(
        FIXTURES / "locks_bad.py", "locks_bad.py", [("BadQueue", ())]
    )
    assert "_lock" in audit.lock_attrs
    assert "_items" in audit.guarded and "_items" in audit.mutated
    rules = {f.rule for f in audit.findings}
    assert rules == {"unguarded-access"}
    # drain() is flagged on the bare read, the .clear() mutator call, and
    # the attribute load inside it
    assert len(audit.findings) == 3
    assert all("drain" in f.message for f in audit.findings)


def test_locks_good_fixture_is_silent():
    (audit,) = locks.audit_file(
        FIXTURES / "locks_good.py", "locks_good.py", [("GoodQueue", ())]
    )
    assert audit.findings == []
    # the lock-held-callers fixpoint proved _track safe
    assert audit.assumed_locked == {"_track"}


def test_locks_repo_targets_are_clean():
    report = Report()
    assert locks.run(report) == []
    assert report.checked["locks.classes"] == len(locks.TARGETS)


# ---------------------------------------------------------------------------
# fuzz: silent seeds, deterministic injected race
# ---------------------------------------------------------------------------


def test_fuzz_clean_seeds_are_silent():
    for seed in fuzz.DEFAULT_SEEDS[:4]:
        assert fuzz.run_schedule(seed, steps=150) == [], f"seed={seed}"


def test_fuzz_injected_race_fires_deterministically():
    first = fuzz.run_schedule(0, steps=150, inject_race=True)
    assert any(f.rule == "stale-after-commit" for f in first)
    # same seed, same virtual clock, same thread => bit-identical replay
    again = fuzz.run_schedule(0, steps=150, inject_race=True)
    assert first == again


def test_fuzz_pass_runs_the_blindness_self_test():
    report = Report()
    found = fuzz.run(report, seeds=(0, 1), steps=150)
    assert found == []
    assert report.checked["fuzz.schedules"] == 2
    assert report.checked["fuzz.injected"] == 1


# ---------------------------------------------------------------------------
# spmd: byte census == analytic model for every variant x geometry
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def spmd_run():
    spmd_audit = pytest.importorskip("repro.analysis.spmd_audit")
    report = Report()
    found = spmd_audit.run(report)
    return spmd_audit, report, found


def test_spmd_audit_is_clean(spmd_run):
    _, _, found = spmd_run
    assert found == []


def test_spmd_audit_covered_every_variant_and_geometry(spmd_run):
    spmd_audit, report, _ = spmd_run
    n_geo = len(spmd_audit.GEOMETRIES)
    n_impl = len(spmd_audit.IMPLS)
    assert n_geo >= 3  # 1x1, 4x1, 2x4 at minimum
    # 14 cached step variants (7 one-axis + their 2-D twins) per
    # (geometry, impl) sweep cell, times >=1 backend
    assert report.checked["spmd.frontier_steps"] >= n_geo * n_impl * 14
    assert report.checked["spmd.query_steps"] >= 16
    assert report.checked["spmd.basis_passes"] == 3


# ---------------------------------------------------------------------------
# inventory: the committed census matches the tree
# ---------------------------------------------------------------------------


def test_committed_inventory_is_fresh():
    committed = json.loads((REPO / "ANALYSIS_inventory.json").read_text())
    current = inventory.build_inventory(REPO)
    assert committed == current, (
        "ANALYSIS_inventory.json is stale — regenerate with "
        "`PYTHONPATH=src python -m repro.analysis --inventory`"
    )


# ---------------------------------------------------------------------------
# concurrency regressions for the fixes this PR shipped
# ---------------------------------------------------------------------------


def test_histogram_concurrent_record_and_snapshot():
    from repro.obs.metrics import Histogram

    h = Histogram()
    errors = []
    n_threads, n_each = 4, 2000

    def writer():
        try:
            for i in range(n_each):
                h.record(1e-4 * (i % 13 + 1))
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    def reader():
        try:
            for _ in range(400):
                h.percentiles()
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(n_threads)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # the lock keeps count exact: a bare `count += 1` loses increments
    assert h.count == n_threads * n_each


def test_statsbase_observe_latency_vs_asdict():
    from repro.obs.metrics import StatsBase

    st = StatsBase()
    errors = []
    done = threading.Event()

    def writer():
        try:
            for i in range(4000):
                st.observe_latency("closure", 1e-4 * (i % 7 + 1))
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)
        finally:
            done.set()

    def reader():
        try:
            while not done.is_set():
                dataclasses.asdict(st)  # iterates latency_percentiles
        except Exception as e:  # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=writer), threading.Thread(target=reader)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert "closure" in st.latency_percentiles


def test_frontier_step_cache_builds_once_under_contention():
    from repro.analysis.spmd_audit import _frontier_ctx
    from repro.core.engine import ClosureEngine
    from repro.core.frontier import DeviceFrontier
    from repro.dist.shardplan import ShardPlan

    ctx = _frontier_ctx()
    engine = ClosureEngine(
        ctx, plan=ShardPlan.simulated(2, block_n=12), backend="jnp"
    )
    frontier = DeviceFrontier(engine)
    name = sorted(frontier._cache["builders"])[0]
    builds = []
    orig = frontier._cache["builders"][name]
    frontier._cache["builders"][name] = lambda: builds.append(1) or orig()

    n = 8
    barrier = threading.Barrier(n)
    steps = [None] * n

    def hit(i):
        barrier.wait()
        steps[i] = frontier._step_fn(name)

    threads = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1
    assert len({id(s) for s in steps}) == 1


def test_stream_updater_uses_injected_clock():
    from repro.analysis.spmd_audit import _tiny_store
    from repro.query.stream import StreamUpdater

    store = _tiny_store(1, "rsag")
    clock = fuzz.VirtualClock()  # frozen unless explicitly advanced
    upd = StreamUpdater(store, clock=clock)
    new_rows = np.array([[0b1010_0101]], np.uint32)
    receipt = upd.stage(new_rows)
    # a wall-clock read anywhere in the stage path would make this > 0
    assert receipt.stage_wall_s == 0.0
    assert receipt.n_new_objects == 1


def test_query_engine_uses_injected_clock():
    from repro.analysis.spmd_audit import _tiny_store
    from repro.query.engine import QueryEngine

    store = _tiny_store(1, "rsag")
    clock = fuzz.VirtualClock()
    engine = QueryEngine(store, clock=clock)
    engine.closure_batch(np.zeros((2, 1), np.uint32))
    h = engine.stats.registry.histogram("service_s", kind="closure")
    assert h.count >= 1
    # service time measured on the frozen virtual clock is exactly zero
    assert h.sum == 0.0


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        findings_mod.Finding("lint", "x", "y", "z", severity="fatal")
