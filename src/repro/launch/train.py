"""Production training launcher.

    python -m repro.launch.train --arch <id> --shape train_4k \
        [--reduced] [--steps N] [--ckpt-dir D] [--mesh local|production|multi-pod]

On real TPU pods this builds the production mesh and runs the sharded
train step with FSDP/TP per the arch plan; on CPU use ``--reduced`` +
``--mesh local`` (what the examples and tests exercise).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_plan, get_shape
from repro.data.lm_data import make_batch_iterator
from repro.dist.partition import Partitioner
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer
from repro.models.config import ShapeConfig
from repro.train import step as tstep
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import get_optimizer, warmup_cosine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--mesh", default="local",
                   choices=["local", "production", "multi-pod", "none"])
    p.add_argument("--lr", type=float, default=3e-4)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    plan = get_plan(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig("reduced", "train", 64, 8)
    else:
        shape = get_shape(args.shape)

    if args.mesh == "none":
        mesh, part = None, None
    elif args.mesh == "local":
        mesh = make_local_mesh()
        part = Partitioner(mesh, fsdp=plan.fsdp)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi-pod")
        part = Partitioner(mesh, fsdp=plan.fsdp)

    opt = get_optimizer(plan.optimizer, warmup_cosine(args.lr, 100, args.steps))

    def init_state():
        params, axes = transformer.init_params(cfg, seed=0)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if part is not None:
            abstract = jax.tree_util.tree_map(
                lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params
            )
            sh = tstep.state_shardings(part, axes, abstract, opt)
            state = jax.device_put(state, sh)
        return state

    step_fn = jax.jit(tstep.make_train_step(cfg, opt, part), donate_argnums=0)

    trainer = Trainer(
        step_fn=step_fn,
        init_state_fn=init_state,
        batch_iter_fn=lambda start: make_batch_iterator(cfg, shape, seed=0,
                                                        start_step=start),
        cfg=TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, async_ckpt=True),
    )
    out = trainer.run()
    h = out["history"]
    print(f"done: steps={out['steps']} restarts={out['n_restarts']} "
          f"loss {h[0]['loss']:.4f} → {h[-1]['loss']:.4f}")
    return out


if __name__ == "__main__":
    main()
