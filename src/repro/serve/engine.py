"""Batched serving engine: prefill once, decode in lock-step slots.

A deliberately compact continuous-batching core: requests are padded into a
fixed slot batch (SPMD-friendly static shapes), prefilled together, then
decoded token-synchronously with per-slot stop tracking.  greedy or
temperature sampling.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 1024
    batch_slots: int = 8
    greedy: bool = True
    temperature: float = 1.0
    eos_id: int | None = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig, partitioner=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        shard = partitioner if (partitioner and partitioner.mesh) else None

        def _prefill(params, tokens, caches, valid_from):
            return transformer.prefill(params, cfg, tokens, caches, shard=shard,
                                       valid_from=valid_from)

        def _decode(params, tok, t, caches):
            return transformer.decode_step(params, cfg, tok, t, caches, shard=shard)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.greedy:
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1, :] / self.scfg.temperature
        ).astype(jnp.int32)

    def generate(self, prompts: list[list[int]], max_new: int, seed: int = 0):
        """Greedy/temperature generation for a list of prompts."""
        scfg = self.scfg
        B = scfg.batch_slots
        if len(prompts) > B:
            raise ValueError(f"{len(prompts)} prompts > {B} slots")
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        valid_from = np.full((B,), plen, np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad so last token aligns
            valid_from[i] = plen - len(p)

        caches = transformer.init_caches(self.cfg, B, scfg.max_len)
        logits, caches = self._prefill(
            self.params, jnp.asarray(toks), caches, jnp.asarray(valid_from)
        )
        key = jax.random.key(seed)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(logits, key)
        for step in range(max_new):
            t = plen + step
            for i in range(len(prompts)):
                if not done[i]:
                    v = int(tok[i])
                    out[i].append(v)
                    if scfg.eos_id is not None and v == scfg.eos_id:
                        done[i] = True
            if done[: len(prompts)].all() or t >= scfg.max_len - 1:
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None], t, caches)
            tok = self._sample(logits, sub)
        return out[: len(prompts)]
