"""Core: the paper's contribution — centralized and distributed FCA."""

from repro.core.context import FormalContext, paper_context
from repro.core.engine import ClosureEngine
from repro.core.frontier import DeviceFrontier
from repro.core.mr import MRResult, mrcbo, mrganter, mrganter_plus
from repro.core.nextclosure import all_closures, all_closures_batched, first_closure, next_closure
from repro.core.closebyone import CbOResult, close_by_one
from repro.core.hashindex import TwoLevelHash
from repro.core.incremental import add_object, add_objects, add_objects_sequential
from repro.core.lattice import ConceptLattice, build_lattice

__all__ = [
    "FormalContext",
    "paper_context",
    "ClosureEngine",
    "DeviceFrontier",
    "MRResult",
    "mrganter",
    "mrganter_plus",
    "mrcbo",
    "all_closures",
    "all_closures_batched",
    "first_closure",
    "next_closure",
    "CbOResult",
    "close_by_one",
    "TwoLevelHash",
    "ConceptLattice",
    "build_lattice",
    "add_object",
    "add_objects",
    "add_objects_sequential",
]
