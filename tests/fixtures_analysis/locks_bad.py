"""Known-bad lock-discipline fixture — the checker must flag BadQueue.

``_items`` is guarded in ``push`` (so the class declares it racy) but
``drain`` reads and mutates it bare from a public entry point: exactly
the defect class the ``unguarded-access`` rule exists for.  Analyzed by
path only (never imported).
"""

import threading


class BadQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def push(self, x):
        with self._lock:
            self._items.append(x)

    def drain(self):
        out = list(self._items)  # unguarded read of a guarded field
        self._items.clear()  # unguarded mutation of a guarded field
        return out
