"""While-aware static analysis of post-SPMD HLO: FLOPs, bytes, collectives.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies **once**, so any
scanned-layer model (all of ours) is undercounted by ~n_layers×.  This
module parses the HLO text into a computation graph, recovers scan trip
counts, and walks the call graph with multipliers:

  * **trip counts** — jax's ``lax.scan`` lowers to ``while`` whose condition
    is ``lt(carry[i], carry[j])`` with ``carry[j]`` a loop-invariant s32
    constant in the init tuple; we trace the compare operands through
    get-tuple-element → init-tuple → constant.
  * **FLOPs** — 2 · |output| · contraction-extent per ``dot`` (operand
    shapes resolved from their defining instructions).  Elementwise FLOPs
    are ignored (documented; dots dominate every assigned arch).
  * **HBM bytes** — per instruction: output + operand bytes, *not*
    descending into fused computations (a fusion is one kernel: its
    intermediates never touch HBM).  This is a no-cache-reuse traffic model.
  * **collective bytes** — payload per collective op (result bytes; operand
    bytes for reduce-scatter), scaled by enclosing trip counts.

The HLO is per-partition under SPMD ⇒ all results are per-device.
Validated against hand-counted small programs in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_list(segment: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list  # [(dtype, dims)]
    operands: list[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict  # name -> Instr
    order: list[str]


_OPCODE_RE = re.compile(
    r"^((?:\([^)]*\)|[a-z0-9_\[\],\{\}:\s\*\/]+))\s*([a-z][\w\-]*)\("
)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("{" in line) and ("(" in line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result shapes: everything before the opcode token
        om = re.search(r"(?:^|\s)([a-z][\w\-]*)\(", rhs)
        if om:
            opcode = om.group(1)
            result_seg = rhs[: om.start(1)]
            after = rhs[om.end():]  # just past the opening paren
        else:
            opcode = "unknown"
            result_seg, after = rhs, ""
        # operands: %refs inside the first (...) — slice to matching paren
        depth, end = 1, len(after)
        for i, ch in enumerate(after):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_seg = after[:end]
        operands = _OPERAND_RE.findall(operand_seg)
        cur.instrs[name] = Instr(
            name=name,
            opcode=opcode,
            result_shapes=_shape_list(result_seg),
            operands=operands,
            raw=rhs,
        )
        cur.order.append(name)
    return comps


def _attr(raw: str, key: str) -> str | None:
    m = re.search(rf"{key}=%([\w.\-]+)", raw)
    return m.group(1) if m else None


def _int_list(raw: str, key: str) -> list[int]:
    m = re.search(rf"{key}=\{{([0-9,\s]*)\}}", raw)
    if not m or not m.group(1).strip():
        return []
    return [int(x) for x in m.group(1).split(",")]


def _gte_index(instr: Instr) -> int | None:
    m = re.search(r"index=(\d+)", instr.raw)
    return int(m.group(1)) if m else None


def _trace_to_tuple_index(comp: Computation, name: str) -> int | None:
    """Follow copies/converts to a get-tuple-element of the computation param."""
    seen = 0
    while seen < 20:
        seen += 1
        instr = comp.instrs.get(name)
        if instr is None:
            return None
        if instr.opcode == "get-tuple-element":
            return _gte_index(instr)
        if instr.opcode in ("copy", "convert", "bitcast") and instr.operands:
            name = instr.operands[0]
            continue
        # wrapped compare: operands are parameters of a tiny computation —
        # handled by the caller.
        return None
    return None


def _const_int(instr: Instr) -> int | None:
    m = re.search(r"constant\((\d+)\)", instr.raw)
    return int(m.group(1)) if m else None


def _resolve_const(comp: Computation, name: str) -> int | None:
    """Follow copy/convert chains to a constant int within ``comp``."""
    for _ in range(10):
        ins = comp.instrs.get(name)
        if ins is None:
            return None
        if ins.opcode == "constant":
            return _const_int(ins)
        if ins.opcode in ("copy", "convert", "bitcast") and ins.operands:
            name = ins.operands[0]
            continue
        return None
    return None


def _find_lt_compare(comps, cond: Computation) -> list[str] | None:
    """Call-site operand names of the condition's LT compare (in ``cond``)."""
    for nm in cond.order[::-1]:
        ins = cond.instrs[nm]
        if ins.opcode == "compare" and "direction=LT" in ins.raw:
            return ins.operands
        if ins.opcode in ("fusion", "call"):
            callee = _attr(ins.raw, "calls") or _attr(ins.raw, "to_apply")
            if callee and callee in comps:
                sub = comps[callee]
                for nm2 in sub.order[::-1]:
                    ins2 = sub.instrs[nm2]
                    if ins2.opcode == "compare" and "direction=LT" in ins2.raw:
                        # map compare's parameter operands → call-site operands
                        mapped = []
                        for op in ins2.operands:
                            p = sub.instrs.get(op)
                            if p is not None and p.opcode == "parameter":
                                pm = re.search(r"parameter\((\d+)\)", p.raw)
                                i = int(pm.group(1)) if pm else None
                                mapped.append(
                                    ins.operands[i]
                                    if i is not None and i < len(ins.operands)
                                    else None
                                )
                            else:
                                mapped.append(None)
                        if all(m is not None for m in mapped):
                            return mapped
    return None


def _while_trip(comps, parent: Computation, wh: Instr) -> int | None:
    """Trip count of a jax-scan-style while: cond is lt(iter, CONST)."""
    cond_name = _attr(wh.raw, "condition")
    if cond_name is None or cond_name not in comps:
        return None
    cond = comps[cond_name]
    ops = _find_lt_compare(comps, cond)
    if not ops or len(ops) < 2:
        return None
    # The bound is usually a constant inside the condition computation …
    bound = _resolve_const(cond, ops[1])
    if bound is not None:
        return bound
    # … or a loop-invariant element of the init tuple.
    idx = _trace_to_tuple_index(cond, ops[1])
    if idx is not None and wh.operands:
        init = parent.instrs.get(wh.operands[0])
        if init is not None and init.opcode == "tuple" and idx < len(init.operands):
            return _resolve_const(parent, init.operands[idx])
    return None


# ---------------------------------------------------------------------------
# FLOPs / bytes / collectives with multipliers
# ---------------------------------------------------------------------------


def _dot_flops(comp: Computation, instr: Instr) -> int:
    out_elems = 1
    for dt, shape in instr.result_shapes:
        for d in shape:
            out_elems *= d
    lhs = comp.instrs.get(instr.operands[0]) if instr.operands else None
    contracting = _int_list(instr.raw, "lhs_contracting_dims")
    k = 1
    if lhs is not None and lhs.result_shapes:
        _, lshape = lhs.result_shapes[0]
        for c in contracting:
            if c < len(lshape):
                k *= lshape[c]
    return 2 * out_elems * k


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    unresolved_whiles: int = 0
    bytes_by_op: dict = dataclasses.field(default_factory=lambda: defaultdict(float))


def _fusion_param_windows(comps, ins: Instr) -> dict[int, int]:
    """Param-index → windowed byte size, for fusion params consumed *only*
    via dynamic-slice (XLA reads the slice window per execution)."""
    callee = _attr(ins.raw, "calls")
    if callee not in comps:
        return {}
    sub = comps[callee]
    param_idx: dict[str, int] = {}
    for nm in sub.order:
        p = sub.instrs[nm]
        if p.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", p.raw)
            if m:
                param_idx[nm] = int(m.group(1))
    uses: dict[str, list[tuple[str, int]]] = {nm: [] for nm in param_idx}
    for nm in sub.order:
        p = sub.instrs[nm]
        for pos, o in enumerate(p.operands):
            if o in uses:
                uses[o].append((nm, pos))
    out: dict[int, int] = {}
    for pname, use_list in uses.items():
        if not use_list:
            continue
        ok = all(
            sub.instrs[u].opcode in ("dynamic-slice", "dynamic-update-slice")
            and pos == 0
            for u, pos in use_list
        )
        if ok:
            total = 0
            for u, _ in use_list:
                du = sub.instrs[u]
                if du.opcode == "dynamic-slice":
                    total += _nbytes(du.result_shapes)
                else:  # DUS buffer param: charge the update window (in-place)
                    upd = sub.instrs.get(du.operands[1]) if len(du.operands) > 1 else None
                    total += _nbytes(upd.result_shapes) if upd is not None else 0
            out[param_idx[pname]] = total
    return out


def _fusion_root(comps, comp: Computation, ins: Instr) -> str | None:
    """Root opcode of a fusion's called computation (None for non-fusions)."""
    if ins.opcode != "fusion":
        return None
    callee = _attr(ins.raw, "calls")
    if callee not in comps:
        return None
    sub = comps[callee]
    if not sub.order:
        return None
    return sub.instrs[sub.order[-1]].opcode


def _collective_payload(comp: Computation, instr: Instr) -> int:
    size = _nbytes(instr.result_shapes)
    if instr.opcode.startswith("reduce-scatter"):
        op_sizes = 0
        for op in instr.operands:
            d = comp.instrs.get(op)
            if d is not None:
                op_sizes += _nbytes(d.result_shapes)
        size = max(size, op_sizes)
    return size


def analyze(text: str, default_trip: int = 1) -> Totals:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    totals = Totals()
    if entry is None:
        return totals

    def walk(comp: Computation, mult: float, depth: int = 0):
        if depth > 30:
            return
        for nm in comp.order:
            ins = comp.instrs[nm]
            op = ins.opcode
            if op == "dot":
                totals.flops += mult * _dot_flops(comp, ins)
            base = op.replace("-start", "")
            if base in _COLLECTIVE_KINDS and not op.endswith("-done"):
                size = _collective_payload(comp, ins)
                totals.collective_bytes += mult * size
                totals.coll_by_kind[base] += mult * size
                totals.coll_counts[base] += mult
            # HBM traffic model: operands + outputs at kernel granularity.
            if op not in ("tuple", "get-tuple-element", "parameter", "constant",
                          "while", "call", "bitcast"):
                result_b = _nbytes(ins.result_shapes)
                operand_b = []
                windows = _fusion_param_windows(comps, ins) if op == "fusion" else {}
                for i, o in enumerate(ins.operands):
                    d = comp.instrs.get(o)
                    b = _nbytes(d.result_shapes) if d is not None else 0
                    # Fusion params consumed only through dynamic-slice read a
                    # window, not the whole buffer (XLA windowed fusion).
                    if i in windows:
                        b = min(b, windows[i])
                    operand_b.append(b)
                size = result_b + sum(operand_b)
                # dynamic-(update-)slice is in-place / windowed on every
                # backend: charge slice traffic, not whole-buffer traffic.
                root = _fusion_root(comps, comp, ins)
                if root == "dynamic-update-slice" or op == "dynamic-update-slice":
                    non_buffer = [b for b in operand_b if b != result_b]
                    size = 2 * sum(non_buffer) if non_buffer else 2 * result_b
                elif root == "dynamic-slice" or op == "dynamic-slice":
                    size = 2 * result_b
                totals.hbm_bytes += mult * size
                totals.bytes_by_op[op] += mult * size
            # descend
            if op == "while":
                body = _attr(ins.raw, "body")
                trip = _while_trip(comps, comp, ins)
                if trip is None:
                    trip = default_trip
                    totals.unresolved_whiles += 1
                if body in comps:
                    walk(comps[body], mult * trip, depth + 1)
            elif op == "fusion":
                callee = _attr(ins.raw, "calls")
                if callee in comps:
                    # FLOPs only — fused intermediates don't touch HBM.
                    sub = comps[callee]
                    for nm2 in sub.order:
                        ins2 = sub.instrs[nm2]
                        if ins2.opcode == "dot":
                            totals.flops += mult * _dot_flops(sub, ins2)
            elif op in ("call", "custom-call", "conditional"):
                callee = _attr(ins.raw, "calls") or _attr(ins.raw, "to_apply")
                if callee in comps:
                    walk(comps[callee], mult, depth + 1)

    walk(entry, 1.0)
    return totals


# Back-compat simple interfaces ------------------------------------------------


def collective_bytes(text: str) -> dict:
    t = analyze(text)
    return {
        "total_bytes": int(t.collective_bytes),
        "by_kind": {k: int(v) for k, v in t.coll_by_kind.items()},
        "counts": {k: int(v) for k, v in t.coll_counts.items()},
    }
