"""Jitted public wrapper around the Pallas closure kernel.

Handles the padding/correction discipline so callers see clean semantics:

    closures, supports = batched_closure(rows, cands, n_attrs,
                                         n_valid_rows=N_real)

  * rows may carry pre-existing all-ones padding (``n_valid_rows`` real);
  * cands of any batch size (padded internally to the block multiple);
  * closures come back masked to ``n_attrs`` bits;
  * supports count only real rows.

Falls back to the pure-jnp reference for word widths beyond the kernel's
single-block limit or when ``use_kernel=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.kernels import ref
from repro.kernels.closure import MAX_W, closure_pallas

FULL_WORD = np.uint32(0xFFFFFFFF)


def _attr_mask_jnp(n_attrs: int, W: int) -> jnp.ndarray:
    return jnp.asarray(bitset.attr_mask(n_attrs, W))


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_attrs",
        "n_valid_rows",
        "block_b",
        "block_n",
        "use_kernel",
        "interpret",
        "fused_reduce",
    ),
)
def batched_closure(
    rows: jax.Array,
    cands: jax.Array,
    n_attrs: int,
    *,
    n_valid_rows: int,
    block_b: int = 8,
    block_n: int = 256,
    use_kernel: bool = True,
    interpret: bool = True,
    fused_reduce: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched closure with clean semantics.  rows [N,W], cands [B,W]."""
    N, W = rows.shape
    B = cands.shape[0]
    mask = _attr_mask_jnp(n_attrs, W)

    if not use_kernel or W > MAX_W:
        closures, supports = ref.closure_ref(rows, cands, fused_reduce=fused_reduce)
        n_pad_rows = N - n_valid_rows
        return closures & mask, supports - n_pad_rows

    # Pad rows to the N block multiple with all-ones (AND identity rows).
    N_pad = -N % block_n
    if N_pad:
        rows = jnp.concatenate(
            [rows, jnp.full((N_pad, W), FULL_WORD, dtype=jnp.uint32)], axis=0
        )
    # Pad candidate batch to the B block multiple (all-ones; outputs dropped).
    B_pad = -B % block_b
    if B_pad:
        cands = jnp.concatenate(
            [cands, jnp.full((B_pad, W), FULL_WORD, dtype=jnp.uint32)], axis=0
        )

    closures, supports = closure_pallas(
        rows, cands, block_b=block_b, block_n=block_n, interpret=interpret
    )
    closures = closures[:B] & mask
    # All-ones padding rows (pre-existing + internal) match every candidate.
    n_pad_rows = (N - n_valid_rows) + N_pad
    supports = supports[:B] - n_pad_rows
    return closures, supports


@functools.partial(
    jax.jit, static_argnames=("n_attrs", "n_valid_rows", "compute_dtype")
)
def closure_matmul(
    rows: jax.Array,
    cands: jax.Array,
    n_attrs: int,
    *,
    n_valid_rows: int,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, jax.Array]:
    """Closure as two MXU matmuls over complement bit-planes (§Perf C2).

    Let ``R̄ ∈ {0,1}^{N×m}`` be the complement of the unpacked context and
    ``C ∈ {0,1}^{B×m}`` the unpacked candidates.  Then

        miss   = C · R̄ᵀ          (miss[b,n] = #candidate attrs absent in row n)
        match  = (miss == 0)
        absent = match · R̄        (absent[b,m] = #matching rows missing attr m)
        Y''    = (absent == 0)

    Both contractions are systolic-array work — the bitwise ⊕ hot-spot
    becomes matmuls, with O(B·m + B·N) HBM traffic instead of O(B·N·W).
    Exactness: {0,1} inputs with fp32 accumulation — sums are exact up to
    2²⁴ ≫ any shard's row count.  All-ones padding rows have an empty
    complement, so they match every candidate and never add absences
    (supports corrected by the pad count, as everywhere).
    """
    N, W = rows.shape
    B = cands.shape[0]
    m_pad = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def unpack(x):
        bits = (x[:, :, None] >> shifts) & jnp.uint32(1)
        return bits.reshape(x.shape[0], m_pad)[:, :n_attrs]

    rows_c = (1 - unpack(rows)).astype(compute_dtype)  # [N, m] complement
    cand_b = unpack(cands).astype(compute_dtype)  # [B, m]

    miss = jnp.einsum("bm,nm->bn", cand_b, rows_c,
                      preferred_element_type=jnp.float32)
    match = miss == 0.0  # [B, N]
    absent = jnp.einsum("bn,nm->bm", match.astype(compute_dtype), rows_c,
                        preferred_element_type=jnp.float32)
    closure_bits = (absent == 0.0)  # [B, m]

    pad = m_pad - n_attrs
    if pad:
        closure_bits = jnp.concatenate(
            [closure_bits, jnp.zeros((B, pad), bool)], axis=1
        )
    weights = (jnp.uint32(1) << shifts).astype(jnp.uint32)
    closures = (
        closure_bits.reshape(B, W, 32).astype(jnp.uint32) * weights
    ).sum(axis=-1, dtype=jnp.uint32)
    supports = match.sum(axis=-1, dtype=jnp.int32) - (N - n_valid_rows)
    return closures, supports


def bucket_size(n: int, minimum: int = 8) -> int:
    """Next power-of-two capacity ≥ n — bounds jit recompiles across the
    iterative drivers (the frontier size changes every iteration)."""
    size = minimum
    while size < n:
        size <<= 1
    return size
