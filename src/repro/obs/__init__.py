"""repro.obs — round-level tracing + metrics for mining and serving.

The observability floor: span traces (Perfetto ``trace_event`` JSON) of
every host-side round boundary, a label-aware metrics registry with
HDR-style latency histograms, and the shared schedule-census mixin both
stats tiers inherit.  Tracing is off by default (shared no-op tracer);
install one with ``use_tracer(Tracer())`` or ``fca ... --trace out.json``.

The serving tier adds ``export`` (OpenMetrics text exposition +
``MetricsServer`` scrape endpoint) and ``slo`` (latency/shed objectives,
burn rates, and the bench-regression gate CI runs).
"""

from repro.obs.export import (
    MetricsServer,
    parse_openmetrics,
    sanitize_name,
    to_openmetrics,
)
from repro.obs.metrics import Histogram, Registry, ScheduleCensus, StatsBase
from repro.obs.slo import SLO, burn_rate, check_baselines, evaluate, run_gate
from repro.obs.trace import (
    NOOP,
    NoopTracer,
    Tracer,
    async_overlaps,
    current,
    set_tracer,
    span_rollup,
    start_device_trace,
    stop_device_trace,
    use_tracer,
    validate_trace,
)

__all__ = [
    "MetricsServer",
    "parse_openmetrics",
    "sanitize_name",
    "to_openmetrics",
    "SLO",
    "burn_rate",
    "check_baselines",
    "evaluate",
    "run_gate",
    "Histogram",
    "Registry",
    "ScheduleCensus",
    "StatsBase",
    "NOOP",
    "NoopTracer",
    "Tracer",
    "async_overlaps",
    "current",
    "set_tracer",
    "span_rollup",
    "start_device_trace",
    "stop_device_trace",
    "use_tracer",
    "validate_trace",
]
