"""Pass 3b — deterministic schedule-fuzzing harness for the serve tier.

The static lock checker cannot see races that flow through aliases or
snapshot references, so this harness *runs* the real
:class:`repro.serve.admission.AdmissionQueue` against a scripted
double-buffered store under a *virtual clock* and a seeded random
interleaving of ``submit`` / ``poll`` / ``advance`` / ``stage`` /
``commit`` operations, then checks happens-before invariants on the
snapshot versions every dispatched micro-batch observed:

* **monotone reads** — observed snapshot versions never go backwards
  (each batch reads ONE consistent ``store.state`` at entry; a batch
  observing an older version than a previous batch means a torn read);
* **committed floor** — a batch dispatched after ``commit()`` returned
  must observe at least that committed version (no stale-snapshot
  resurrection);
* **conservation** — after ``flush()``: nothing pending, every admitted
  ticket carries a result, shed + completed == submitted, and every
  ticket's virtual dispatch time ≥ its arrival time.

Determinism: one thread, one ``random.Random(seed)``, a virtual clock
that only moves on explicit ``advance`` ops — the same seed replays the
same schedule bit-for-bit (the CI gate runs a fixed seed set).

``inject_race=True`` swaps in a store whose ``commit`` *publishes a
stale snapshot* (the staged version is dropped on the floor) — the
defect the double-buffer discipline exists to prevent.  The harness must
flag it; that self-test is how we know the invariants have teeth.
"""

from __future__ import annotations

import dataclasses
import random

import numpy as np

from repro.analysis.findings import Finding


class VirtualClock:
    """Injectable monotone clock; advances only when the schedule says so."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


@dataclasses.dataclass(frozen=True)
class _Snapshot:
    version: int


@dataclasses.dataclass(frozen=True)
class _State:
    snapshot: _Snapshot


class ScriptedStore:
    """Double-buffered snapshot store with the ConceptStore discipline:
    ``state`` is one immutable reference, ``stage`` prepares a successor,
    ``commit`` swaps it in.  ``inject_race=True`` breaks the swap —
    commit discards the staged version and republishes a *stale* one."""

    def __init__(self, *, inject_race: bool = False):
        self.state = _State(_Snapshot(version=0))
        self._staged: _State | None = None
        self.committed_version = 0
        self.inject_race = inject_race

    def stage(self):
        self._staged = _State(_Snapshot(self.state.snapshot.version + 1))

    def commit(self) -> int:
        if self._staged is None:
            return self.committed_version
        staged = self._staged
        self._staged = None
        if self.inject_race:
            # the bug under test: the swap publishes an old snapshot while
            # the committed floor moves forward
            self.state = _State(_Snapshot(max(0, staged.snapshot.version - 2)))
        else:
            self.state = staged
        self.committed_version = staged.snapshot.version
        return self.committed_version


class ProbeEngine:
    """Stub query engine for the admission queue: every batch records the
    snapshot version it observed and the committed floor at dispatch —
    the happens-before evidence the invariants run on."""

    def __init__(self, store: ScriptedStore, *, slots: int = 4):
        from repro.obs import StatsBase

        self.store = store
        self.cfg = dataclasses.make_dataclass("Cfg", ["slots"])(slots)
        self.stats = StatsBase()
        self.observations: list[tuple[int, int]] = []  # (observed, floor)

    def _observe(self, n: int):
        state = self.store.state  # ONE consistent read per micro-batch
        self.observations.append(
            (state.snapshot.version, self.store.committed_version)
        )
        return state.snapshot.version, n

    def closure_batch(self, arr):
        v, n = self._observe(arr.shape[0])
        return arr, np.full(n, v), np.arange(n)

    def topk_batch(self, arr, k=5):
        v, n = self._observe(arr.shape[0])
        return np.full((n, k), v), np.zeros((n, k))

    def lookup_batch(self, arr):
        v, n = self._observe(arr.shape[0])
        return np.full(n, v)

    def rules_batch(self, index, arr, k=5, min_conf=0.0, rank_by="confidence"):
        v, n = self._observe(arr.shape[0])
        return np.full((n, k), v), np.zeros((n, k)), arr


OPS = ("submit", "poll", "advance", "stage", "commit")


def run_schedule(
    seed: int,
    *,
    steps: int = 200,
    slots: int = 4,
    inject_race: bool = False,
) -> list[Finding]:
    """One fuzzed schedule; returns invariant violations as findings."""
    from repro.serve.admission import AdmissionConfig, AdmissionQueue

    rng = random.Random(seed)
    clock = VirtualClock()
    store = ScriptedStore(inject_race=inject_race)
    engine = ProbeEngine(store, slots=slots)
    queue = AdmissionQueue(
        engine,
        AdmissionConfig(max_wait_s=0.004, depth=16),
        clock=clock,
    )
    label = f"seed={seed}/race={'on' if inject_race else 'off'}"
    findings = []
    tickets = []
    kinds = ("closure", "topk", "lookup")
    for _ in range(steps):
        op = rng.choices(OPS, weights=(6, 3, 3, 2, 2))[0]
        if op == "submit":
            payload = np.full(1, rng.randrange(256), np.uint32)
            tickets.append(queue.submit(rng.choice(kinds), payload))
        elif op == "poll":
            queue.poll()
        elif op == "advance":
            clock.advance(rng.choice((0.001, 0.002, 0.005)))
        elif op == "stage":
            store.stage()
        else:
            store.commit()
    queue.flush()

    def err(rule, msg):
        findings.append(Finding("fuzz", rule, label, msg))

    prev = -1
    for i, (observed, floor) in enumerate(engine.observations):
        if observed < prev:
            err(
                "nonmonotone-snapshot",
                f"batch {i} observed snapshot v{observed} after an earlier "
                f"batch observed v{prev} — torn/stale snapshot read",
            )
        if observed < floor:
            err(
                "stale-after-commit",
                f"batch {i} observed snapshot v{observed} but v{floor} had "
                "already committed (happens-before violation)",
            )
        prev = max(prev, observed)

    if queue.pending():
        err("unflushed-tickets", f"{queue.pending()} tickets stuck after flush")
    for i, t in enumerate(tickets):
        if t.shed:
            continue
        if t.result is None or t.done_s is None:
            err("lost-ticket", f"admitted ticket {i} never dispatched")
        elif t.dispatch_s < t.arrival_s:
            err(
                "time-travel",
                f"ticket {i} dispatched at {t.dispatch_s} before its "
                f"arrival {t.arrival_s}",
            )
    st = queue.stats
    if st.shed + st.completed != st.submitted:
        err(
            "ticket-conservation",
            f"shed({st.shed}) + completed({st.completed}) != "
            f"submitted({st.submitted})",
        )
    return findings


DEFAULT_SEEDS = tuple(range(8))


def run(report, *, seeds=DEFAULT_SEEDS, steps: int = 200) -> list[Finding]:
    """Clean-schedule sweep (must be silent) plus the injected-race
    self-test (must fire) — a harness that cannot detect the seeded bug
    is itself reported."""
    findings = []
    for seed in seeds:
        findings.extend(run_schedule(seed, steps=steps))
        report.note_checked("fuzz", "schedules")
    injected = run_schedule(seeds[0], steps=steps, inject_race=True)
    report.note_checked("fuzz", "injected")
    if not any(
        f.rule in ("stale-after-commit", "nonmonotone-snapshot")
        for f in injected
    ):
        findings.append(
            Finding(
                "fuzz",
                "harness-blind",
                f"seed={seeds[0]}/race=on",
                "injected stale-snapshot commit produced no violation — "
                "the fuzz invariants lost their teeth",
            )
        )
    return findings
