"""Device-resident frontier pipeline for the MR* drivers (§Perf F1, §Dist).

The seed drivers kept the *frontier* on the host: per-intent Python loops
built ⊕/CbO seeds, `np.unique` deduped candidates, and the two-level hash
filtered closures row by row — O(frontier · m) small host ops per
iteration.  This module runs the whole frontier side through the engine's
:class:`repro.dist.ShardPlan`:

    frontier [F, W]  ──►  vectorized seed expansion (LOW/BIT broadcast)
                     ──►  validity compaction (+ local pruning: lexsort +
                          adjacent-unique over packed words, *before* the
                          reduce — MRGanter+'s per-partition combiner)
                     ──►  plan-SPMD round, one region per chunk:
                          local closure map → AND-allreduce (+ support
                          psum) → fused canonicity / feasibility /
                          closure-dedupe / iceberg min-support cut
                     ──►  compacted survivors

Frontier state and the LOW/BIT tables are plan-replicated, so under a real
mesh the expansion and pruning stages compute partition-locally on every
device (no central expand + broadcast), and the only wire traffic per round
is the AND-allreduce itself — sized by the *pruned* candidate count, since
the chunk buckets are chosen after the dedupe.  Pruned candidates never
cross the wire.

On a 2-D plan (``ShardPlan.cand_parts > 1`` — the Spark reproduction's
row-block × column-block decomposition) the chunk itself is blocked over
the candidate axis: each device closes only its ``1/cand_parts`` block of
the chunk, the AND-allreduce runs over the object axes at the *block*
batch size, the driver filter runs block-locally, and the blocks' compacted
survivors are all-gathered along ``cand`` afterwards — so one round absorbs
``cand_parts × max_batch`` candidates at the same per-device footprint, and
pruned candidates never replicate across the candidate axis either.  XLA shapes are static, so the one scalar sync per round
(the surviving-seed count) is what lets the reduce shrink to the pruned
bucket; everything else stays on device.

Every stage is a jitted device function over bucket-padded shapes
(powers of two — recompiles are bounded by O(log max_frontier)); the host
loop shrinks to convergence control plus one bulk download of surviving
intents per iteration (and, for MRGanter+, one bulk upload of the novel
frontier after the global-registry check).  This is the Twister framing of
§3 taken to its limit: static data (context rows, LOW/BIT tables) never
moves, and the dynamic delta crossing the boundary is exactly the new
concepts.

Benchmarked in EXPERIMENTS.md §Perf/§Dist; equivalence to the host-loop
drivers is asserted in tests/test_frontier_pipeline.py and, on a real
8-device mesh, tests/test_distributed_8dev.py.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lectic
from repro.kernels import frontier as fkern
from repro.kernels.ops import bucket_size
from repro.obs import trace as obs


# ---------------------------------------------------------------------------
# device primitives
# ---------------------------------------------------------------------------


def _compact(valid: jax.Array, *arrays) -> tuple:
    """Stable-move rows with ``valid`` to the front of every array.

    Returns ``(count, *reordered_arrays)`` — shapes unchanged (rows past
    ``count`` are garbage the caller slices away after a scalar sync).
    """
    perm = jnp.argsort(~valid)  # jax argsort is stable
    return (valid.sum(dtype=jnp.int32), *(a[perm] for a in arrays))


def _sort_unique(seeds: jax.Array, valid: jax.Array, *arrays) -> tuple:
    """Lexsort packed rows, mark adjacent duplicates, compact survivors.

    Invalid rows sort to the end (primary key), so duplicate detection only
    ever compares real rows.  Returns ``(count, seeds, *arrays)`` with the
    unique valid rows moved to the front.
    """
    keys = tuple(seeds[:, w] for w in reversed(range(seeds.shape[1]))) + (~valid,)
    perm = jnp.lexsort(keys)
    seeds = seeds[perm]
    valid = valid[perm]
    same_prev = jnp.all(seeds == jnp.roll(seeds, 1, axis=0), axis=-1)
    same_prev = same_prev.at[0].set(False)
    keep = valid & ~(same_prev & jnp.roll(valid, 1))
    return _compact(keep, seeds, *(a[perm] for a in arrays))


def slice_pad(arr, lo: int, cap: int, fill=0):
    """Static-shape device slice ``arr[lo:lo+cap]``, zero-padded past the
    end — keeps chunk shapes bucketed without a host round-trip.

    This is a *windowing* primitive: rows past ``lo + cap`` are simply not
    in this window, and the caller is responsible for covering them with
    further windows (the drivers' chunk loops) — callers that use it to
    retain an entire array must size ``cap`` to hold every live row (see
    :meth:`DeviceFrontier._adopt`, which guards exactly that).
    """
    chunk = arr[lo : lo + cap]
    short = cap - chunk.shape[0]
    if short > 0:
        pad = jnp.full((short, *arr.shape[1:]), fill, arr.dtype)
        chunk = jnp.concatenate([chunk, pad], axis=0)
    return chunk


# ---------------------------------------------------------------------------
# jitted stages (shapes bucketed by the driver)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_attrs", "dedupe"))
def expand_oplus(frontier, n_valid, LOW, BIT, *, n_attrs: int, dedupe: bool):
    """⊕-expansion of a frontier [F, W] → compacted seeds [F·m, W] + count.

    ``dedupe=True`` is MRGanter+'s local pruning: duplicate seeds die here,
    on the partition, before any reduce is sized (``dedupe_candidates``).
    """
    F, W = frontier.shape
    row_ok = jnp.arange(F) < n_valid
    seeds, valid = lectic.oplus_seeds_jnp(frontier, LOW, BIT, n_attrs)
    valid = valid & row_ok[:, None]
    seeds = seeds.reshape(F * n_attrs, W)
    valid = valid.reshape(F * n_attrs)
    if dedupe:
        n, seeds = _sort_unique(seeds, valid)
    else:
        n, seeds = _compact(valid, seeds)
    return seeds, n


@functools.partial(jax.jit, static_argnames=("n_attrs",))
def expand_cbo(frontier, gens, n_valid, BIT, *, n_attrs: int):
    """CbO expansion: seeds ``Y ∪ {a}`` for ``a > gen(Y), a ∉ Y``.

    Returns compacted ``(seeds [F·m, W], parent_rows, gen_attr, count)`` —
    parent/generator lineage rides along for the fused canonicity stage.
    """
    F, W = frontier.shape
    row_ok = jnp.arange(F) < n_valid
    seeds, valid = lectic.cbo_seeds_jnp(frontier, gens, BIT, n_attrs)
    valid = valid & row_ok[:, None]
    seeds = seeds.reshape(F * n_attrs, W)
    valid = valid.reshape(F * n_attrs)
    parent = jnp.repeat(jnp.arange(F, dtype=jnp.int32), n_attrs)
    gen = jnp.tile(jnp.arange(n_attrs, dtype=jnp.int32), F)
    n, seeds, parent, gen = _compact(valid, seeds, parent, gen)
    return seeds, frontier[parent], gen, n


def unique_closures(closures, n_valid):
    """Intra-batch dedupe of closure outputs: sorted-unique + compaction.

    The cross-iteration novelty check stays with the host registry; this
    stage just collapses the (heavily duplicated) reduce output so only
    distinct intents cross the device→host boundary.  Fused into the
    plan's SPMD round after the AND-allreduce (the plan places it:
    in-region on a mesh, once past the vmap on a simulated plan).
    """
    valid = jnp.arange(closures.shape[0]) < n_valid
    n, closures = _sort_unique(closures, valid)
    return closures, n


# -- candidate-axis (2-D) block merges ---------------------------------------
# Post-reduce filters run block-locally on each candidate shard; these
# merges consume the cand-axis all-gather of the filtered blocks
# ([cand_parts, Bc, ...] stacks + per-block survivor counts) and produce
# the chunk's global survivors.  Shard-invariant by construction (their
# inputs are the gathered stacks), so the plan places them like any fused
# post stage.


def _block_valid(counts, Bc):
    """Flattened validity mask for gathered [cand, Bc, ...] block stacks."""
    return (jnp.arange(Bc)[None, :] < counts[:, None]).reshape(-1)


def merge_blocks_plain(gc_blocks):
    """No filter ran: concatenating blocks restores the chunk's row order
    (block i held rows [i·Bc, (i+1)·Bc) of the chunk)."""
    return gc_blocks.reshape(-1, gc_blocks.shape[-1])


def merge_blocks_compact(gc_blocks, counts):
    """Compact each block's survivors (already front-packed) into one run."""
    valid = _block_valid(counts, gc_blocks.shape[1])
    n, gc = _compact(valid, gc_blocks.reshape(-1, gc_blocks.shape[-1]))
    return gc, n


def merge_blocks_unique(gc_blocks, counts):
    """Block-local dedupe removed intra-block duplicates; this pass removes
    the cross-block ones (sorted-unique over the concatenated survivors)."""
    valid = _block_valid(counts, gc_blocks.shape[1])
    n, gc = _sort_unique(gc_blocks.reshape(-1, gc_blocks.shape[-1]), valid)
    return gc, n


def merge_blocks_cbo(gc_blocks, gen_blocks, counts):
    """CbO survivors with their generator lineage (canonicity already ran
    block-locally; canonical survivors are globally unique by the CbO
    generation-tree argument, so compaction is the whole merge)."""
    valid = _block_valid(counts, gc_blocks.shape[1])
    n, gc, gens = _compact(
        valid,
        gc_blocks.reshape(-1, gc_blocks.shape[-1]),
        gen_blocks.reshape(-1),
    )
    return gc, gens, n


def filter_canonical(closures, parents, gens, n_valid, LOW):
    """CbO canonicity ``(Z ^ Y) & LOW[a] == 0`` + survivor compaction.

    Survivors are *exactly* the new concepts (CbO generates each concept
    once under this test), so they double as the next device frontier.
    Fused into the plan's SPMD round, on the globally-reduced closures.
    """
    ok = lectic.feasible_jnp(closures, parents, gens, LOW)
    ok = ok & (jnp.arange(closures.shape[0]) < n_valid)
    n, closures, gens = _compact(ok, closures, gens)
    return closures, gens, n


def ganter_select(closures, Y, valid, LOW, mask, *, n_attrs: int):
    """NextClosure's Alg.-5 scan as one device op: feasibility for every
    generator attribute, then the *largest* feasible one wins (the shared
    argmax + dynamic-slice gather in ``lectic.select_lectic``)."""
    gens = jnp.arange(n_attrs, dtype=jnp.int32)
    ok = lectic.feasible_jnp(closures[:n_attrs], Y[None, :], gens, LOW)
    ok = ok & valid
    Y_next, _ = lectic.select_lectic(closures[:n_attrs], ok)
    return Y_next, jnp.all(Y_next == mask)


# ---------------------------------------------------------------------------
# speculative round state (async scheduler)
# ---------------------------------------------------------------------------


@jax.jit
def _pack_round(a, b, payload):
    """Pack a round's scalar outcomes + payload into ONE uint32 D2H buffer.

    Layout ``[a, b, payload.ravel()]`` — the drivers' per-round readback
    (surviving-seed count, survivor count, and the survivor rows that used
    to cross as separate ``np.asarray`` calls) collapses to a single
    transfer whose copy is started asynchronously at dispatch time.
    """
    head = jnp.stack([a.astype(jnp.uint32), b.astype(jnp.uint32)])
    return jnp.concatenate([head, payload.reshape(-1).astype(jnp.uint32)])


def _start_d2h(arr) -> None:
    """Begin the device→host copy without blocking (overlaps the next
    dispatch); purely an optimization — the later ``np.asarray`` is what
    the reconcile actually waits on."""
    try:
        arr.copy_to_host_async()
    except Exception:  # pragma: no cover — optional fast path only
        pass


@dataclasses.dataclass
class SpecRound:
    """One in-flight speculative round: the second frontier slot.

    Holds the expansion buffers round r was dispatched from (so an under-
    covered speculation can re-chunk them synchronously), the survivor
    buffers the *next* round was speculatively chained on, and the packed
    readback already copying to the host.  ``cap`` is the speculative
    chunk's padded coverage — reconciliation compares it against the true
    seed count to decide whether speculation covered the round.  ``slot``
    is how many survivor rows the adopted slot kept (the next round's
    expansion input); a true survivor count past it means the in-flight
    speculation chained on a truncated frontier and must be discarded.
    """

    kind: str  # "oplus" | "cbo" | "ganter"
    packed: jax.Array
    cap: int
    blk: int
    two_d: bool
    seeds: jax.Array | None = None
    parents: jax.Array | None = None
    gen: jax.Array | None = None
    surv_z: jax.Array | None = None
    surv_g: jax.Array | None = None
    slot: int = 0
    # observability: the round's sequence number (the async trace span id)
    # and its dispatch timestamp (per-round latency = reconcile − dispatch)
    seq: int = 0
    t_dispatch: float = 0.0


@dataclasses.dataclass
class OplusRound:
    """Reconciled MRGanter+ round: true seed count + the round's closures."""

    n_seeds: int
    closures: np.ndarray
    under_covered: bool


@dataclasses.dataclass
class CboRound:
    """Reconciled MRCbo round: true seed count + canonical survivors."""

    n_seeds: int
    new_intents: np.ndarray
    n_new: int
    under_covered: bool


# ---------------------------------------------------------------------------
# driver-facing pipeline
# ---------------------------------------------------------------------------


class DeviceFrontier:
    """Holds the plan-replicated frontier state for one mining run and
    exposes the per-iteration fused steps the MR* drivers are written in.

    The engine's ShardPlan provides placement and the SPMD round builder
    (`spmd_step`); this class owns expansion/pruning orchestration, the
    fused post-reduce filters, and the bucket/chunk bookkeeping.
    """

    def __init__(self, engine, *, dedupe_closures: bool = False):
        self.engine = engine
        self.plan = engine.plan
        self.n_attrs = engine.ctx.n_attrs
        self.W = engine.ctx.W
        # Collapse duplicate *closure outputs* on device before download.
        # Saves D2H bandwidth on real accelerators; on the CPU 'device' the
        # XLA variadic sort costs more than the memcpy it saves, so the
        # default leaves cross-closure dedupe to the (vectorized) host
        # registry.  Equivalence holds either way (tests cover both).
        self.dedupe_closures = dedupe_closures
        self._frontier = None  # [Fb, W] plan-replicated
        self._gens = None  # [Fb] plan-replicated (CbO lineage)
        self._n = 0
        # Second frontier slot (async rounds): when a speculative round is
        # adopted before its counts are reconciled, ``_n`` is None and the
        # survivor count lives on device in ``_n_dev`` — round r+1 chains
        # on the device scalar without any host readback.
        self._n_dev = None
        # Last reconciled TRUE seed / survivor counts — size the next
        # speculative chunk and its adopted slot (see _spec_caps /
        # _slot_rows).  Hints only: too small merely triggers the
        # under-coverage fallback, never an incorrect result.
        self._seed_hint = None
        self._k_hint = None
        # Round sequence counter + plan-geometry tags for the span tracer
        # (repro.obs) — the seq numbers the ``mine/round[r]`` spans and ids
        # the async round tracks, so sync/async timelines line up.
        self._seq = 0
        self._tags = engine.plan.trace_tags()

        # Everything frontier-static is memoized on the ENGINE, not this
        # object: a driver builds a fresh DeviceFrontier per run, and
        # per-run jax.jit wrappers would re-trace and re-compile the whole
        # pipeline every run (defeating the warm-run protocol).  The
        # tables are engine-ctx-determined and the fused steps are
        # identical for every DeviceFrontier of a given engine.  Steps are
        # built lazily (``_step_fn``): a run that never mines icebergs
        # never traces the iceberg variants.
        #
        # The build runs under the engine's ``_frontier_lock``: frontiers
        # are constructed from both the main thread and the admission
        # dispatcher thread, and two racing first-misses would otherwise
        # each build a cache (losing the memoization and tracing every
        # step twice).
        with engine._frontier_lock:
            cache = getattr(engine, "_frontier_cache", None)
            if cache is None:
                t = lectic.LecticTables(self.n_attrs)
                n_attrs = self.n_attrs

                # Host-side tables are closed over by the fused post stages
                # (baked into the SPMD region as compile-time constants).
                def post_cbo(gc, parents, gens, n_valid):
                    return filter_canonical(
                        gc, parents, gens, n_valid, jnp.asarray(t.LOW)
                    )

                def post_ganter(gc, Y, valid):
                    return ganter_select(
                        gc, Y, valid, jnp.asarray(t.LOW),
                        jnp.asarray(t.attr_mask), n_attrs=n_attrs,
                    )

                # Iceberg posts: min_support rides as a *traced* extra operand,
                # so one compile serves every threshold.  The support filter
                # runs right after the psum, inside the same SPMD region —
                # infrequent candidates are compacted away before they are
                # downloaded, re-expanded, or ever sized into a later reduce.
                def post_iceberg(gc, gs, n_valid, min_sup):
                    keep = (jnp.arange(gc.shape[0]) < n_valid) & (gs >= min_sup)
                    n, gc = _compact(keep, gc)
                    return gc, n

                def post_iceberg_unique(gc, gs, n_valid, min_sup):
                    keep = (jnp.arange(gc.shape[0]) < n_valid) & (gs >= min_sup)
                    n, gc = _sort_unique(gc, keep)
                    return gc, n

                def post_cbo_iceberg(gc, gs, parents, gens, n_valid, min_sup):
                    ok = lectic.feasible_jnp(gc, parents, gens, jnp.asarray(t.LOW))
                    ok = ok & (jnp.arange(gc.shape[0]) < n_valid)
                    ok = ok & (gs >= min_sup)
                    n, gc, gens = _compact(ok, gc, gens)
                    return gc, gens, n

                # Candidate-axis (2-D) posts: the same filters made
                # *block-local* — each candidate shard filters its own block of
                # the chunk right after the object-axis reduce, using its block
                # index to reconstruct row validity from the replicated valid
                # count.  Survivors are all-gathered along ``cand`` only after
                # these run (the merge_blocks_* stages above finish the job).
                def _bvalid(idx, Bc, n_valid):
                    return (jnp.arange(Bc) + idx * Bc) < n_valid

                def post2d_unique(idx, gc, n_valid):
                    n, gc = _sort_unique(gc, _bvalid(idx, gc.shape[0], n_valid))
                    return gc, n

                def post2d_iceberg(idx, gc, gs, n_valid, min_sup):
                    keep = _bvalid(idx, gc.shape[0], n_valid) & (gs >= min_sup)
                    n, gc = _compact(keep, gc)
                    return gc, n

                def post2d_iceberg_unique(idx, gc, gs, n_valid, min_sup):
                    keep = _bvalid(idx, gc.shape[0], n_valid) & (gs >= min_sup)
                    n, gc = _sort_unique(gc, keep)
                    return gc, n

                def post2d_cbo(idx, gc, parents, gens, n_valid):
                    ok = lectic.feasible_jnp(gc, parents, gens, jnp.asarray(t.LOW))
                    ok = ok & _bvalid(idx, gc.shape[0], n_valid)
                    n, gc, gens = _compact(ok, gc, gens)
                    return gc, gens, n

                def post2d_cbo_iceberg(
                    idx, gc, gs, parents, gens, n_valid, min_sup
                ):
                    ok = lectic.feasible_jnp(gc, parents, gens, jnp.asarray(t.LOW))
                    ok = ok & _bvalid(idx, gc.shape[0], n_valid)
                    ok = ok & (gs >= min_sup)
                    n, gc, gens = _compact(ok, gc, gens)
                    return gc, gens, n

                def post_ganter_iceberg(gc, gs, Y, valid, min_sup):
                    # Alg.-5 scan restricted to *frequent* successors: the next
                    # frequent closure in lectic order is Y ⊕ a for the largest
                    # feasible a with support ≥ min_sup (any smaller frequent
                    # closure between would be a subset of it — see
                    # tests/test_rules.py for the property statement).
                    gens = jnp.arange(n_attrs, dtype=jnp.int32)
                    ok = lectic.feasible_jnp(
                        gc[:n_attrs], Y[None, :], gens, jnp.asarray(t.LOW)
                    )
                    ok = ok & valid & (gs[:n_attrs] >= min_sup)
                    Y_next, found = lectic.select_lectic(gc[:n_attrs], ok)
                    return Y_next, ~found

                cache = {
                    # plan-replicated so expansion runs on every partition
                    # instead of one device + a broadcast at the region edge
                    "LOW": self.plan.replicate(t.LOW),
                    "BIT": self.plan.replicate(t.BIT),
                    # fused per-round SPMD steps: each is ONE plan round doing
                    # closure map → AND-allreduce [+ support psum] → the
                    # driver's filter.  Values are zero-arg builders; built
                    # steps land in "steps".
                    "steps": {},
                    "builders": {
                        "plain": lambda: engine.spmd_step(),
                        "unique": lambda: engine.spmd_step(
                            unique_closures, n_extra=1
                        ),
                        "cbo": lambda: engine.spmd_step(post_cbo, n_extra=3),
                        "ganter": lambda: engine.spmd_step(post_ganter, n_extra=2),
                        "iceberg": lambda: engine.spmd_step(
                            post_iceberg, with_supports=True, n_extra=2
                        ),
                        "iceberg_unique": lambda: engine.spmd_step(
                            post_iceberg_unique, with_supports=True, n_extra=2
                        ),
                        "cbo_iceberg": lambda: engine.spmd_step(
                            post_cbo_iceberg, with_supports=True, n_extra=4
                        ),
                        "ganter_iceberg": lambda: engine.spmd_step(
                            post_ganter_iceberg, with_supports=True, n_extra=3
                        ),
                        # 2-D (candidate × object) variants: one plan round per
                        # chunk of cand_parts blocks — map + object-axis reduce
                        # per block, block-local filter, cand-axis survivor
                        # gather, merge.  Built only when a driver runs on a
                        # cand-sharded plan.
                        "plain2d": lambda: engine.spmd_step_cand(
                            None, merge_blocks_plain
                        ),
                        "unique2d": lambda: engine.spmd_step_cand(
                            post2d_unique, merge_blocks_unique, n_post_rep=1
                        ),
                        "iceberg2d": lambda: engine.spmd_step_cand(
                            post2d_iceberg, merge_blocks_compact,
                            with_supports=True, n_post_rep=2,
                        ),
                        "iceberg_unique2d": lambda: engine.spmd_step_cand(
                            post2d_iceberg_unique, merge_blocks_unique,
                            with_supports=True, n_post_rep=2,
                        ),
                        "cbo2d": lambda: engine.spmd_step_cand(
                            post2d_cbo, merge_blocks_cbo,
                            n_cand=3, n_post_rep=1,
                        ),
                        "cbo_iceberg2d": lambda: engine.spmd_step_cand(
                            post2d_cbo_iceberg, merge_blocks_cbo,
                            with_supports=True, n_cand=3, n_post_rep=2,
                        ),
                    },
                }
                # backend="kernel": route every step variant above (except the
                # single-intent ganter walks, whose map already runs the Pallas
                # closure kernel and whose argmax-select has no batch filter to
                # fuse) to the fused Pallas kernels — closure → support → driver
                # filter in one VMEM-resident pass (repro.kernels.frontier).
                # Same names, same call signatures, bit-identical outputs; the
                # jnp builders above remain the oracles the kernels are
                # property-tested against (tests/test_fused_frontier.py).
                if fkern.supports_fused(engine.backend, engine.ctx.W):
                    LOWt = t.LOW
                    fused = {
                        v: (lambda v=v: engine.spmd_step_fused(v, LOWt))
                        for v in fkern.VARIANTS
                    }
                    merges = {
                        "plain": merge_blocks_plain,
                        "unique": merge_blocks_unique,
                        "iceberg": merge_blocks_compact,
                        "iceberg_unique": merge_blocks_unique,
                        "cbo": merge_blocks_cbo,
                        "cbo_iceberg": merge_blocks_cbo,
                    }
                    for v, mg in merges.items():
                        fused[v + "2d"] = (
                            lambda v=v, mg=mg: engine.spmd_step_cand_fused(
                                v, LOWt, mg
                            )
                        )
                    cache["builders"].update(fused)
                engine._frontier_cache = cache
        self._cache = cache
        self.LOW = cache["LOW"]
        self.BIT = cache["BIT"]

    def _step_fn(self, name: str):
        """Fused SPMD step ``name``, built on first use and memoized on the
        engine (shared by every DeviceFrontier of that engine).

        Double-checked under the engine's ``_frontier_lock``: the steps
        dict is shared by every frontier of the engine, including ones
        driven from the admission dispatcher thread, and a concurrent
        first-miss must not build (and jit) the same step twice."""
        steps = self._cache["steps"]
        fn = steps.get(name)
        if fn is None:
            with self.engine._frontier_lock:
                fn = steps.get(name)
                if fn is None:
                    fn = steps[name] = self._cache["builders"][name]()
        return fn

    # -- frontier state ----------------------------------------------------

    def __len__(self) -> int:
        if self._n is None:
            raise RuntimeError(
                "frontier count is speculative — reconcile the in-flight "
                "round before asking for len()"
            )
        return self._n

    def set_frontier(self, intents: np.ndarray, gens: np.ndarray | None = None):
        """Upload a new frontier (one bulk H2D — the Twister dynamic delta)."""
        n = intents.shape[0]
        cap = bucket_size(max(1, n))
        buf = np.zeros((cap, self.W), np.uint32)
        buf[:n] = intents
        self._frontier = self.plan.replicate(buf)
        st = self.engine.stats
        st.h2d_transfers += 1
        st.h2d_bytes += buf.nbytes
        if gens is not None:
            gbuf = np.zeros((cap,), np.int32)
            gbuf[:n] = gens
            self._gens = self.plan.replicate(gbuf)
            st.h2d_transfers += 1
            st.h2d_bytes += gbuf.nbytes
        self._n = n
        self._n_dev = None
        # NOT a _k_hint update: frontier row count is a poor estimate of
        # the next round's survivor count (root uploads are 1 row, round-1
        # survivors up to n_attrs) — and with _n known the next spec's cap
        # is already exact, so an untruncated slot costs nothing extra.

    def _adopt(self, frontier_dev, gens_dev, n: int):
        """Keep device survivors as the next frontier (no host round-trip).

        ``slice_pad`` here only ever *grows* the buffer to the next bucket:
        the guard makes dropping live rows a loud error instead of a silent
        truncation.  Frontier size itself is unbounded — per-round device
        footprint is bounded by the chunk loops (``max_batch`` per chunk,
        × ``cand_parts`` blocks on a 2-D plan), never by this buffer.
        """
        if n > frontier_dev.shape[0]:
            raise RuntimeError(
                f"_adopt: {n} surviving frontier rows but only "
                f"{frontier_dev.shape[0]} device rows were materialized — "
                "adopting would silently drop concepts.  Raise max_batch or "
                "shard the frontier axis (ShardPlan cand_parts / "
                "--cand-shards)."
            )
        cap = bucket_size(max(1, n))
        self._frontier = slice_pad(frontier_dev, 0, cap)
        self._gens = None if gens_dev is None else slice_pad(gens_dev, 0, cap)
        self._n = n
        self._n_dev = None
        self._k_hint = max(1, n)

    def _download(self, arr_dev, n: int) -> np.ndarray:
        st = self.engine.stats
        t0 = time.perf_counter()
        out = np.asarray(arr_dev[:n])
        st.host_blocked_s += time.perf_counter() - t0
        st.d2h_transfers += 1
        st.d2h_bytes += out.nbytes
        return out

    def _block_scalar(self, x_dev) -> int:
        """Host-blocking scalar readback, ledgered as such: a 4-byte D2H
        transfer plus the wall time the host spent waiting on it (the
        per-round coordination cost async rounds exist to remove)."""
        st = self.engine.stats
        t0 = time.perf_counter()
        v = int(x_dev)
        st.host_blocked_s += time.perf_counter() - t0
        st.d2h_transfers += 1
        st.d2h_bytes += 4
        return v

    # -- chunk geometry ----------------------------------------------------

    @property
    def cand_parts(self) -> int:
        return self.plan.cand_parts

    @property
    def round_budget(self) -> int:
        """Candidates one closure round absorbs.  The driver picks chunking
        vs candidate-sharding from plan geometry: a 1-D plan chunks the
        stream at ``max_batch``; a cand-sharded plan runs ``cand_parts``
        blocks of up to ``max_batch`` each in ONE round, so the per-round
        budget multiplies while each device's block stays bounded."""
        return self.engine.max_batch * self.cand_parts

    def _block_cap(self, b: int) -> int:
        """Bucketed per-block capacity for a chunk of ``b`` candidates
        spread over the plan's candidate blocks."""
        return bucket_size(
            -(-b // self.cand_parts), minimum=self.engine.min_bucket
        )

    def _next_seq(self) -> int:
        """Monotone round sequence number — span index + async track id."""
        s = self._seq
        self._seq = s + 1
        return s

    # -- fused per-iteration steps ----------------------------------------

    def step_oplus(
        self, *, dedupe: bool, min_support: int | None = None
    ) -> np.ndarray:
        """One MRGanter+ iteration: expand → local prune → close → collect.

        Returns the round's closure intents (host array; de-duplicated on
        device when ``dedupe_closures``); the caller runs the global-
        registry novelty check and hands the novel rows back via
        :meth:`set_frontier`.  ``dedupe=True`` prunes duplicate seeds on
        the partition *before* the reduce is sized, so they never enter
        the AND-allreduce.  With ``min_support``, infrequent closures are
        compacted away right after the support psum, inside the same SPMD
        region — they never cross the device→host boundary and (because
        the caller re-expands only what it receives) never size a later
        round's reduce.
        """
        tr = obs.current()
        seq = self._next_seq()
        t_round = time.perf_counter()
        with tr.span(
            f"mine/round[{seq}]", algo="oplus", mode="sync", **self._tags
        ) as sp:
            with tr.span(f"mine/round[{seq}]/expand"):
                t0 = time.perf_counter()
                seeds, n_dev = expand_oplus(
                    self._frontier, jnp.int32(self._n), self.LOW, self.BIT,
                    n_attrs=self.n_attrs, dedupe=dedupe,
                )
                self.engine.stats.dispatch_s += time.perf_counter() - t0
                # scalar sync — sizes the reduce to the prune
                n_seeds = self._block_scalar(n_dev)
            if n_seeds == 0:
                return np.zeros((0, self.W), np.uint32)
            self._seed_hint = n_seeds
            out = np.concatenate(
                self._oplus_chunks(
                    seeds, n_seeds, 0, min_support=min_support, first=True,
                    seq=seq,
                ),
                axis=0,
            )
            sp.set(n_seeds=n_seeds, survivors=int(out.shape[0]))
        self.engine.stats.observe_latency(
            "round", time.perf_counter() - t_round
        )
        return out

    def _charge(self, two_d: bool, blk: int, cap: int, b: int, count: bool):
        if two_d:
            self.engine.charge_round_cand(blk, b, count_round=count)
        else:
            self.engine.charge_round(cap, b, count_round=count)

    def _chunk_caps(self, b: int) -> tuple[int, int]:
        """(padded chunk capacity, per-block capacity) for ``b`` seeds."""
        if self.cand_parts > 1:
            blk = self._block_cap(b)
            return blk * self.cand_parts, blk
        cap = bucket_size(b, minimum=self.engine.min_bucket)
        return cap, cap

    def _oplus_chunks(
        self,
        seeds,
        n_seeds: int,
        lo0: int,
        *,
        min_support: int | None,
        first: bool,
        force_unique: bool = False,
        seq: int = -1,
    ) -> list[np.ndarray]:
        """Close seeds ``[lo0, n_seeds)`` in round_budget chunks, one fused
        SPMD dispatch each, downloading every chunk's survivors.  Shared by
        the sync step and the async under-coverage fallback (every filter
        is row-wise, so chunk boundaries never change the surviving rows —
        only how many dispatches produce them)."""
        eng = self.engine
        tr = obs.current()
        pfx = f"mine/round[{seq}]"
        two_d = self.cand_parts > 1
        unique = self.dedupe_closures or force_unique
        parts = []
        for lo in range(lo0, n_seeds, self.round_budget):
            b = min(self.round_budget, n_seeds - lo)
            cap, blk = self._chunk_caps(b)
            chunk = slice_pad(seeds, lo, cap)
            t0 = time.perf_counter()
            if min_support is not None:
                name = "iceberg_unique" if unique else "iceberg"
                if two_d:
                    name += "2d"
                with tr.span(pfx + "/dispatch", chunk=b, cap=cap):
                    cl, k_dev = self._step_fn(name)(
                        eng.rows, chunk, jnp.int32(b), jnp.int32(min_support)
                    )
                    eng.stats.dispatch_s += time.perf_counter() - t0
                self._charge(two_d, blk, cap, b, first)
                with tr.span(pfx + "/allreduce"):
                    k = self._block_scalar(k_dev)
                with tr.span(pfx + "/filter", survivors=k):
                    parts.append(self._download(cl, k))
            elif unique:
                with tr.span(pfx + "/dispatch", chunk=b, cap=cap):
                    cl_u, k_dev = self._step_fn(
                        "unique2d" if two_d else "unique"
                    )(eng.rows, chunk, jnp.int32(b))
                    eng.stats.dispatch_s += time.perf_counter() - t0
                self._charge(two_d, blk, cap, b, first)
                with tr.span(pfx + "/allreduce"):
                    k = self._block_scalar(k_dev)
                with tr.span(pfx + "/filter", survivors=k):
                    parts.append(self._download(cl_u, k))
            else:
                with tr.span(pfx + "/dispatch", chunk=b, cap=cap):
                    closures = self._step_fn("plain2d" if two_d else "plain")(
                        eng.rows, chunk
                    )
                    eng.stats.dispatch_s += time.perf_counter() - t0
                self._charge(two_d, blk, cap, b, first)
                with tr.span(pfx + "/filter", survivors=b):
                    parts.append(self._download(closures, b))
            first = False
        return parts

    def step_cbo(
        self, *, min_support: int | None = None
    ) -> tuple[np.ndarray, int, int]:
        """One MRCbo iteration: expand → close+canonicity (fused) → adopt.

        The canonicity filter runs inside the same SPMD region as the
        closure map and reduce; canonical survivors stay on device as the
        next frontier and the same rows are downloaded once for the result
        set.  With ``min_support`` the support filter fuses into the same
        region (CbO intents only grow along the tree, so every frequent
        concept's canonical ancestors are frequent — pruning is lossless).
        Returns ``(new_intents, n_seeds, n_new)`` — ``n_seeds`` is 0
        when the frontier was already exhausted (no closure round ran).
        """
        tr = obs.current()
        seq = self._next_seq()
        t_round = time.perf_counter()
        with tr.span(
            f"mine/round[{seq}]", algo="cbo", mode="sync", **self._tags
        ) as sp:
            with tr.span(f"mine/round[{seq}]/expand"):
                t0 = time.perf_counter()
                seeds, parents, gen, n_dev = expand_cbo(
                    self._frontier, self._gens, jnp.int32(self._n), self.BIT,
                    n_attrs=self.n_attrs,
                )
                self.engine.stats.dispatch_s += time.perf_counter() - t0
                n_seeds = self._block_scalar(n_dev)
            if n_seeds == 0:
                self._n = 0
                return np.zeros((0, self.W), np.uint32), 0, 0
            self._seed_hint = n_seeds
            surv_z, surv_g, counts = self._cbo_chunks(
                seeds, parents, gen, n_seeds, 0,
                min_support=min_support, first=True, seq=seq,
            )
            n_new = sum(counts)
            sp.set(n_seeds=n_seeds, survivors=n_new)
            if n_new == 0:
                self._n = 0
                self.engine.stats.observe_latency(
                    "round", time.perf_counter() - t_round
                )
                return np.zeros((0, self.W), np.uint32), n_seeds, 0
            z_all = surv_z[0] if len(surv_z) == 1 else jnp.concatenate(surv_z)
            g_all = surv_g[0] if len(surv_g) == 1 else jnp.concatenate(surv_g)
            self._adopt(z_all, g_all, n_new)
            with tr.span(f"mine/round[{seq}]/filter", survivors=n_new):
                out = self._download(self._frontier, n_new)
        self.engine.stats.observe_latency(
            "round", time.perf_counter() - t_round
        )
        return out, n_seeds, n_new

    def _cbo_chunks(
        self,
        seeds,
        parents,
        gen,
        n_seeds: int,
        lo0: int,
        *,
        min_support: int | None,
        first: bool,
        seq: int = -1,
    ) -> tuple[list, list, list]:
        """Close+canonicity for CbO seeds ``[lo0, n_seeds)`` in
        round_budget chunks.  Returns device survivor buffers
        ``(z_list, g_list, k_list)`` — callers adopt/concatenate.  Shared
        by the sync step and the async under-coverage fallback (canonicity
        is row-wise, so chunk boundaries never change the survivors)."""
        eng = self.engine
        tr = obs.current()
        pfx = f"mine/round[{seq}]"
        two_d = self.cand_parts > 1
        surv_z, surv_g, counts = [], [], []
        for lo in range(lo0, n_seeds, self.round_budget):
            b = min(self.round_budget, n_seeds - lo)
            cap, blk = self._chunk_caps(b)
            args = (
                eng.rows,
                slice_pad(seeds, lo, cap),
                slice_pad(parents, lo, cap),
                slice_pad(gen, lo, cap),
                jnp.int32(b),
            )
            t0 = time.perf_counter()
            with tr.span(pfx + "/dispatch", chunk=b, cap=cap):
                if min_support is not None:
                    name = "cbo_iceberg2d" if two_d else "cbo_iceberg"
                    z, g, k_dev = self._step_fn(name)(
                        *args, jnp.int32(min_support)
                    )
                else:
                    z, g, k_dev = self._step_fn(
                        "cbo2d" if two_d else "cbo"
                    )(*args)
                eng.stats.dispatch_s += time.perf_counter() - t0
            self._charge(two_d, blk, cap, b, first)
            first = False
            with tr.span(pfx + "/allreduce"):
                k = self._block_scalar(k_dev)
            if k:
                surv_z.append(z[:k])
                surv_g.append(g[:k])
                counts.append(k)
        return surv_z, surv_g, counts

    def step_ganter(
        self, *, min_support: int | None = None
    ) -> tuple[np.ndarray, bool]:
        """One MRGanter iteration: ⊕-seeds for the single current intent,
        then one fused SPMD region: closure map → AND-allreduce → Alg.-5
        feasibility scan → argmax-select.  Returns ``(next intent (host),
        reached ⊤)``.

        With ``min_support`` the scan restricts to frequent successors
        (support psum ≥ threshold, fused in-region) and the flag flips to
        "no frequent successor exists" — when True, the returned intent is
        garbage the caller must NOT emit (the full-lattice contract emits
        ⊤ and reports done in the same step; the iceberg walk only learns
        it is done from an empty scan).

        Always runs the 1-D step, even on a cand-sharded plan: the MRGanter
        frontier is a single intent whose ≤ n_attrs seeds fit any block
        budget, and the Alg.-5 argmax-select needs every seed's closure in
        one place anyway (a cand split would immediately re-gather).  The
        1-D region is candidate-axis-invariant, so on a 2-D mesh it simply
        replicates over the cand axis."""
        eng = self.engine
        tr = obs.current()
        seq = self._next_seq()
        t_round = time.perf_counter()
        with tr.span(
            f"mine/round[{seq}]", algo="ganter", mode="sync", **self._tags
        ):
            with tr.span(f"mine/round[{seq}]/dispatch"):
                Y_next, done, nv_dev, cap = self._dispatch_ganter(min_support)
            with tr.span(f"mine/round[{seq}]/allreduce"):
                eng.charge_round(cap, self._block_scalar(nv_dev))
            with tr.span(f"mine/round[{seq}]/filter"):
                Y = self._download(Y_next[None, :], 1)[0]
                flag = bool(self._block_scalar(done))
        eng.stats.observe_latency("round", time.perf_counter() - t_round)
        return Y, flag

    def _dispatch_ganter(self, min_support):
        """Enqueue one Alg.-5 step (no host sync): seed expansion, the
        fused closure→select region, and the on-device frontier swap.
        Returns ``(Y_next, done, n_valid_seeds, cap)`` — all device."""
        eng = self.engine
        t0 = time.perf_counter()
        Y = self._frontier[0]
        seeds, valid = lectic.oplus_seeds_jnp(
            Y[None, :], self.LOW, self.BIT, self.n_attrs
        )
        seeds = seeds.reshape(self.n_attrs, self.W)
        cap = bucket_size(self.n_attrs, minimum=eng.min_bucket)
        if min_support is not None:
            Y_next, done = self._step_fn("ganter_iceberg")(
                eng.rows, slice_pad(seeds, 0, cap), Y, valid[0],
                jnp.int32(min_support),
            )
        else:
            Y_next, done = self._step_fn("ganter")(
                eng.rows, slice_pad(seeds, 0, cap), Y, valid[0]
            )
        cap_f = self._frontier.shape[0]
        self._frontier = jnp.broadcast_to(Y_next, (cap_f, self.W))
        self._n = 1
        eng.stats.dispatch_s += time.perf_counter() - t0
        return Y_next, done, valid[0].sum(dtype=jnp.int32), cap

    # -- speculative rounds (async scheduler) ------------------------------
    #
    # The async drivers dispatch round r+1's expansion against round r's
    # *unreconciled* survivor buffer: every step function already takes the
    # valid count as a traced operand, so the whole chain — expand → close
    # → filter → adopt — runs on device scalars and the host never blocks
    # between rounds.  The one D2H per round is a packed buffer (counts ++
    # survivors, ``_pack_round``) whose copy starts at dispatch time;
    # ``reconcile_*`` waits on it only when the driver needs round r's
    # result, by which time round r+1 is already in flight.
    #
    # Speculation is capped at ``round_budget``: the spec chunk covers
    # min(expansion bound, round_budget) seeds (bucket-padded, so coverage
    # can exceed the budget for free).  Reconciliation compares the true
    # seed count against that coverage — over-expanded rows were already
    # masked out by the traced valid count (reconcile-on-adopt: nothing
    # re-runs), and only genuine *under*-coverage falls back to synchronous
    # re-dispatch of the uncovered tail through the shared chunk runners.
    # Stats are charged at reconcile time, when true counts are known, so
    # the ledger matches the sync path and discarded speculative rounds
    # are never charged.

    def _n_arg(self):
        """The frontier's valid count as a step operand — the host int when
        reconciled, the device scalar when speculative (never a readback)."""
        return self._n_dev if self._n is None else jnp.int32(self._n)

    def _adopt_spec(self, frontier_dev, gens_dev, k_dev):
        """Adopt a speculative survivor buffer whose count is still device-
        resident.  The buffer is pre-sliced to ``_slot_rows`` — smaller
        than the chunk cap — so ``_adopt``'s refuse-to-drop guard cannot
        run here; reconciliation performs the equivalent check against the
        true count (``k > spec.slot``) once the packed buffer lands."""
        self._frontier = frontier_dev
        self._gens = gens_dev
        self._n = None
        self._n_dev = k_dev

    def _spec_caps(self, bound: int) -> tuple[int, int]:
        """Speculative chunk coverage: min(expansion bound, round_budget),
        bucket-padded.  Returns ``(cap, blk)`` like :meth:`_chunk_caps`.

        The structural bound (slot rows × n_attrs) wildly over-states the
        post-dedupe seed count, and a speculative round pays compute for
        its whole padded cap — while an under-covered round only re-runs
        the *uncovered tail* through the sync chunk runner (the covered
        part's closures are kept).  Over-sizing is therefore the
        expensive miss, so when a reconciled round has told us the true
        count the chunk is sized at 2× that hint (growth allowance); a
        growth spurt past it under-covers and falls back.  Sizing is a
        pure latency heuristic, never a correctness input."""
        if self._seed_hint is not None:
            bound = min(
                bound, max(self.engine.min_bucket, 2 * self._seed_hint)
            )
        return self._chunk_caps(max(1, min(bound, self.round_budget)))

    def _spec_bound(self) -> int:
        """Structural expansion bound for the next speculative chunk: the
        reconciled row count when the host knows it (first spec of a run,
        or right after an under-coverage re-adoption), the padded slot
        capacity when the count is still in flight."""
        rows = self._n if self._n is not None else self._frontier.shape[0]
        return max(1, rows) * self.n_attrs

    def _slot_rows(self, cap: int) -> int:
        """Rows the adopted speculative slot keeps.  The slot is the NEXT
        round's expansion input, and expansion cost (the dedupe sort in
        particular) scales with slot rows × n_attrs — keeping the whole
        cap-row chunk buffer makes every speculative expansion pay for the
        chunk's padding.  The in-flight survivor count is unknown at
        dispatch, so the slot is sized from the last reconciled survivor
        count with a 2× growth allowance.  A growth spurt past the slot
        truncates live in-flight rows — reconciliation detects that
        (``k > spec.slot``) from the *full* packed buffer and recovers
        through the driver's ordinary under-coverage reset, so sizing
        stays a latency heuristic, never a correctness input."""
        if self._k_hint is None:
            return cap
        rows = bucket_size(
            max(self.engine.min_bucket, 2 * self._k_hint),
            minimum=self.engine.min_bucket,
        )
        return min(cap, rows)

    def discard_spec(self, spec: SpecRound | None) -> None:
        """Drop a speculative round whose premise turned out wrong (the
        true frontier emptied, or under-coverage invalidated its input).
        Nothing to undo, and the round's *modeled* cost is never ledgered
        (spec rounds charge collectives at reconciliation only) — but the
        packed readback's copy has been in flight since dispatch, so those
        bytes crossed the boundary whether or not anyone reads them and
        the transfer census charges them here (sync-vs-async census parity
        is asserted in tests/test_obs.py)."""
        if spec is not None:
            st = self.engine.stats
            st.spec_discarded += 1
            st.d2h_transfers += 1
            st.d2h_bytes += int(spec.packed.size) * 4
            tr = obs.current()
            tr.instant(f"spec/discard[{spec.seq}]")
            tr.end_async(f"mine/round[{spec.seq}]", spec.seq, outcome="discard")

    def _download_packed(self, packed) -> np.ndarray:
        """The reconcile's ONE host-blocking wait: the packed round buffer
        (copy already in flight since dispatch)."""
        st = self.engine.stats
        t0 = time.perf_counter()
        out = np.asarray(packed)
        st.host_blocked_s += time.perf_counter() - t0
        st.d2h_transfers += 1
        st.d2h_bytes += out.nbytes
        return out

    def spec_oplus(
        self, *, dedupe: bool, min_support: int | None = None
    ) -> SpecRound:
        """Dispatch one speculative MRGanter+ round (no host sync).

        Always routes through the *unique* step variants regardless of
        ``dedupe_closures``: the adopted spec slot doubles as the next
        round's expansion input, and deduping it on device bounds the
        stale-row re-expansion (the host registry still owns novelty).
        """
        eng = self.engine
        tr = obs.current()
        seq = self._next_seq()
        t0 = time.perf_counter()
        tr.begin_async(
            f"mine/round[{seq}]", seq, algo="oplus", mode="async", **self._tags
        )
        with tr.span(f"spec/dispatch[{seq}]"):
            seeds, n_dev = expand_oplus(
                self._frontier, self._n_arg(), self.LOW, self.BIT,
                n_attrs=self.n_attrs, dedupe=dedupe,
            )
            cap, blk = self._spec_caps(self._spec_bound())
            chunk = slice_pad(seeds, 0, cap)
            nv = jnp.minimum(n_dev, jnp.int32(cap))
            two_d = self.cand_parts > 1
            if min_support is not None:
                name = "iceberg_unique2d" if two_d else "iceberg_unique"
                cl, k_dev = self._step_fn(name)(
                    eng.rows, chunk, nv, jnp.int32(min_support)
                )
            else:
                cl, k_dev = self._step_fn("unique2d" if two_d else "unique")(
                    eng.rows, chunk, nv
                )
            slot = self._slot_rows(cap)
            self._adopt_spec(
                cl if slot == cap else slice_pad(cl, 0, slot), None, k_dev
            )
            packed = _pack_round(n_dev, k_dev, cl)  # full buffer: recovery
            _start_d2h(packed)
            eng.stats.dispatch_s += time.perf_counter() - t0
            eng.stats.spec_rounds += 1
        return SpecRound(
            "oplus", packed, cap, blk, two_d, seeds=seeds, slot=slot,
            seq=seq, t_dispatch=t0,
        )

    def reconcile_oplus(
        self, spec: SpecRound, *, min_support: int | None = None
    ) -> OplusRound:
        """Adopt round r's true counts: read the packed buffer, charge the
        round at its real size, and — only if the speculative chunk under-
        covered the true seed count — close the uncovered tail through the
        sync chunk runner."""
        tr = obs.current()
        with tr.span(f"spec/reconcile[{spec.seq}]") as sp:
            rec = self._reconcile_oplus(spec, min_support=min_support)
            outcome = "fallback" if rec.under_covered else "adopt"
            sp.set(outcome=outcome, n_seeds=rec.n_seeds)
        tr.end_async(f"mine/round[{spec.seq}]", spec.seq, outcome=outcome)
        self.engine.stats.observe_latency(
            "round", time.perf_counter() - spec.t_dispatch
        )
        return rec

    def _reconcile_oplus(
        self, spec: SpecRound, *, min_support: int | None = None
    ) -> OplusRound:
        eng = self.engine
        host = self._download_packed(spec.packed)
        n_seeds = int(host[0])
        k = int(host[1])
        if n_seeds == 0:
            # parity with sync: no closure round ran, nothing is charged
            return OplusRound(0, np.zeros((0, self.W), np.uint32), False)
        self._seed_hint = n_seeds
        self._charge(spec.two_d, spec.blk, spec.cap, min(n_seeds, spec.cap), True)
        closures = host[2:].reshape(spec.cap, self.W)
        if n_seeds <= spec.cap:
            self._k_hint = max(1, k)
            new = np.ascontiguousarray(closures[:k])
            if k > spec.slot:
                # the adopted slot truncated the in-flight survivors, so
                # the round already speculating on it chained on a partial
                # frontier.  The packed buffer holds the full survivor set
                # — recovery is the driver's ordinary under-coverage reset
                # (discard + set_frontier + re-spec), no recompute here.
                eng.stats.spec_fallbacks += 1
                return OplusRound(n_seeds, new, True)
            return OplusRound(n_seeds, new, False)
        eng.stats.spec_fallbacks += 1
        parts = [np.ascontiguousarray(closures[:k])]
        parts += self._oplus_chunks(
            spec.seeds, n_seeds, spec.cap,
            min_support=min_support, first=False, force_unique=True,
            seq=spec.seq,
        )
        out = np.concatenate(parts, axis=0)
        self._k_hint = max(1, out.shape[0])
        return OplusRound(n_seeds, out, True)

    def spec_cbo(self, *, min_support: int | None = None) -> SpecRound:
        """Dispatch one speculative MRCbo round (no host sync).  Canonical
        survivors are adopted as the next frontier with their count still
        on device — exactly the sync contract, minus the readbacks."""
        eng = self.engine
        tr = obs.current()
        seq = self._next_seq()
        t0 = time.perf_counter()
        tr.begin_async(
            f"mine/round[{seq}]", seq, algo="cbo", mode="async", **self._tags
        )
        with tr.span(f"spec/dispatch[{seq}]"):
            seeds, parents, gen, n_dev = expand_cbo(
                self._frontier, self._gens, self._n_arg(), self.BIT,
                n_attrs=self.n_attrs,
            )
            cap, blk = self._spec_caps(self._spec_bound())
            nv = jnp.minimum(n_dev, jnp.int32(cap))
            two_d = self.cand_parts > 1
            args = (
                eng.rows,
                slice_pad(seeds, 0, cap),
                slice_pad(parents, 0, cap),
                slice_pad(gen, 0, cap),
                nv,
            )
            if min_support is not None:
                z, g, k_dev = self._step_fn(
                    "cbo_iceberg2d" if two_d else "cbo_iceberg"
                )(*args, jnp.int32(min_support))
            else:
                z, g, k_dev = self._step_fn("cbo2d" if two_d else "cbo")(*args)
            slot = self._slot_rows(cap)
            if slot == cap:
                self._adopt_spec(z, g, k_dev)
            else:
                self._adopt_spec(
                    slice_pad(z, 0, slot), slice_pad(g, 0, slot), k_dev
                )
            packed = _pack_round(n_dev, k_dev, z)  # full buffer: recovery
            _start_d2h(packed)
            eng.stats.dispatch_s += time.perf_counter() - t0
            eng.stats.spec_rounds += 1
        return SpecRound(
            "cbo", packed, cap, blk, two_d, seeds=seeds, parents=parents,
            gen=gen, surv_z=z, surv_g=g, slot=slot, seq=seq, t_dispatch=t0,
        )

    def reconcile_cbo(
        self, spec: SpecRound, *, min_support: int | None = None
    ) -> CboRound:
        """Adopt round r's true counts.  When covered, the speculatively
        adopted slot already IS the true frontier (over-expanded rows were
        masked by the traced valid count) and the survivors come straight
        from the packed buffer.  Under-coverage closes the uncovered tail
        synchronously and re-adopts the full survivor set — restoring
        exactness before the driver re-speculates."""
        tr = obs.current()
        with tr.span(f"spec/reconcile[{spec.seq}]") as sp:
            rec = self._reconcile_cbo(spec, min_support=min_support)
            outcome = "fallback" if rec.under_covered else "adopt"
            sp.set(outcome=outcome, n_seeds=rec.n_seeds)
        tr.end_async(f"mine/round[{spec.seq}]", spec.seq, outcome=outcome)
        self.engine.stats.observe_latency(
            "round", time.perf_counter() - spec.t_dispatch
        )
        return rec

    def _reconcile_cbo(
        self, spec: SpecRound, *, min_support: int | None = None
    ) -> CboRound:
        eng = self.engine
        host = self._download_packed(spec.packed)
        n_seeds = int(host[0])
        k = int(host[1])
        if n_seeds == 0:
            # parity with sync: exhausted frontier, no round ran/charged
            self._n, self._n_dev = 0, None
            return CboRound(0, np.zeros((0, self.W), np.uint32), 0, False)
        self._seed_hint = n_seeds
        self._charge(spec.two_d, spec.blk, spec.cap, min(n_seeds, spec.cap), True)
        if n_seeds <= spec.cap:
            new = np.ascontiguousarray(host[2:].reshape(spec.cap, self.W)[:k])
            if k == 0:
                self._n, self._n_dev = 0, None
            elif k > spec.slot:
                # slot truncated the in-flight survivors — re-adopt the
                # full survivor buffer (kept in the SpecRound exactly for
                # this) so the frontier is exact before the driver
                # discards the mispremised speculation and re-dispatches.
                eng.stats.spec_fallbacks += 1
                self._adopt(spec.surv_z, spec.surv_g, k)
                return CboRound(n_seeds, new, k, True)
            else:
                self._k_hint = k
            return CboRound(n_seeds, new, k, False)
        eng.stats.spec_fallbacks += 1
        z_list, g_list, counts = self._cbo_chunks(
            spec.seeds, spec.parents, spec.gen, n_seeds, spec.cap,
            min_support=min_support, first=False, seq=spec.seq,
        )
        n_new = k + sum(counts)
        if n_new == 0:
            self._n, self._n_dev = 0, None
            return CboRound(n_seeds, np.zeros((0, self.W), np.uint32), 0, True)
        z_all = jnp.concatenate([spec.surv_z[:k], *z_list])
        g_all = jnp.concatenate([spec.surv_g[:k], *g_list])
        self._adopt(z_all, g_all, n_new)
        return CboRound(
            n_seeds, self._download(self._frontier, n_new), n_new, True
        )

    def spec_ganter(self, *, min_support: int | None = None) -> SpecRound:
        """Dispatch one speculative Alg.-5 step: the fused select's result
        is broadcast into the frontier slot on device, so the next step
        chains on it without the intent ever visiting the host."""
        eng = self.engine
        tr = obs.current()
        seq = self._next_seq()
        t_dispatch = time.perf_counter()
        tr.begin_async(
            f"mine/round[{seq}]", seq, algo="ganter", mode="async",
            **self._tags,
        )
        with tr.span(f"spec/dispatch[{seq}]"):
            Y_next, done, nv_dev, cap = self._dispatch_ganter(min_support)
            t0 = time.perf_counter()
            packed = _pack_round(done, nv_dev, Y_next[None, :])
            _start_d2h(packed)
            eng.stats.dispatch_s += time.perf_counter() - t0
            eng.stats.spec_rounds += 1
        return SpecRound(
            "ganter", packed, cap, cap, False, seq=seq, t_dispatch=t_dispatch
        )

    def reconcile_ganter(self, spec: SpecRound) -> tuple[np.ndarray, bool]:
        """Wait on the packed ``[done/exhausted, n_valid, Y_next]`` buffer
        and charge the round at its true seed count.  Returns
        ``(Y_next, flag)`` with the same contract as :meth:`step_ganter`."""
        tr = obs.current()
        with tr.span(f"spec/reconcile[{spec.seq}]") as sp:
            host = self._download_packed(spec.packed)
            self.engine.charge_round(spec.cap, int(host[1]))
            sp.set(outcome="adopt")
        tr.end_async(f"mine/round[{spec.seq}]", spec.seq, outcome="adopt")
        self.engine.stats.observe_latency(
            "round", time.perf_counter() - spec.t_dispatch
        )
        return host[2:].astype(np.uint32, copy=False), bool(host[0])
