"""Query-serving benchmark: batched SPMD queries vs the per-query host loop,
plus streaming-update commit latency, 1→8 shards (§Query).

Workload: census-income (the largest bundled Table-7 dataset) at the
standard CPU-budget scale; mine once with MRGanter+, build the
ConceptStore, then

  * **throughput grid** — a mixed batch of closure-of-attrset (with fused
    concept lookup) and top-k-by-support queries, answered (a) by the
    QueryEngine in fixed-slot SPMD micro-batches over k ∈ {1, 2, 4, 8}
    simulated shards, and (b) by the per-query host-loop baseline
    (``closure_np`` + python bucket probe + python subset scan per query —
    the pre-subsystem serving story).  Results are asserted bit-identical
    before any timing is reported.
  * **streaming A/B** — one K-object update batch committed through the
    device Godin path (stage + commit wall time) vs remining the grown
    context from scratch with batch NextClosure; intent sets asserted
    equal.

Warm-run protocol throughout: one untimed pass populates the jit caches,
the second pass is measured.  Writes BENCH_query.json; the headline is the
batched-vs-host throughput ratio at k = 1 (the two use the same devices —
shard counts isolate the collective schedule, not extra silicon).
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import row
from repro.core import ClosureEngine, all_closures_batched, bitset, mrganter_plus
from repro.core.closure import closure_np
from repro.core.hashindex import TwoLevelHash
from repro.data import fca_datasets
from repro.dist.shardplan import ShardPlan
from repro.query import ConceptStore, QueryEngine, QueryStats, StreamUpdater
from repro.query.engine import QueryConfig


def _make_queries(ctx, n: int, seed: int) -> np.ndarray:
    """Attrsets that hit populated lattice regions: real rows thinned."""
    rng = np.random.default_rng(seed)
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=n)]
    keep = bitset.pack_bool(rng.random((n, ctx.n_attrs)) < 0.25, ctx.W)
    return base & keep


def _host_index(snap):
    """One-time host index build (outside the timed region, matching the
    engine side's untimed store/jit setup)."""
    index = TwoLevelHash()
    id_of = {}
    for i, y in enumerate(snap.intents_np):
        index.add(y)
        id_of[bitset.key_bytes(y)] = i
    return index, id_of


def _host_baseline(
    ctx, snap, index, id_of, queries: np.ndarray, topk_q: np.ndarray, k: int
):
    """The per-query host loop the subsystem replaces: one ``closure_np``
    per query, a python two-level-hash probe for the lookup, and a python
    subset scan + sort for top-k."""
    mask = ctx.attr_mask()
    closures = np.empty((queries.shape[0], ctx.W), np.uint32)
    supports = np.empty((queries.shape[0],), np.int32)
    ids = np.empty((queries.shape[0],), np.int32)
    for i, q in enumerate(queries):
        c, s = closure_np(ctx.rows, q, mask)
        closures[i] = c
        supports[i] = s
        ids[i] = id_of[bitset.key_bytes(c)] if c in index else -1
    top_ids = np.full((topk_q.shape[0], k), -1, np.int32)
    top_vals = np.full((topk_q.shape[0], k), -1, np.int32)
    for i, q in enumerate(topk_q):
        c, _ = closure_np(ctx.rows, q, mask)
        matches = [
            (int(snap.supports_np[j]), j)
            for j in range(snap.n_concepts)
            if bool(bitset.is_subset(c, snap.intents_np[j]))
        ]
        matches.sort(key=lambda t: (-t[0], t[1]))
        for r, (s, j) in enumerate(matches[:k]):
            top_ids[i, r] = j
            top_vals[i, r] = s
    return closures, supports, ids, top_ids, top_vals


def _timed_engine_pass(qe, queries, topk_q, k, reps: int = 3):
    """Best-of-``reps`` wall time (one warm pass is ~0.15 s — short enough
    that scheduler jitter dominates a single measurement)."""
    out, wall = None, float("inf")
    for _ in range(reps):
        qe.stats = QueryStats()  # stats reflect one pass, not the sum
        t0 = time.perf_counter()
        closures, supports, ids = qe.closure_batch(queries)
        top_ids, top_vals = qe.topk_batch(topk_q, k=k)
        wall = min(wall, time.perf_counter() - t0)
        out = (closures, supports, ids, top_ids, top_vals)
    return out, wall


def run(
    dataset: str = "census-income",
    scale: float = 0.002,
    n_queries: int = 4096,
    n_topk: int = 256,
    k: int = 5,
    slots: int = 1024,
    shard_counts=(1, 2, 4, 8),
    n_update: int = 6,
    out_path: str = "BENCH_query.json",
) -> list[str]:
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)
    plan0 = ShardPlan.simulated(1)
    eng = ClosureEngine(ctx, plan=plan0, backend="jnp")
    res = mrganter_plus(ctx, eng, local_prune=True)
    queries = _make_queries(ctx, n_queries, seed=1)
    topk_q = queries[:n_topk]

    # -- host-loop baseline (per query; the pre-subsystem story) ----------
    store0 = ConceptStore.build(ctx, res.intents, plan=plan0)
    index, id_of = _host_index(store0.snapshot)
    host_wall = float("inf")
    for _ in range(3):  # best-of-3, same protocol as the engine passes
        t0 = time.perf_counter()
        host_out = _host_baseline(
            ctx, store0.snapshot, index, id_of, queries, topk_q, k
        )
        host_wall = min(host_wall, time.perf_counter() - t0)
    n_total = n_queries + n_topk

    # -- SPMD grid: shard count × schedule ---------------------------------
    grid = []
    engine_out = None
    for n_parts in shard_counts:
        for impl in ("allgather", "rsag", "auto"):
            plan = ShardPlan.simulated(n_parts, reduce_impl=impl)
            store = ConceptStore.build(ctx, res.intents, plan=plan)
            qe = QueryEngine(store, QueryConfig(slots=slots, backend="jnp"))
            _timed_engine_pass(qe, queries, topk_q, k, reps=1)  # warm
            out, wall = _timed_engine_pass(qe, queries, topk_q, k)
            if n_parts == 1 and impl == "rsag":
                engine_out = out
            grid.append({
                "n_parts": n_parts,
                "reduce_impl": impl,
                "wall_s": round(wall, 4),
                "queries_per_s": round(n_total / wall, 1),
                "collective_rounds": qe.stats.collective_rounds,
                "reduce_rounds": qe.stats.reduce_rounds,
                "modeled_comm_bytes": qe.stats.modeled_comm_bytes,
                # HDR-histogram micro-batch latency view (last timed pass)
                "latency_percentiles": qe.stats.latency_percentiles,
            })

    # bit-identical acceptance check: SPMD results == host loop
    names = ("closures", "supports", "ids", "top_ids", "top_vals")
    for name, a, b in zip(names, engine_out, host_out):
        if not np.array_equal(a, b):
            raise AssertionError(f"SPMD {name} diverge from host baseline")

    # -- streaming update vs remine ---------------------------------------
    plan = ShardPlan.simulated(1)
    store = ConceptStore.build(ctx, res.intents, plan=plan)
    upd = StreamUpdater(store)
    rng = np.random.default_rng(7)
    new_rows = bitset.pack_bool(
        rng.random((n_update, ctx.n_attrs)) < max(0.05, spec.density), ctx.W
    )
    receipt = upd.stage(new_rows)  # warm (compiles the grow/support steps)
    upd.commit()
    store2 = ConceptStore.build(ctx, res.intents, plan=plan)
    upd2 = StreamUpdater(store2)
    t0 = time.perf_counter()
    receipt = upd2.stage(new_rows)
    upd2.commit()
    commit_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    remine = all_closures_batched(store2.ctx)
    remine_wall = time.perf_counter() - t0
    same = {bitset.key_bytes(y) for y in remine} == {
        bitset.key_bytes(y) for y in store2.snapshot.intents_np
    }
    if not same:
        raise AssertionError("streamed lattice diverges from batch remine")

    base_qps = n_total / host_wall
    batched = next(
        g for g in grid if g["n_parts"] == 1 and g["reduce_impl"] == "rsag"
    )
    payload = {
        "dataset": dataclasses.asdict(spec),
        "concepts": res.n_concepts,
        "workload": {
            "closure_queries": n_queries,
            "topk_queries": n_topk,
            "k": k,
            "slots": slots,
        },
        "host_baseline": {
            "wall_s": round(host_wall, 4),
            "queries_per_s": round(base_qps, 1),
        },
        "spmd_grid": grid,
        "update": {
            "n_new_objects": n_update,
            "stage_commit_s": round(commit_wall, 4),
            "remine_s": round(remine_wall, 4),
            "speedup_vs_remine": round(remine_wall / commit_wall, 2),
            "concepts_after": receipt.n_concepts_after,
            "matches_remine": same,
        },
        "headline": {
            "batched_queries_per_s": batched["queries_per_s"],
            "host_queries_per_s": round(base_qps, 1),
            "throughput_ratio": round(batched["queries_per_s"] / base_qps, 1),
            "micro_batch_latency": batched["latency_percentiles"].get(
                "micro_batch", {}
            ),
            "bit_identical": True,
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = [
        row("query/host_baseline", 1e6 * host_wall,
            f"qps={payload['host_baseline']['queries_per_s']}"),
    ]
    for g in grid:
        out.append(row(
            f"query/spmd/{g['reduce_impl']}/k={g['n_parts']}",
            1e6 * g["wall_s"],
            f"qps={g['queries_per_s']}|rounds={g['collective_rounds']}",
        ))
    out.append(row(
        "query/update_commit", 1e6 * commit_wall,
        f"remine_speedup={payload['update']['speedup_vs_remine']}"
        f"|concepts={receipt.n_concepts_after}",
    ))
    out.append(row(
        "query/headline_throughput_ratio",
        payload["headline"]["throughput_ratio"],
        f"batched_vs_host_qps|json={out_path}",
    ))
    return out
