"""Known-bad linter fixture — every lint rule must trip in this file.

Analyzed by path only (never imported).  The self-test in
``tests/test_analysis.py`` injects this file into the linter's async and
clock scopes and asserts one finding per seeded defect, so a rule that
silently stops firing fails the suite.
"""

import time

import numpy as np

import jax


def rounds_async(frontier, xs):
    out = []
    for x in xs:
        host = np.asarray(x)  # host-sync: d2h inside the async round loop
        x.block_until_ready()  # host-sync: attribute form
        out.append(jax.device_get(host))  # host-sync: call form
    return out


def dispatch(t0):
    return time.monotonic() - t0  # wall-clock read in clock-injected code


def accumulate(x, acc=[]):  # mutable-default shared across calls
    acc.append(x)
    return acc


def compile_per_item(fns, xs):
    out = []
    for fn, x in zip(fns, xs):
        out.append(jax.jit(fn)(x))  # jit-in-loop: recompiles every pass
    return out


def swallow(fn):
    try:
        return fn()
    except:  # bare-except: eats KeyboardInterrupt and device failures
        return None
