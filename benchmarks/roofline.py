"""§Roofline — derive the three roofline terms from dry-run records.

    compute term    = flops_per_device / PEAK_FLOPS
    memory term     = hbm_bytes_per_device / HBM_BW
    collective term = collective_bytes_per_device / ICI_BW

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
FLOPs/bytes come from the while-aware HLO analyzer (repro.launch.hlo_analysis);
``model_flops`` is the analytic 6·N·D (train) / 2·N·D (inference) with
N = active params.  See EXPERIMENTS.md for conventions and caveats.

The FCA closure kernels are *bitwise VPU* work — zero MXU FLOPs — so an
MXU-only model prices them at 0% of roofline no matter how good they are.
``PEAK_VPU_OPS`` adds the integer/bitwise term: v5e's VPU is an (8, 128)
lane grid with 4 independent ALU slots per lane at ~940 MHz, ≈ 3.85e12
32-bit word-ops/s/chip.  ``closure_path_terms`` prices one frontier
closure round (closure → support → driver filter) under that peak for the
fused single-pass Pallas path vs the unfused op chain, whose stage
boundaries re-stream the [B, W] closure block through HBM.  Reported per
path as ``achieved_fraction`` — the fraction of the binding resource's
roofline the path sustains — in BENCH_frontier.json (§Roofline table in
EXPERIMENTS.md).
"""

from __future__ import annotations

import json

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
# v5e VPU integer peak: 8·128 lanes × 4 ALU slots × ~0.94 GHz ≈ 3.85e12
# 32-bit word-ops/s.  Documented assumption, not a measured number — see
# EXPERIMENTS §Roofline.
PEAK_VPU_OPS = 3.85e12

TERMS = ("compute", "memory", "collective")


def closure_path_terms(
    B: int, N: int, W: int, *, path: str = "fused"
) -> dict:
    """VPU-aware roofline terms for ONE closure round of B candidates
    against N context rows of W packed words.

    Word-op census (per candidate·row·word): AND + compare for the subset
    test, the select, and the AND-accumulate ≈ 4 ops, plus the match
    reduction (≈ B·N) and the fused filter tail (≈ 3·B·W for mask, pad
    correction, canonicity/iceberg compare).  HBM traffic: both paths
    stream rows + candidates in and closures/supports/keep out; the
    *unfused* op chain additionally round-trips the [B, W] closure block
    at each stage boundary (closure → mask → filter: 3 write+read pairs),
    which is exactly what the fused kernel's VMEM residency deletes.
    """
    if path not in ("fused", "unfused"):
        raise ValueError(f"unknown closure path {path!r}")
    word_ops = 4 * B * N * W + B * N + 3 * B * W
    hbm = (N * W + B * W) * 4  # rows + candidates in
    hbm += B * W * 4 + B * 4 + B * 4  # closures + supports + keep out
    if path == "unfused":
        hbm += 3 * 2 * B * W * 4  # stage-boundary round-trips of [B, W]
    compute_s = word_ops / PEAK_VPU_OPS
    memory_s = hbm / HBM_BW
    bound_s = max(compute_s, memory_s)
    return {
        "path": path,
        "word_ops": word_ops,
        "hbm_bytes": hbm,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "dominant": "compute" if compute_s >= memory_s else "memory",
        # useful-compute time over binding-resource time: 1.0 when the VPU
        # is the bound, < 1 when HBM streaming caps the achievable rate
        "achieved_fraction": compute_s / bound_s if bound_s > 0 else 0.0,
    }


def roofline_terms(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return dict(rec)
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["hbm_bytes_per_device"] / HBM_BW
    collective_s = rec["collective_bytes_per_device"] / ICI_BW
    dominant = max(
        zip(TERMS, (compute_s, memory_s, collective_s)), key=lambda kv: kv[1]
    )[0]
    model_flops_dev = rec["model_flops_global"] / max(1, rec["chips"])
    useful_ratio = (
        model_flops_dev / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
    )
    bound_s = max(compute_s, memory_s, collective_s)
    # fraction of roofline: useful work time over the binding resource time
    roofline_fraction = (
        (model_flops_dev / PEAK_FLOPS) / bound_s if bound_s > 0 else 0.0
    )
    return {
        **rec,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_fraction,
    }


def load_records(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def render_table(records: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful/HLO | roofline_frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        r = roofline_terms(rec)
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — |"
            )
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def run(path: str = "dryrun_single.jsonl") -> list[str]:
    try:
        records = load_records(path)
    except FileNotFoundError:
        return [f"roofline/{path},0.0,missing (run python -m repro.launch.dryrun --all)"]
    out = []
    for rec in records:
        r = roofline_terms(rec)
        if r.get("status") != "ok":
            continue
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{1e6 * max(r['compute_s'], r['memory_s'], r['collective_s']):.1f},"
            f"dominant={r['dominant']}|compute_s={r['compute_s']:.4f}"
            f"|memory_s={r['memory_s']:.4f}|collective_s={r['collective_s']:.4f}"
            f"|useful_ratio={r['useful_flops_ratio']:.2f}"
            f"|roofline_frac={r['roofline_fraction']:.3f}"
        )
    return out
