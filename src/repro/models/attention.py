"""GQA attention: blockwise (flash-style) full-sequence path + cached decode.

The full-sequence path scans over KV blocks with an online-softmax carry, so
peak activation memory is O(S·kv_block) per head instead of O(S²) — the
TPU-native equivalent of flash attention expressed in jnp (the scan body is
a natural remat boundary).  Supports: causal masking, sliding windows
(gemma2 local / griffin), logit softcapping (gemma2), RoPE and M-RoPE.

Caches: full caches ``[B, S_max, KV, hd]`` (decode_32k) or ring-buffer
window caches ``[B, window, KV, hd]`` with a per-slot position vector, so
windowed archs decode in O(window) memory at any context length (long_500k).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

NEG_INF = -2.0**30


class KVCache(NamedTuple):
    k: jax.Array  # [B, L, KV, hd]
    v: jax.Array  # [B, L, KV, hd]
    pos: jax.Array  # [B, L] int32 — absolute position per slot (-1 = empty;
    # per-batch so left-padded prompts mask their pads)


def init_attention(pb: layers.ParamBuilder, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": pb.dense((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": pb.dense((d, KV, hd), ("embed", "kv", "head_dim")),
        "wv": pb.dense((d, KV, hd), ("embed", "kv", "head_dim")),
        "wo": pb.dense((H, hd, d), ("heads", "head_dim", "embed"), fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = pb.zeros((H, hd), ("heads", "head_dim"))
        p["bk"] = pb.zeros((KV, hd), ("kv", "head_dim"))
        p["bv"] = pb.zeros((KV, hd), ("kv", "head_dim"))
    return p


def _project_qkv(params, x, cfg: ModelConfig, rope_positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.rope_kind == "standard":
        q = layers.apply_rope(q, rope_positions, cfg.rope_theta)
        k = layers.apply_rope(k, rope_positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = layers.apply_mrope(q, rope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, rope_positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _mask(q_pos, kv_pos, window):
    """q_pos [..., S, 1], kv_pos [..., 1, T] → bool valid mask."""
    valid = (kv_pos <= q_pos) & (kv_pos >= 0)
    if window is not None:
        valid &= q_pos - kv_pos < window
    return valid


def blockwise_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, KV, hd]
    v: jax.Array,  # [B, T, KV, hd]
    q_pos: jax.Array,  # [S] or [B, S] int32 absolute positions
    kv_pos: jax.Array,  # [T] or [B, T] int32 (sentinel < 0 = invalid slot)
    *,
    window: int | None,
    logit_cap: float | None,
    kv_block: int = 1024,
) -> jax.Array:
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    q_pos = jnp.broadcast_to(q_pos, (B, S)) if q_pos.ndim == 1 else q_pos
    kv_pos = jnp.broadcast_to(kv_pos, (B, T)) if kv_pos.ndim == 1 else kv_pos

    pad = -T % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_blocks = (T + pad) // kv_block
    kb = jnp.moveaxis(k.reshape(B, n_blocks, kv_block, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, n_blocks, kv_block, KV, hd), 1, 0)
    pb = jnp.moveaxis(kv_pos.reshape(B, n_blocks, kv_block), 1, 0)

    def step(carry, blk):
        m, l, acc = carry
        k_j, v_j, pos_j = blk
        s = jnp.einsum(
            "bskgh,btkh->bskgt", qg, k_j, preferred_element_type=jnp.float32
        ) * scale
        s = layers.softcap(s, logit_cap)
        valid = _mask(q_pos[:, :, None, None, None], pos_j[:, None, None, None, :], window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        # §Perf: p in bf16 (stabilized by the fp32 running max) — halves the
        # dominant softmax-chain HBM traffic; running stats stay fp32.
        p = jnp.exp(s - m_new[..., None]).astype(v_j.dtype)
        l_new = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bskgt,btkh->bskgh", p, v_j,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), dtype=jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _constrain_q(q: jax.Array, cfg: ModelConfig, shard) -> jax.Array:
    """§Perf: pick the attention parallelism that actually shards.

    Megatron-style heads-TP needs n_heads % tp == 0; several assigned archs
    (56H, 40H, 36H on a 16-way model axis) fail that and GSPMD silently
    *replicates* the whole attention computation per model shard (~16×
    redundant FLOPs + HBM traffic — measured in EXPERIMENTS.md §Perf).
    For those archs we context-parallelize instead: shard q (and thus
    scores/out, by propagation) on the sequence dim over 'model'; k/v stay
    per-data-shard so the blockwise scan needs no extra collectives —
    only the y reshard at the residual boundary.
    """
    if shard is None or not getattr(shard, "constrain_attention", True):
        return q
    H, S = q.shape[2], q.shape[1]
    if shard.dim_shards("heads", H) > 1:
        return shard(q, "batch", None, "heads", None)
    if shard.dim_shards("seq_model", S) > 1:
        return shard(q, "batch", "seq_model", None, None)
    return q


def attn_full(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    rope_positions,
    *,
    kv_block: int = 1024,
    shard=None,
) -> jax.Array:
    """Train/prefill full-sequence attention (no cache)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, rope_positions)
    q = _constrain_q(q, cfg, shard)
    window = _window_for(cfg, kind)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = blockwise_attention(
        q, k, v, pos, pos,
        window=window, logit_cap=cfg.attn_logit_softcap, kv_block=min(kv_block, S),
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    if kind == "attn_local":
        return cfg.attn_window or (cfg.griffin.attn_window if cfg.griffin else None)
    return None


def init_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype
) -> KVCache:
    window = _window_for(cfg, kind)
    L = min(window, max_len) if window else max_len
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, L, KV, hd), dtype),
        v=jnp.zeros((batch, L, KV, hd), dtype),
        pos=jnp.full((batch, L), -1, dtype=jnp.int32),
    )


def attn_prefill(
    params, x, cfg: ModelConfig, kind: str, rope_positions, cache: KVCache,
    shard=None, valid_from=None,
) -> tuple[jax.Array, KVCache]:
    """Full-sequence forward that also fills the cache (last L positions).

    ``valid_from`` [B] marks the first real token per slot (left-padded
    serving batches); earlier slots get pos = -1 and are never attended.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, rope_positions)
    q = _constrain_q(q, cfg, shard)
    window = _window_for(cfg, kind)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if valid_from is not None:
        pos = jnp.where(pos >= valid_from[:, None], pos, -1)
    out = blockwise_attention(
        q, k, v, pos, pos, window=window,
        logit_cap=cfg.attn_logit_softcap, kv_block=min(1024, S),
    )
    L = cache.k.shape[1]
    if L >= S:
        new = KVCache(
            k=jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0)),
            pos=jax.lax.dynamic_update_slice(cache.pos, pos, (0, 0)),
        )
    else:  # keep the last L positions (ring layout: slot = pos % L)
        tail_k, tail_v, tail_p = k[:, -L:], v[:, -L:], pos[:, -L:]
        roll = -(S % L) if L else 0
        new = KVCache(
            k=jnp.roll(tail_k, roll, axis=1),
            v=jnp.roll(tail_v, roll, axis=1),
            pos=jnp.roll(tail_p, roll, axis=1),
        )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new


def attn_decode(
    params, x, cfg: ModelConfig, kind: str, rope_positions, cache: KVCache, t
) -> tuple[jax.Array, KVCache]:
    """One-token decode.  x [B, 1, d]; t — absolute position scalar."""
    q, k, v = _project_qkv(params, x, cfg, rope_positions)
    L = cache.k.shape[1]
    window = _window_for(cfg, kind)
    slot = t % L
    cache = KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0)),
        pos=jax.lax.dynamic_update_slice(
            cache.pos,
            jnp.full((cache.pos.shape[0], 1), t, jnp.int32),
            (0, slot),
        ),
    )
    B, _, H, hd = q.shape
    KV = cache.k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bkgh,btkh->bkgt", qg, cache.k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    s = layers.softcap(s, cfg.attn_logit_softcap)
    valid = _mask(t, cache.pos[:, None, None, :], window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(cache.v.dtype), cache.v)
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache
