"""Lectic order machinery: the ⊕-operator and the ≤_{p_i} feasibility test.

Convention: attribute index 0 == the paper's smallest attribute ``p_1``.
For packed sets, "the bits strictly below attribute ``a``" is
``bitset.low_mask(a)``; the NextClosure feasibility condition

    Y ⊕ p_i  is accepted  ⟺  (Y ⊕ p_i) ∩ {p_1..p_{i-1}}  ==  Y ∩ {p_1..p_{i-1}}

becomes the word-parallel test ``((cand ^ Y) & low_mask(a)) == 0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset


class LecticTables:
    """Precomputed per-attribute masks: LOW[a] = bits<a, BIT[a] = {a}."""

    def __init__(self, n_attrs: int):
        W = bitset.n_words(n_attrs)
        self.n_attrs = n_attrs
        self.W = W
        self.LOW = np.stack([bitset.low_mask(a, W) for a in range(n_attrs)])
        self.BIT = np.stack([bitset.bit(a, W) for a in range(n_attrs)])
        self.attr_mask = bitset.attr_mask(n_attrs, W)


def oplus_seed(Y: np.ndarray, a: int, tables: LecticTables) -> np.ndarray:
    """The pre-closure seed of ``Y ⊕ p_a``: ``(Y ∩ {bits<a}) ∪ {a}``."""
    return (Y & tables.LOW[a]) | tables.BIT[a]


def oplus_seeds_all(Y: np.ndarray, tables: LecticTables) -> tuple[np.ndarray, np.ndarray]:
    """Seeds for every attribute ``a ∉ Y`` at once.

    Returns (seeds [m, W], valid [m] bool) — ``valid[a]`` is False when
    ``a ∈ Y`` (no candidate is generated for members, Alg. 4 line 2).
    """
    seeds = (Y[None, :] & tables.LOW) | tables.BIT  # [m, W]
    member = bitset.unpack_bits(Y, tables.n_attrs)  # [m]
    return seeds, ~member


def feasible(cand: np.ndarray, Y: np.ndarray, a: int, tables: LecticTables) -> bool:
    """NextClosure acceptance: ``cand`` ≤_{p_a}-succeeds ``Y`` (Eqn. 4)."""
    return bool(np.all(((cand ^ Y) & tables.LOW[a]) == 0))


def feasible_batch(
    cands: np.ndarray, Y: np.ndarray, tables: LecticTables
) -> np.ndarray:
    """Vectorized acceptance for the candidate-per-attribute batch [m, W]."""
    return np.all(((cands ^ Y[None, :]) & tables.LOW) == 0, axis=-1)


def lectic_leq(y1: np.ndarray, y2: np.ndarray, n_attrs: int) -> bool:
    """Total lectic order test ``y1 < y2`` (Eqn. 3); False if equal.

    y1 < y2 iff the smallest attribute where they differ is in y2.
    """
    diff = y1 ^ y2
    if not np.any(diff):
        return False
    a = bitset.head_attr(diff)
    return bool(bitset.unpack_bits(y2, n_attrs)[a])


# ---------------------------------------------------------------------------
# jnp twins — the device half used by the frontier pipeline (core.frontier).
# Same arithmetic as the numpy ops above, on [batch, ...] shapes, jit-able.
# ---------------------------------------------------------------------------


def member_bits_jnp(Y: jax.Array, n_attrs: int) -> jax.Array:
    """Unpack ``[..., W]`` packed sets to bool ``[..., n_attrs]`` on device."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (Y[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*Y.shape[:-1], Y.shape[-1] * 32)
    return flat[..., :n_attrs].astype(bool)


def oplus_seeds_jnp(
    Y: jax.Array, LOW: jax.Array, BIT: jax.Array, n_attrs: int
) -> tuple[jax.Array, jax.Array]:
    """Batched ⊕-seeds for a frontier ``Y [F, W]``.

    Returns ``(seeds [F, m, W], valid [F, m])`` — the device twin of
    ``oplus_seeds_all`` over the whole frontier at once.
    """
    seeds = (Y[:, None, :] & LOW[None, :, :]) | BIT[None, :, :]
    valid = ~member_bits_jnp(Y, n_attrs)
    return seeds, valid


def cbo_seeds_jnp(
    Y: jax.Array, gens: jax.Array, BIT: jax.Array, n_attrs: int
) -> tuple[jax.Array, jax.Array]:
    """Batched CbO expansion seeds ``Y ∪ {a}`` for ``a > gen, a ∉ Y``.

    Y [F, W] packed frontier intents, gens [F] generator attrs.
    Returns ``(seeds [F, m, W], valid [F, m])``.
    """
    seeds = Y[:, None, :] | BIT[None, :, :]
    attrs = jnp.arange(n_attrs, dtype=gens.dtype)
    valid = ~member_bits_jnp(Y, n_attrs) & (attrs[None, :] > gens[:, None])
    return seeds, valid


def feasible_jnp(
    closures: jax.Array, parents: jax.Array, gens: jax.Array, LOW: jax.Array
) -> jax.Array:
    """Word-parallel ``((Z ^ Y) & LOW[a]) == 0`` for a batch ``[B, ...]``.

    This single test is both NextClosure's ≤_{p_i} feasibility (Eqn. 4) and
    CbO's canonicity check — the two drivers differ only in which parent/
    generator pairs they feed it.
    """
    return jnp.all(((closures ^ parents) & LOW[gens]) == 0, axis=-1)


def select_lectic(
    closures: jax.Array, ok: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Pick the lectic-max feasible candidate on device (Alg. 5 line 6).

    ``closures [B, W]`` is the per-attribute candidate batch in ascending
    generator order, ``ok [B]`` the feasibility mask; NextClosure takes the
    *largest* feasible generator.  An argmax over ``where(ok, arange, -1)``
    plus a dynamic-slice gather replaces the host-side
    ``closures[int(idx.max())]`` so the selection never forces a readback.
    Returns ``(Y_next [W], found [] bool)``; ``Y_next`` is ``closures[0]``
    garbage when nothing is feasible — gate on ``found``.
    """
    score = jnp.where(
        ok, jnp.arange(ok.shape[0], dtype=jnp.int32), jnp.int32(-1)
    )
    idx = jnp.argmax(score)
    Y_next = jax.lax.dynamic_index_in_dim(closures, idx, keepdims=False)
    return Y_next, score[idx] >= 0


select_lectic_jnp = jax.jit(select_lectic)


def lectic_sort_key(row: np.ndarray, n_attrs: int) -> tuple:
    """Sort key producing ascending lectic order for packed sets.

    In lectic order, comparing the bit-reversed attribute vector as an
    integer works: smaller attributes are more significant, and a set is
    *larger* if it contains the first differing (smallest) attribute.
    """
    bits = bitset.unpack_bits(row, n_attrs)
    return tuple(int(b) for b in bits)
