"""The paper's two-level hash table ``H`` (MRGanter+, Algorithm 6).

Level 1 keys on the *head attribute* of the closure (its smallest member);
level 2 keys on the closure's *length* (popcount).  Leaves are sets of the
packed intent bytes.  This mirrors the paper's reduce-side index used to
"fast index and search a specified closure".

``add_batch`` is the reduce-side bulk insert: keys (head attribute,
popcount, canonical bytes) are computed with batched numpy ops, intra-batch
duplicates collapse through ``np.unique`` on a bytes view, and membership
against the registry is one flat-set probe per *distinct* row — the
per-row ``add`` remains as the paper-literal oracle
(tests/test_hashindex.py asserts bit-identical behaviour).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset


def batch_heads(rows: np.ndarray) -> np.ndarray:
    """Vectorized ``bitset.head_attr`` for a batch [B, W] → int32 [B].

    Smallest set attribute per row; -1 for empty rows.
    """
    rows = np.ascontiguousarray(rows, dtype=np.uint32)
    nonzero = rows != 0
    first_w = np.argmax(nonzero, axis=-1)  # first non-empty word (0 if none)
    v = np.take_along_axis(rows, first_w[:, None], axis=-1)[:, 0]
    lowbit = v & (~v + np.uint32(1))  # isolate lowest set bit
    lsb = np.bitwise_count((lowbit - np.uint32(1)) & np.uint32(0xFFFFFFFF))
    head = first_w * bitset.WORD_BITS + lsb
    return np.where(nonzero.any(axis=-1), head, -1).astype(np.int32)


def batch_heads_jnp(rows: jax.Array) -> jax.Array:
    """Device twin of :func:`batch_heads` — jit-able, same arithmetic.

    Used by the query subsystem's device-resident index
    (:mod:`repro.query.store`) to key lookups inside the SPMD step.
    """
    rows = rows.astype(jnp.uint32)
    nonzero = rows != 0
    first_w = jnp.argmax(nonzero, axis=-1)
    v = jnp.take_along_axis(rows, first_w[:, None], axis=-1)[:, 0]
    lowbit = v & (~v + jnp.uint32(1))
    lsb = jax.lax.population_count(lowbit - jnp.uint32(1))
    head = first_w.astype(jnp.int32) * bitset.WORD_BITS + lsb.astype(jnp.int32)
    return jnp.where(nonzero.any(axis=-1), head, -1)


def bucket_key(heads, lengths, n_attrs: int):
    """Flat index key combining both hash levels: (head+1)·(m+2) + length.

    Works for numpy and jnp inputs alike; strictly increasing in
    (head, length), so a table sorted by it supports two-sided
    ``searchsorted`` bucket probes (the device index's lookup path).
    """
    return (heads + 1) * (n_attrs + 2) + lengths


class TwoLevelHash:
    def __init__(self):
        self._levels: dict[int, dict[int, set[bytes]]] = {}
        self._keys: set[bytes] = set()  # flat view for O(1) batch probes
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __contains__(self, row: np.ndarray) -> bool:
        head = bitset.head_attr(row)
        length = int(bitset.popcount(row))
        bucket = self._levels.get(head, {}).get(length)
        return bucket is not None and bitset.key_bytes(row) in bucket

    def add(self, row: np.ndarray) -> bool:
        """Insert; returns True iff the intent was new (Alg. 6 line 7)."""
        head = bitset.head_attr(row)
        length = int(bitset.popcount(row))
        bucket = self._levels.setdefault(head, {}).setdefault(length, set())
        key = bitset.key_bytes(row)
        if key in bucket:
            return False
        bucket.add(key)
        self._keys.add(key)
        self._n += 1
        return True

    def add_batch(self, rows: np.ndarray) -> list[int]:
        """Insert a batch [B, W]; returns indices of the rows that were new.

        Semantics match a row-by-row ``add`` loop: the *first* occurrence
        of each previously-unseen intent is reported, in ascending batch
        order.
        """
        B = rows.shape[0]
        if B == 0:
            return []
        rows = np.ascontiguousarray(rows, dtype=np.uint32)
        # Intra-batch dedupe on the raw bytes; first-occurrence indices.
        view = rows.view([("", np.uint8)] * rows.dtype.itemsize * rows.shape[1])
        _, first_idx = np.unique(view, return_index=True)
        first_idx = np.sort(first_idx)
        cand = rows[first_idx]
        heads = batch_heads(cand)
        lengths = bitset.popcount(cand)
        out: list[int] = []
        for i, head, length in zip(first_idx, heads, lengths):
            key = rows[i].tobytes()
            if key in self._keys:
                continue
            self._keys.add(key)
            self._levels.setdefault(int(head), {}).setdefault(
                int(length), set()
            ).add(key)
            out.append(int(i))
            self._n += 1
        return out

    def bucket_stats(self) -> dict[str, float]:
        sizes = [
            len(s) for lv2 in self._levels.values() for s in lv2.values()
        ]
        if not sizes:
            return {"buckets": 0, "max": 0, "mean": 0.0}
        return {
            "buckets": len(sizes),
            "max": max(sizes),
            "mean": float(np.mean(sizes)),
        }
