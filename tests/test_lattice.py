"""Covering-relation construction: the vectorized subset-test-matmul path
vs the host loop vs a brute-force transitive-reduction oracle."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import all_closures_batched, bitset
from repro.core.context import FormalContext, paper_context
from repro.core.lattice import (
    build_lattice,
    covering_matmul,
    subset_matrix,
)

settings.register_profile("lat", deadline=None, max_examples=20)
settings.load_profile("lat")


def _brute_force_children(arr: np.ndarray) -> list[list[int]]:
    """Independent O(C³) oracle: strict-subset pairs, then drop any pair
    with a strictly-between third intent (transitive reduction)."""
    C = arr.shape[0]
    strict = np.zeros((C, C), dtype=bool)  # strict[j, i]: intent_j ⊂ intent_i
    for j in range(C):
        for i in range(C):
            if j != i and bool(bitset.is_subset(arr[j], arr[i])) and not (
                np.array_equal(arr[j], arr[i])
            ):
                strict[j, i] = True
    children = [[] for _ in range(C)]
    for i in range(C):
        for j in range(C):
            if strict[j, i] and not any(
                strict[j, k] and strict[k, i] for k in range(C)
            ):
                children[i].append(j)
    return children


@given(
    st.integers(3, 30), st.integers(2, 12), st.floats(0.15, 0.6),
    st.integers(0, 10_000),
)
def test_covering_matmul_vs_oracles(n, m, density, seed):
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    intents = all_closures_batched(ctx)
    lat_mm = build_lattice(ctx, intents, method="matmul")
    lat_host = build_lattice(ctx, intents, method="host")
    assert np.array_equal(lat_mm.intents, lat_host.intents)
    assert [list(c) for c in lat_mm.children] == [
        list(c) for c in lat_host.children
    ]
    assert lat_mm.children == _brute_force_children(lat_mm.intents)


def test_subset_matrix_matches_pairwise():
    ctx = FormalContext.synthetic(25, 10, 0.3, seed=3)
    arr = np.stack(all_closures_batched(ctx))
    leq = subset_matrix(arr, ctx.n_attrs)
    C = arr.shape[0]
    for i in range(C):
        for j in range(C):
            assert leq[i, j] == bool(bitset.is_subset(arr[i], arr[j]))


def test_covering_paper_example_structure():
    ctx = paper_context()
    lat = build_lattice(ctx, all_closures_batched(ctx))
    assert lat.n_concepts == 21
    # the Hasse diagram of a lattice is connected: every non-top concept
    # is covered by someone, every non-bottom concept covers someone
    covered_by = [[] for _ in range(21)]
    for i, kids in enumerate(lat.children):
        for j in kids:
            covered_by[j].append(i)
    for i in range(21):
        pop = int(bitset.popcount(lat.intents[i]))
        if pop > 0:  # not the top (∅ intent) — someone's child
            assert covered_by[i] or lat.children[i], i
    # covering edges only go from larger to smaller intents
    for i, kids in enumerate(lat.children):
        for j in kids:
            assert bitset.popcount(lat.intents[j]) < bitset.popcount(
                lat.intents[i]
            )
            assert bool(bitset.is_subset(lat.intents[j], lat.intents[i]))


def test_default_method_is_matmul_and_matches_seed_behaviour():
    """The old host-loop output is the contract; the new default must
    reproduce it exactly on a mined lattice."""
    ctx = FormalContext.synthetic(40, 14, 0.25, seed=11)
    intents = all_closures_batched(ctx)
    assert build_lattice(ctx, intents).children == build_lattice(
        ctx, intents, method="host"
    ).children
