"""Pallas flash-attention kernel vs plain-softmax oracle (interpret mode)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def oracle(q, k, v, causal=True, window=None, logit_cap=None):
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) / math.sqrt(hd)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    valid = jnp.ones((S, T), bool)
    if causal:
        valid &= kp <= qp
    if window:
        valid &= qp - kp < window
    s = jnp.where(valid, s, -2.0**30)
    p = jax.nn.softmax(s, axis=-1)
    any_valid = valid.any(-1)[None, None, :, None]
    out = jnp.einsum("bhst,bhtd->bhsd", p, vx.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def _case(B, H, KV, S, T, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KV, T, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KV, T, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("B,H,KV,S,T,hd,causal,window,cap", [
    (2, 4, 2, 64, 64, 16, True, None, None),       # GQA causal
    (1, 6, 2, 100, 100, 32, True, 32, None),       # sliding window, ragged S
    (2, 2, 1, 48, 48, 16, True, None, 50.0),       # MQA + gemma2 softcap
    (1, 4, 4, 33, 70, 8, False, None, None),       # MHA, cross S≠T, no mask
    (1, 8, 2, 256, 256, 64, True, 64, 30.0),       # window + cap together
    (1, 1, 1, 8, 8, 8, True, None, None),          # minimal
])
def test_flash_matches_oracle(B, H, KV, S, T, hd, causal, window, cap):
    q, k, v = _case(B, H, KV, S, T, hd, seed=B + S + hd)
    got = flash_attention(
        q, k, v, causal=causal, window=window, logit_cap=cap,
        q_blk=32, kv_blk=32,
    )
    want = oracle(q, k, v, causal, window, cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("q_blk,kv_blk", [(16, 64), (64, 16), (128, 128)])
def test_flash_block_shape_invariance(q_blk, kv_blk):
    q, k, v = _case(1, 4, 2, 128, 128, 32, seed=7)
    a = flash_attention(q, k, v, q_blk=q_blk, kv_blk=kv_blk)
    b = oracle(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-4)


def test_flash_bf16_inputs():
    q, k, v = _case(1, 2, 2, 64, 64, 32, seed=3)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    got = flash_attention(q, k, v, q_blk=32, kv_blk=32)
    want = oracle(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got).astype(np.float32), np.asarray(want), atol=3e-2, rtol=3e-2
    )


def test_flash_rejects_bad_gqa():
    q, k, v = _case(1, 3, 2, 16, 16, 8)
    with pytest.raises(ValueError, match="multiple"):
        flash_attention(q, k, v)
