"""Distributed closure engine — the MapReduce substrate for the MR* miners.

The engine owns the *static data* (the object-partitioned context, resident
on device across iterations — Twister's defining feature) and exposes one
operation: batched **global** closure.

    map    : per-shard batched closure (Pallas kernel or jnp fallback)
    reduce : bitwise-AND all-reduce of local closures across the object
             partition axes + psum of supports   (paper Theorem 2)

Backends:
  * ``mesh``      — real SPMD over a jax Mesh via shard_map; object rows are
    sharded over the given axis names (e.g. ("pod", "data")).
  * ``simulated`` — single-device: rows reshaped [k, N/k, W], local closures
    vmapped over the partition axis, AND-folded.  Bit-identical arithmetic,
    used for tests/benchmarks on one CPU device.

Supports are corrected globally: all-ones padding rows match every
candidate, so ``supports -= n_pad_total`` after the psum.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import bitset
from repro.core.context import FormalContext
from repro.dist import collectives
from repro.kernels import ops


BACKENDS = ("kernel", "jnp", "matmul")


@dataclasses.dataclass
class EngineStats:
    closure_calls: int = 0
    closures_computed: int = 0
    modeled_comm_bytes: int = 0
    rounds: int = 0
    # host↔device traffic census (the frontier pipeline's whole point):
    h2d_transfers: int = 0
    h2d_bytes: int = 0
    d2h_transfers: int = 0
    d2h_bytes: int = 0


class ClosureEngine:
    def __init__(
        self,
        ctx: FormalContext,
        *,
        mesh: Mesh | None = None,
        axis_names: tuple[str, ...] = ("data",),
        n_parts: int | None = None,
        backend: str | None = None,
        use_kernel: bool = True,
        reduce_impl: str = "rsag",
        block_n: int = 256,
        max_batch: int = 8192,
        interpret: bool = True,
    ):
        # ``backend`` supersedes the old ``use_kernel`` flag:
        #   kernel — Pallas closure kernel (interpret-mode on CPU)
        #   jnp    — fused-jnp reference (fastest on CPU/XLA)
        #   matmul — MXU complement-counting closure (§Perf C2)
        if backend is None:
            backend = "kernel" if use_kernel else "jnp"
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose {BACKENDS}")
        self.ctx = ctx
        self.mesh = mesh
        self.axis_names = axis_names
        self.backend = backend
        self.use_kernel = backend == "kernel"
        self.reduce_impl = reduce_impl
        self.block_n = block_n
        self.max_batch = max_batch
        self.interpret = interpret
        self.stats = EngineStats()

        if mesh is not None:
            k = 1
            for a in axis_names:
                k *= mesh.shape[a]
        else:
            k = n_parts or 1
        self.n_parts = k

        # Pad rows so every shard is block-aligned: N % (k * block_n) == 0.
        rows, n_pad = ctx.padded_rows(k * block_n)
        self.n_pad_rows = n_pad
        self.N_padded = rows.shape[0]
        self._mask = jnp.asarray(ctx.attr_mask())

        if mesh is not None:
            sharding = NamedSharding(mesh, P(axis_names, None))
            self.rows = jax.device_put(jnp.asarray(rows), sharding)
        else:
            self.rows = jnp.asarray(rows).reshape(k, self.N_padded // k, ctx.W)

        self._step = self._build_step()

    # -- step builders -----------------------------------------------------

    def _build_step(self):
        ctx, axis_names, impl = self.ctx, self.axis_names, self.reduce_impl
        backend, block_n, interp = self.backend, self.block_n, self.interpret

        if backend == "matmul":

            def local_closure(rows_local, cands):
                return ops.closure_matmul(
                    rows_local,
                    cands,
                    ctx.n_attrs,
                    n_valid_rows=rows_local.shape[0],  # global pad corrected later
                )

        else:

            def local_closure(rows_local, cands):
                return ops.batched_closure(
                    rows_local,
                    cands,
                    ctx.n_attrs,
                    n_valid_rows=rows_local.shape[0],  # global pad corrected later
                    block_n=block_n,
                    use_kernel=backend == "kernel",
                    interpret=interp,
                )

        if self.mesh is not None:
            flat_axes = axis_names if len(axis_names) > 1 else axis_names[0]

            def shard_body(rows_local, cands):
                lc, ls = local_closure(rows_local, cands)
                gc = collectives.and_allreduce(
                    lc, flat_axes, impl=impl, n_attrs=ctx.n_attrs
                )
                gs = lax.psum(ls, flat_axes)
                return gc, gs

            smapped = compat.shard_map(
                shard_body,
                mesh=self.mesh,
                in_specs=(P(axis_names, None), P()),
                out_specs=(P(), P()),
                check_vma=False,  # pallas_call outputs carry no vma info
            )

            @jax.jit
            def step(rows, cands):
                gc, gs = smapped(rows, cands)
                return gc & self._mask, gs - self.n_pad_rows

            return step

        # Simulated partitions on one device.
        def sim_body(rows_k, cands):
            lc, ls = jax.vmap(lambda r: local_closure(r, cands))(rows_k)
            gc = collectives._and_fold(lc)
            gs = ls.sum(axis=0)
            return gc, gs

        @jax.jit
        def step(rows, cands):
            gc, gs = sim_body(rows, cands)
            return gc & self._mask, gs - self.n_pad_rows

        return step

    # -- public API ----------------------------------------------------------

    @property
    def min_bucket(self) -> int:
        return max(8, self.n_parts)

    def closure(self, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Global closures + supports for a host candidate batch [B, W]."""
        B = cands.shape[0]
        if B == 0:
            return (
                np.zeros((0, self.ctx.W), np.uint32),
                np.zeros((0,), np.int32),
            )
        out_c = np.empty((B, self.ctx.W), np.uint32)
        out_s = np.empty((B,), np.int32)
        self.stats.rounds += 1
        for lo in range(0, B, self.max_batch):
            chunk = cands[lo : lo + self.max_batch]
            b = chunk.shape[0]
            cap = ops.bucket_size(b, minimum=self.min_bucket)
            if cap != b:  # pad with all-ones candidates; outputs dropped
                pad = np.full((cap - b, self.ctx.W), 0xFFFFFFFF, np.uint32)
                chunk = np.concatenate([chunk, pad], axis=0)
            gc, gs = self._step(self.rows, jnp.asarray(chunk))
            out_c[lo : lo + b] = np.asarray(gc)[:b]
            out_s[lo : lo + b] = np.asarray(gs)[:b]
            self.stats.closure_calls += 1
            self.stats.closures_computed += b
            self.stats.h2d_transfers += 1
            self.stats.h2d_bytes += cap * self.ctx.W * 4
            self.stats.d2h_transfers += 2
            self.stats.d2h_bytes += cap * (self.ctx.W + 1) * 4
            self.stats.modeled_comm_bytes += collectives.modeled_comm_bytes(
                self.reduce_impl, self.n_parts, cap, self.ctx.W
            )
        return out_c, out_s

    def closure_dev(
        self, cands, n_valid: int, *, count_round: bool = True
    ):
        """Device-to-device closure for an already bucket-padded batch.

        ``cands`` is a device array [cap, W]; rows past ``n_valid`` are
        padding whose outputs the caller ignores.  Nothing crosses the
        host boundary — this is the frontier pipeline's map+reduce step.
        """
        cap = cands.shape[0]
        gc, gs = self._step(self.rows, cands)
        self.stats.closure_calls += 1
        if count_round:
            self.stats.rounds += 1
        self.stats.closures_computed += n_valid
        self.stats.modeled_comm_bytes += collectives.modeled_comm_bytes(
            self.reduce_impl, self.n_parts, cap, self.ctx.W
        )
        return gc, gs

    def first_closure(self) -> tuple[np.ndarray, int]:
        """``∅''`` and its support ``|O|`` via a full map/reduce round."""
        empty = np.zeros((1, self.ctx.W), np.uint32)
        c, s = self.closure(empty)
        return c[0], int(s[0])
