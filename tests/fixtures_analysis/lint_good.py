"""Clean twin of ``lint_bad.py`` — every lint rule must stay silent.

Each function is the disciplined version of its bad counterpart: the
annotated sync marker, the injected clock, the None-default idiom, the
hoisted jit, and the typed except.  Analyzed by path only.
"""

import time

import numpy as np

import jax


def rounds_async(frontier, xs):
    out = []
    for x in xs:
        out.append(np.asarray(x))  # sync: ok — test fixture reconcile point
    return out


def reconcile_results(xs):
    # host syncs OUTSIDE the async scopes are ordinary and legal
    return [np.asarray(x) for x in xs]


def dispatch(t0, clock=time.monotonic):
    # the bare attribute default IS the injection mechanism — only direct
    # time.*() calls are wall-clock reads
    return clock() - t0


def accumulate(x, acc=None):
    acc = [] if acc is None else acc
    acc.append(x)
    return acc


def compile_once(fn, xs):
    step = jax.jit(fn)
    return [step(x) for x in xs]


def swallow(fn):
    try:
        return fn()
    except Exception:
        return None
