"""The decoder stack: init + train/prefill/decode for every assigned arch.

Layer heterogeneity (gemma2 local/global alternation, griffin rec-rec-attn)
is handled by scanning over *super-blocks* — one period of
``cfg.layer_pattern`` per scan step with stacked params — keeping the HLO
compact for 512-device compiles; a non-divisible tail is unrolled.

Modes:
  * train   — full sequence, loss-ready hidden states (no caches)
  * prefill — full sequence, returns per-layer caches + last hidden
  * decode  — one token against caches at absolute position ``t``

Modality stubs (assignment rules): ``input_mode == "embeds"`` archs
(qwen2-vl, musicgen) consume precomputed frame/patch embeddings [B, S, d]
from ``input_specs()`` instead of token ids.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, griffin, layers, moe, ssm
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(pb: layers.ParamBuilder, cfg: ModelConfig, kind: str):
    p: dict[str, Any] = {"pre_norm": layers.init_rms_norm(pb, cfg.d_model)}
    if kind.startswith("attn"):
        p["core"] = attention.init_attention(pb, cfg)
    elif kind == "rec":
        p["core"] = griffin.init_recurrent(pb, cfg)
    elif kind == "ssd":
        p["core"] = ssm.init_ssd(pb, cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.post_norm:
        p["post_norm"] = layers.init_rms_norm(pb, cfg.d_model)

    if kind != "ssd":  # mamba2 blocks have no FFN sub-layer
        p["pre_mlp_norm"] = layers.init_rms_norm(pb, cfg.d_model)
        if cfg.moe is not None:
            p["moe"] = moe.init_moe(pb, cfg)
            if cfg.moe.dense_residual:
                p["mlp"] = layers.init_mlp(pb, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
        else:
            p["mlp"] = layers.init_mlp(pb, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
        if cfg.post_norm:
            p["post_mlp_norm"] = layers.init_rms_norm(pb, cfg.d_model)
    return p


def _init_superblock(pb: layers.ParamBuilder, cfg: ModelConfig):
    return {
        f"block{i}": _init_block(pb.fork(), cfg, kind)
        for i, kind in enumerate(cfg.layer_pattern)
    }


def init_model(cfg: ModelConfig, key: jax.Array | None, abstract: bool = False):
    """Returns a Param-tree (use ``layers.split_params`` for values/axes)."""
    dtype = jnp.dtype(cfg.dtype)
    pb = layers.ParamBuilder(key, dtype, abstract=abstract)
    params: dict[str, Any] = {
        "embed": pb.embed((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "final_norm": layers.init_rms_norm(pb, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = pb.dense(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab")
        )
    if cfg.n_periods > 0:
        params["layers"] = layers.stack_params(
            [_init_superblock(pb.fork(), cfg) for _ in range(cfg.n_periods)]
        )
    if cfg.tail_pattern:
        params["tail"] = [
            _init_block(pb.fork(), cfg, kind) for kind in cfg.tail_pattern
        ]
    return params


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """(ShapeDtypeStruct values, axes) without allocating — for the dry-run."""
    tree = init_model(cfg, key=None, abstract=True)
    return layers.split_params(tree)


def init_params(cfg: ModelConfig, seed: int = 0):
    tree = init_model(cfg, jax.random.key(seed))
    return layers.split_params(tree)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind.startswith("attn"):
        return attention.init_cache(cfg, kind, batch, max_len, dtype)
    if kind == "rec":
        return griffin.init_rec_cache(cfg, batch, dtype)
    if kind == "ssd":
        return ssm.init_ssm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _layer_cache_axes(cfg: ModelConfig, kind: str):
    """Logical axes mirroring ``_init_layer_cache`` leaf-for-leaf."""
    if kind.startswith("attn"):
        # Cache sequence dim shards over 'model' (sequence-parallel KV):
        # at 32k+ contexts the cache dwarfs per-step attention math, and
        # seq always divides the model axis where GQA kv-heads often don't.
        return attention.KVCache(
            k=("batch", "seq_kv", "kv", "head_dim"),
            v=("batch", "seq_kv", "kv", "head_dim"),
            pos=("batch", None),
        )
    if kind == "rec":
        return griffin.RecCache(conv=("batch", "conv", "lru"), h=("batch", "lru"))
    if kind == "ssd":
        return ssm.SSMCache(conv=("batch", "conv", "inner"), h=("batch", "heads", None, None))
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig):
    """Logical-axes tree matching ``init_caches`` (stacked dims → 'layers')."""
    def one_superblock(stacked: bool):
        pre = ("layers",) if stacked else ()
        return {
            f"block{i}": jax.tree_util.tree_map(
                lambda ax: pre + ax,
                _layer_cache_axes(cfg, kind),
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x
                ),
            )
            for i, kind in enumerate(cfg.layer_pattern)
        }

    axes: dict[str, Any] = {}
    if cfg.n_periods > 0:
        axes["layers"] = one_superblock(stacked=True)
    if cfg.tail_pattern:
        axes["tail"] = [
            jax.tree_util.tree_map(
                lambda ax: ax,
                _layer_cache_axes(cfg, kind),
                is_leaf=lambda x: isinstance(x, tuple),
            )
            for kind in cfg.tail_pattern
        ]
    return axes


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree matching the scan structure: stacked + tail."""
    dtype = jnp.dtype(cfg.dtype)

    def one_superblock():
        return {
            f"block{i}": _init_layer_cache(cfg, kind, batch, max_len, dtype)
            for i, kind in enumerate(cfg.layer_pattern)
        }

    caches: dict[str, Any] = {}
    if cfg.n_periods > 0:
        caches["layers"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[one_superblock() for _ in range(cfg.n_periods)]
        )
    if cfg.tail_pattern:
        caches["tail"] = [
            _init_layer_cache(cfg, kind, batch, max_len, dtype)
            for kind in cfg.tail_pattern
        ]
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block_apply(p, x, cfg: ModelConfig, kind: str, rope_pos, mode, cache, t, shard,
                 valid_from=None):
    """One layer.  Returns (x, new_cache, aux)."""
    exact_moe = mode == "decode"  # no capacity drops for single-token decode
    aux = jnp.zeros((), jnp.float32)
    h = layers.rms_norm(x, p["pre_norm"]["scale"])
    if kind.startswith("attn"):
        if mode == "train":
            y, new_cache = attention.attn_full(
                p["core"], h, cfg, kind, rope_pos, shard=shard
            ), None
        elif mode == "prefill":
            y, new_cache = attention.attn_prefill(
                p["core"], h, cfg, kind, rope_pos, cache, shard=shard,
                valid_from=valid_from,
            )
        else:
            y, new_cache = attention.attn_decode(p["core"], h, cfg, kind, rope_pos, cache, t)
    elif kind == "rec":
        if mode == "decode":
            y, new_cache = griffin.rec_block_decode(p["core"], h, cfg, cache)
        else:
            y, full_cache = griffin.rec_block_full(p["core"], h, cfg)
            new_cache = full_cache if mode == "prefill" else None
    elif kind == "ssd":
        if mode == "decode":
            y, new_cache = ssm.ssd_block_decode(p["core"], h, cfg, cache)
        else:
            y, full_cache = ssm.ssd_block_full(p["core"], h, cfg)
            new_cache = full_cache if mode == "prefill" else None
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        y = layers.rms_norm(y, p["post_norm"]["scale"])
    x = x + y

    if kind != "ssd":
        h = layers.rms_norm(x, p["pre_mlp_norm"]["scale"])
        if cfg.moe is not None:
            y, moe_aux = moe.moe_fwd(p["moe"], h, cfg, shard, exact=exact_moe)
            aux = aux + moe_aux
            if cfg.moe.dense_residual:
                y = y + layers.mlp_fwd(p["mlp"], h, cfg.mlp_kind)
        else:
            y = layers.mlp_fwd(p["mlp"], h, cfg.mlp_kind)
        if cfg.post_norm:
            y = layers.rms_norm(y, p["post_mlp_norm"]["scale"])
        x = x + y
    if mode == "train":
        new_cache = cache  # pass through (None)
    return x, new_cache, aux


def _superblock_apply(p, x, cfg, rope_pos, mode, caches, t, shard, valid_from=None):
    new_caches = {} if caches is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.layer_pattern):
        c = caches[f"block{i}"] if caches is not None else None
        x, nc, a = _block_apply(p[f"block{i}"], x, cfg, kind, rope_pos, mode, c, t,
                                shard, valid_from)
        aux = aux + a
        if new_caches is not None:
            new_caches[f"block{i}"] = nc
    if shard is not None:
        x = shard(x, "batch", None, None)
    return x, new_caches, aux


def forward_hidden(
    params,
    cfg: ModelConfig,
    inputs: jax.Array,
    *,
    mode: str,
    rope_positions=None,
    caches=None,
    t=None,
    shard=None,
    remat: bool = True,
    valid_from=None,
):
    """inputs: token ids [B, S] or embeds [B, S, d].  Returns
    (hidden [B, S, d], new_caches, aux)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "embeds" and inputs.ndim == 3:
        x = inputs.astype(dtype)
    else:
        x = params["embed"][inputs].astype(dtype)
        if cfg.emb_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, dtype)
    if shard is not None:
        x = shard(x, "batch", None, None)

    B, S = x.shape[0], x.shape[1]
    if rope_positions is None:
        base = jnp.arange(S, dtype=jnp.int32)[None, :] if mode != "decode" else (
            jnp.full((B, 1), t, dtype=jnp.int32)
        )
        rope_positions = (
            jnp.broadcast_to(base, (3, B, S)) if cfg.rope_kind == "mrope" else
            jnp.broadcast_to(base, (B, S))
        )

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.n_periods > 0:
        stacked = params["layers"]
        stacked_caches = caches["layers"] if caches is not None else None

        def body(carry, xs):
            xc, auxc = carry
            if stacked_caches is not None:
                p, c = xs
            else:
                p, c = xs, None
            xc, nc, a = _superblock_apply(p, xc, cfg, rope_positions, mode, c, t, shard,
                                          valid_from)
            return (xc, auxc + a), nc

        if remat and mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (stacked, stacked_caches) if stacked_caches is not None else stacked
        (x, aux_total), new_stacked = jax.lax.scan(body, (x, aux_total), xs)
    else:
        new_stacked = None

    new_tail = []
    if cfg.tail_pattern:
        tail_caches = caches["tail"] if caches is not None else [None] * len(cfg.tail_pattern)
        for p, kind, c in zip(params["tail"], cfg.tail_pattern, tail_caches):
            x, nc, a = _block_apply(p, x, cfg, kind, rope_positions, mode, c, t,
                                    shard, valid_from)
            aux_total = aux_total + a
            new_tail.append(nc)

    x = layers.rms_norm(x, params["final_norm"]["scale"])

    new_caches = None
    if caches is not None:
        new_caches = {}
        if new_stacked is not None:
            new_caches["layers"] = new_stacked
        if cfg.tail_pattern:
            new_caches["tail"] = new_tail
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Heads / losses
# ---------------------------------------------------------------------------


def _unembed_matrix(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def logits_for(params, cfg: ModelConfig, hidden: jax.Array, shard=None) -> jax.Array:
    w = _unembed_matrix(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", hidden, w, preferred_element_type=jnp.float32)
    logits = layers.softcap(logits, cfg.final_logit_softcap)
    if shard is not None:
        logits = shard(logits, "batch", None, "vocab")
    return logits


def lm_loss(
    params,
    cfg: ModelConfig,
    hidden: jax.Array,
    labels: jax.Array,
    *,
    shard=None,
    seq_chunk: int = 512,
) -> jax.Array:
    """Chunked-over-sequence xent so [B, S, V] never materializes whole."""
    B, S, d = hidden.shape
    chunk = min(seq_chunk, S)
    n = S // chunk
    h = hidden[:, : n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    y = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def step(carry, xs):
        h_c, y_c = xs
        logits = logits_for(params, cfg, h_c, shard)  # [B, chunk, V] fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        nll = lse - gold
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (h, y))
    # remainder (if S % chunk) — rare; handled unchunked
    if S % chunk:
        logits = logits_for(params, cfg, hidden[:, n * chunk :], shard)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, labels[:, n * chunk :][..., None], axis=-1
        )[..., 0]
        total = total + (lse - gold).sum()
    return total / (B * S)


def train_loss_fn(
    params, cfg: ModelConfig, batch: dict, shard=None, aux_weight: float = 0.01
):
    hidden, _, aux = forward_hidden(
        params, cfg, batch["inputs"], mode="train",
        rope_positions=batch.get("positions"), shard=shard,
    )
    loss = lm_loss(params, cfg, hidden, batch["labels"], shard=shard)
    return loss + aux_weight * aux, {"xent": loss, "moe_aux": aux}


def prefill(params, cfg: ModelConfig, inputs, caches, rope_positions=None, shard=None,
            valid_from=None):
    hidden, caches, _ = forward_hidden(
        params, cfg, inputs, mode="prefill",
        rope_positions=rope_positions, caches=caches, shard=shard,
        valid_from=valid_from,
    )
    logits = logits_for(params, cfg, hidden[:, -1:, :], shard)
    return logits, caches


def decode_step(params, cfg: ModelConfig, inputs, t, caches, rope_positions=None, shard=None):
    """inputs: [B, 1] token ids or [B, 1, d] embeds; t: absolute position."""
    hidden, caches, _ = forward_hidden(
        params, cfg, inputs, mode="decode",
        rope_positions=rope_positions, caches=caches, t=t, shard=shard,
    )
    logits = logits_for(params, cfg, hidden, shard)
    return logits, caches
