"""FCA × MoE: mine expert co-activation concepts from router decisions.

    PYTHONPATH=src python examples/moe_expert_fca.py

The one genuine contact point between the paper's technique and the LM
stack (DESIGN.md §Arch-applicability): a top-k router induces a Boolean
relation  *tokens × experts*  — a formal context.  Its concept lattice
describes which expert subsets fire together on which token subsets, i.e.
interpretable routing structure (expert specialization clusters, dead
pairs, capacity pressure) mined with the exact machinery of the paper.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ClosureEngine, FormalContext, bitset, mrganter_plus
from repro.data.lm_data import make_batch_iterator
from repro.models import transformer
from repro.models.config import ShapeConfig


def main(n_batches: int = 4):
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2)
    )
    params, _ = transformer.init_params(cfg, seed=0)
    shape = ShapeConfig("fca", "train", 64, 8)
    it = make_batch_iterator(cfg, shape, seed=0)

    # Collect router top-k decisions of the first MoE layer.
    p_moe = jax.tree_util.tree_map(
        lambda v: v[0], params["layers"]["block0"]["moe"]
    )

    @jax.jit
    def route(tokens):
        x = params["embed"][tokens].astype(jnp.float32)
        logits = x.reshape(-1, cfg.d_model) @ p_moe["router"].astype(jnp.float32)
        _, top_i = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k)
        return top_i

    rows = []
    for _ in range(n_batches):
        _, batch = next(it)
        top_i = np.asarray(route(jnp.asarray(batch["inputs"])))
        onehot = np.zeros((top_i.shape[0], cfg.moe.n_experts), bool)
        for k in range(cfg.moe.top_k):
            onehot[np.arange(top_i.shape[0]), top_i[:, k]] = True
        rows.append(onehot)
    ctx = FormalContext.from_dense(np.concatenate(rows, axis=0))
    print(f"routing context: {ctx.n_objects} tokens × {ctx.n_attrs} experts, "
          f"density {ctx.density:.3f} (≈ top_k/E = {cfg.moe.top_k / cfg.moe.n_experts:.3f})")

    eng = ClosureEngine(ctx, n_parts=4, reduce_impl="rsag", use_kernel=False)
    res = mrganter_plus(ctx, eng, dedupe_candidates=True)
    print(f"MRGanter+: {res.n_concepts} expert co-activation concepts "
          f"in {res.n_iterations} rounds\n")

    print("most-supported non-trivial expert subsets:")
    scored = []
    for y in res.intents:
        size = int(bitset.popcount(y))
        if 0 < size < cfg.moe.n_experts:
            from repro.core.closure import extent_np
            support = int(extent_np(ctx.rows, y).sum())
            scored.append((support, size, y))
    for support, size, y in sorted(scored, reverse=True)[:10]:
        experts = [a for a in range(ctx.n_attrs)
                   if bitset.unpack_bits(y, ctx.n_attrs)[a]]
        print(f"  experts {experts}  ← {support} tokens")


if __name__ == "__main__":
    main()
