"""Training substrate: optimizers, trainer fault tolerance, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.lm_data import LMDataConfig, SyntheticLM, make_batch_iterator
from repro.models import transformer
from repro.models.config import ShapeConfig
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optim import adafactor, adamw, get_optimizer, warmup_cosine
from repro.train.step import make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 4)


def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal(16), jnp.float32)

    def loss_fn(params):
        return jnp.sum((params["w"] - target) ** 2)

    return {"w": jnp.zeros(16, jnp.float32)}, loss_fn, target


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_converge_quadratic(opt_name):
    params, loss_fn, target = _quadratic_problem()
    # adafactor's RMS-normalized steps need a decaying lr to settle
    opt = get_optimizer(opt_name, lambda step: 0.1 / jnp.sqrt(step + 1.0))
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state = opt.apply(g, state, params)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_state_axes_structure():
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    axes = {"w": ("embed", "ffn"), "b": ("ffn",)}
    opt = adamw(lambda s: 1e-3)
    st = opt.init(params)
    st_axes = opt.state_axes(axes)
    assert st_axes["m"] == axes and st_axes["v"] == axes
    flat1 = jax.tree_util.tree_structure(st["m"])
    flat2 = jax.tree_util.tree_structure(params)
    assert flat1 == flat2


def test_adafactor_factored_shapes():
    params = {"w": jnp.zeros((6, 4, 8))}
    opt = adafactor(lambda s: 1e-3)
    st = opt.init(params)
    assert st["v"]["w"]["vr"].shape == (6, 4)
    assert st["v"]["w"]["vc"].shape == (6, 8)
    ax = opt.state_axes({"w": ("experts", "embed", "ffn")})
    assert ax["v"]["w"] == {"vr": ("experts", "embed"), "vc": ("experts", "ffn")}


def test_data_pipeline_deterministic_and_resumable():
    cfg = get_config("mamba2-370m").reduced()
    it1 = make_batch_iterator(cfg, SMOKE_SHAPE, seed=3)
    batches = [next(it1) for _ in range(5)]
    it2 = make_batch_iterator(cfg, SMOKE_SHAPE, seed=3, start_step=3)
    s, b = next(it2)
    assert s == 3
    np.testing.assert_array_equal(b["inputs"], batches[3][1]["inputs"])


def test_synthetic_lm_has_structure():
    data = SyntheticLM(LMDataConfig(vocab_size=64, seq_len=128, global_batch=8))
    b = data.batch(0)
    # Markov chain: successor entropy < log(V)
    seen = set(zip(b["inputs"].ravel().tolist(), b["labels"].ravel().tolist()))
    assert len(seen) < 64 * 64 * 0.5


def _tiny_trainer(tmp_path, total_steps=12, fault_hook=None, **kw):
    cfg = get_config("mamba2-370m").reduced()
    opt = get_optimizer("adamw", warmup_cosine(1e-2, 2, total_steps))
    step_fn = jax.jit(make_train_step(cfg, opt, None), donate_argnums=0)

    def init_state():
        params, _ = transformer.init_params(cfg, seed=0)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    return Trainer(
        step_fn=step_fn,
        init_state_fn=init_state,
        batch_iter_fn=lambda start: make_batch_iterator(
            cfg, SMOKE_SHAPE, seed=0, start_step=start
        ),
        cfg=TrainerConfig(
            total_steps=total_steps, ckpt_every=4,
            ckpt_dir=str(tmp_path), max_retries=3, **kw,
        ),
        fault_hook=fault_hook,
    )


def test_trainer_runs_and_loss_decreases(tmp_path):
    t = _tiny_trainer(tmp_path, total_steps=15)
    out = t.run()
    hist = out["history"]
    assert out["steps"] == 15
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_trainer_restart_after_injected_fault(tmp_path):
    boom = {"armed": True}

    def fault_hook(step):
        if step == 9 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    t = _tiny_trainer(tmp_path, total_steps=12, fault_hook=fault_hook)
    out = t.run()
    assert out["steps"] == 12
    assert out["n_restarts"] == 1
    # resumed from the step-8 checkpoint and replayed deterministically
    steps_seen = [h["step"] for h in out["history"]]
    assert steps_seen.count(8) == 2  # replayed after restore


def test_trainer_restart_equals_uninterrupted(tmp_path):
    """Checkpoint/restart must be bit-identically replayable."""
    t1 = _tiny_trainer(tmp_path / "a", total_steps=10)
    out1 = t1.run()

    def fault_hook(step):
        if step == 6 and not getattr(fault_hook, "fired", False):
            fault_hook.fired = True
            raise RuntimeError("boom")

    t2 = _tiny_trainer(tmp_path / "b", total_steps=10, fault_hook=fault_hook)
    out2 = t2.run()
    l1 = {h["step"]: h["loss"] for h in out1["history"]}
    l2 = {h["step"]: h["loss"] for h in out2["history"]}
    for s in range(10):
        assert l1[s] == pytest.approx(l2[s], rel=1e-6), s
