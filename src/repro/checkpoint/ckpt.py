"""Fault-tolerant checkpointing: atomic, hashed, elastic, async.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       # treedef, codec, shapes, dtypes, per-leaf sha256
        leaf_00000.bin.zst  # zstd-compressed raw array bytes
        ...                 # (.bin, uncompressed, when zstandard is absent)
        COMMITTED           # written last — absence ⇒ incomplete/corrupt

Guarantees:
  * **Atomicity** — data written to ``step_X.tmp``, fsynced, then renamed;
    the COMMITTED marker is written only after every leaf lands.  A crash
    mid-save never corrupts the previous checkpoint; ``latest_step`` skips
    uncommitted directories.
  * **Integrity** — per-leaf sha256 verified on restore.
  * **Elasticity** — leaves are stored *unsharded* (host-gathered); restore
    takes a tree of target shardings, so a run checkpointed on a 16×16 mesh
    restores cleanly onto 2×16×16 (or 2×4 in tests) — mesh-shape changes
    between runs are a first-class operation.
  * **Async** — ``CheckpointManager(async_save=True)`` snapshots to host and
    writes on a background thread, off the training critical path.
"""

from __future__ import annotations

import concurrent.futures
import hashlib
import json
import os
import shutil

import jax
import numpy as np

try:  # optional: fall back to raw (uncompressed) leaves when absent
    import zstandard
except ModuleNotFoundError:
    zstandard = None

_MANIFEST = "manifest.json"
_COMMITTED = "COMMITTED"


def have_zstd() -> bool:
    return zstandard is not None


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(path: str, step: int, tree) -> str:
    """Blocking save.  Returns the committed directory."""
    flat, treedef = _leaf_paths(tree)
    host = [np.asarray(jax.device_get(x)) for x in flat]

    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    codec = "zstd" if zstandard is not None else "raw"
    cctx = zstandard.ZstdCompressor(level=3) if codec == "zstd" else None
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "codec": codec,
        "leaves": [],
    }
    for i, arr in enumerate(host):
        raw = np.ascontiguousarray(arr).tobytes()
        digest = hashlib.sha256(raw).hexdigest()
        name = f"leaf_{i:05d}.bin.zst" if codec == "zstd" else f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, name), "wb") as f:
            f.write(cctx.compress(raw) if cctx is not None else raw)
        manifest["leaves"].append(
            {"file": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "sha256": digest}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMITTED), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    """Largest committed step under ``path`` (uncommitted dirs skipped)."""
    if not os.path.isdir(path):
        return None
    best = None
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, _COMMITTED)):
                best = max(best or -1, int(name.split("_")[1]))
    return best


def restore_checkpoint(path: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching tree of NamedShardings — this is the
    elastic path: leaves are device_put with the *new* mesh's shardings.
    """
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten(target_tree)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target tree has {len(flat)}"
        )
    codec = manifest.get("codec", "zstd")
    if codec == "zstd" and zstandard is None:
        raise ModuleNotFoundError(
            "checkpoint was written with zstd compression but the "
            "'zstandard' module is not installed"
        )
    dctx = zstandard.ZstdDecompressor() if codec == "zstd" else None
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(flat)
    )
    out = []
    for leaf, meta, shard in zip(flat, manifest["leaves"], shard_flat):
        with open(os.path.join(d, meta["file"]), "rb") as f:
            raw = dctx.decompress(f.read()) if dctx is not None else f.read()
        if hashlib.sha256(raw).hexdigest() != meta["sha256"]:
            raise IOError(f"checksum mismatch in {meta['file']}")
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch {arr.shape} vs target {leaf.shape} in {meta['file']}"
            )
        out.append(jax.device_put(arr, shard) if shard is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Keep-last-k manager with optional async (off-critical-path) saves."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = False):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._pool = (
            concurrent.futures.ThreadPoolExecutor(max_workers=1)
            if async_save
            else None
        )
        self._pending: concurrent.futures.Future | None = None
        os.makedirs(path, exist_ok=True)

    def save(self, step: int, tree):
        if self._pool is not None:
            self.wait()
            # Snapshot to host *now* (cheap, device→host copy), write later.
            host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
            self._pending = self._pool.submit(self._save_and_gc, step, host)
        else:
            self._save_and_gc(step, tree)

    def _save_and_gc(self, step: int, tree):
        save_checkpoint(self.path, step, tree)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.path)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.path, n, _COMMITTED))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.path)

    def restore(self, target_tree, shardings=None, step: int | None = None):
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return restore_checkpoint(self.path, step, target_tree, shardings)
