"""Incremental concept maintenance (Godin-style object addition).

The paper's §1.1 motivates incremental algorithms: "batch algorithms …
require that the entire lattice is reconstructed from scratch if the
database changes."  This module closes that gap for the streaming case:

    intents' = intents ∪ { B ∩ Y_g : B ∈ intents }

— adding object ``g`` with intent ``Y_g`` can only create concepts whose
intents are intersections of old intents with ``Y_g`` (every other closure
is unchanged; extents of intents ⊆ Y_g silently gain ``g``).  One pass,
O(|F|·W) word-ops, vectorized over the whole intent set — no mining rerun.

``add_objects`` streams a batch through; equivalence with batch NextClosure
on the grown context is property-tested (tests/test_incremental.py).
"""

from __future__ import annotations

import numpy as np

from repro.core import bitset
from repro.core.context import FormalContext


def add_object(
    ctx: FormalContext, intents: np.ndarray, new_row: np.ndarray
) -> tuple[FormalContext, np.ndarray]:
    """intents [C, W] (any order) + one packed row [W] → updated pair."""
    new_row = np.asarray(new_row, dtype=np.uint32)
    if np.any(new_row & ~ctx.attr_mask()):
        raise ValueError("new object has attribute bits above n_attrs")

    inter = intents & new_row[None, :]  # candidate new intents
    combined = np.concatenate([intents, inter, new_row[None, :]], axis=0)
    new_intents = np.unique(combined, axis=0)

    new_ctx = FormalContext(
        rows=np.concatenate([ctx.rows, new_row[None, :]], axis=0),
        n_objects=ctx.n_objects + 1,
        n_attrs=ctx.n_attrs,
        attr_names=ctx.attr_names,
    )
    return new_ctx, new_intents


def add_objects(
    ctx: FormalContext, intents, rows: np.ndarray
) -> tuple[FormalContext, np.ndarray]:
    """Stream a batch of packed rows [K, W] through ``add_object``."""
    cur = np.asarray(
        intents if not isinstance(intents, list) else np.stack(intents),
        dtype=np.uint32,
    )
    for i in range(rows.shape[0]):
        ctx, cur = add_object(ctx, cur, rows[i])
    return ctx, cur
