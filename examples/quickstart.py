"""Quickstart: the paper's worked example end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Mines the 21 formal concepts of Table 1 with the centralized baselines
(NextClosure, CloseByOne) and the distributed MR* algorithms (MRGanter,
MRGanter+, MRCbo), checks they agree, and prints the concept lattice.
"""

import numpy as np

from repro.core import (
    ClosureEngine,
    all_closures,
    bitset,
    build_lattice,
    close_by_one,
    mrcbo,
    mrganter,
    mrganter_plus,
    paper_context,
)

NAMES = "abcdefg"


def fmt(row, n=7):
    return "{" + ",".join(NAMES[a] for a in range(n) if bitset.unpack_bits(row, n)[a]) + "}"


def main():
    ctx = paper_context()
    print(f"context: {ctx.n_objects} objects × {ctx.n_attrs} attributes, "
          f"density {ctx.density:.2f}")

    ref = all_closures(ctx)
    print(f"\nNextClosure: {len(ref)} concepts (lectic order)")

    cbo = close_by_one(ctx)
    print(f"CloseByOne:  {len(cbo.intents)} concepts in {cbo.n_iterations} levels")

    for name, algo in [("MRGanter", mrganter), ("MRGanter+", mrganter_plus),
                       ("MRCbo", mrcbo)]:
        eng = ClosureEngine(ctx, n_parts=2, block_n=64)  # paper's S_1/S_2 split
        res = algo(ctx, eng)
        same = {bitset.key_bytes(y) for y in res.intents} == {
            bitset.key_bytes(y) for y in ref
        }
        print(f"{name:10s}: {res.n_concepts} concepts in {res.n_iterations:2d} "
              f"MapReduce rounds — matches NextClosure: {same}")

    lat = build_lattice(ctx, ref)
    print("\nconcept lattice (intent ← covered intents):")
    for i in range(lat.n_concepts):
        kids = ", ".join(fmt(lat.intents[j]) for j in lat.children[i])
        ext = "".join(str(o + 1) for o in np.nonzero(lat.extents[i])[0])
        print(f"  ⟨{{{ext}}}, {fmt(lat.intents[i])}⟩  ←  [{kids}]")


if __name__ == "__main__":
    main()
