"""Ganter's NextClosure (Algorithms 1–2 of the paper), centralized.

Two equivalent drivers are provided:

* ``next_closure`` / ``all_closures``  — the faithful scalar algorithm:
  scan attributes from p_m down to p_1, compute one ⊕ at a time, stop at the
  first feasible candidate.  This is the paper's Algorithm 2, verbatim.

* ``all_closures_batched`` — a vectorized variant that computes *all* m
  candidate closures of an iteration in one batched call and then picks the
  largest feasible attribute.  Bit-identical output (the first feasible
  candidate scanning downward == the feasible candidate with the largest
  generator), and it is exactly the compute shape of MRGanter's map phase,
  so the centralized and distributed code paths share arithmetic.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitset, closure, lectic
from repro.core.context import FormalContext


def first_closure(ctx: FormalContext) -> np.ndarray:
    """``∅''`` — the lectically smallest intent (Algorithm 1, line 1)."""
    empty = np.zeros(ctx.W, dtype=np.uint32)
    c, _ = closure.closure_np(ctx.rows, empty, ctx.attr_mask())
    return c


def next_closure(
    ctx: FormalContext, Y: np.ndarray, tables: lectic.LecticTables | None = None
) -> np.ndarray | None:
    """The next intent after ``Y`` in lectic order, or None if ``Y`` is last."""
    tables = tables or lectic.LecticTables(ctx.n_attrs)
    mask = ctx.attr_mask()
    member = bitset.unpack_bits(Y, ctx.n_attrs)
    for a in range(ctx.n_attrs - 1, -1, -1):  # p_m down to p_1
        if member[a]:
            continue
        seed = lectic.oplus_seed(Y, a, tables)
        cand, _ = closure.closure_np(ctx.rows, seed, mask)
        if lectic.feasible(cand, Y, a, tables):
            return cand
    return None


def all_closures(ctx: FormalContext) -> list[np.ndarray]:
    """All intents in ascending lectic order (Algorithm 1)."""
    tables = lectic.LecticTables(ctx.n_attrs)
    Y = first_closure(ctx)
    out = [Y]
    full = ctx.attr_mask()
    while not np.array_equal(Y, full):
        Y = next_closure(ctx, Y, tables)
        assert Y is not None, "NextClosure must terminate at the full set"
        out.append(Y)
    return out


def all_closures_batched(ctx: FormalContext) -> list[np.ndarray]:
    """Vectorized AllClosure — one batched closure call per concept."""
    tables = lectic.LecticTables(ctx.n_attrs)
    mask = ctx.attr_mask()
    Y = first_closure(ctx)
    out = [Y]
    full = mask
    while not np.array_equal(Y, full):
        seeds, valid = lectic.oplus_seeds_all(Y, tables)
        cands, _ = closure.batched_closure_np(ctx.rows, seeds, mask)
        ok = lectic.feasible_batch(cands, Y, tables) & valid
        a = int(np.max(np.nonzero(ok)[0]))  # first feasible scanning downward
        Y = cands[a]
        out.append(Y)
    return out


def extents_for_intents(
    ctx: FormalContext, intents: list[np.ndarray]
) -> list[np.ndarray]:
    """Recover extents (bool [N]) for a list of intents — one final pass."""
    return [closure.extent_np(ctx.rows, y) for y in intents]
