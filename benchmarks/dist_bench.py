"""ShardPlan scaling sweep: 1→8 object shards × reduce schedule (§Dist).

Three grids over MRGanter+ on the device pipeline, all through
:class:`repro.dist.ShardPlan` (simulated geometry — the arithmetic and the
analytic wire model are shard-count-exact on one CPU; the same plans run
unchanged over a real mesh, equivalence-tested in
tests/test_distributed_8dev.py):

  * **scaling** — shard count k ∈ {1, 2, 4, 8} × schedule ∈
    {allgather, rsag, pmin}, local pruning on: wall time plus the
    per-round reduce wire bytes each schedule puts on the interconnect.
  * **pruning A/B** — at k = 8, every schedule with local pruning off vs
    on: the paper's MRGanter+ claim that per-partition pruning shrinks
    what the reduce moves.  The reduce is sized by the post-prune bucket,
    so pruned candidates never enter the collective.
  * **2-D (candidate × object) A/B** — 8 total devices split obj×cand ∈
    {8×1, 4×2, 2×4} at a fixed per-device chunk budget: the frontier-axis
    decomposition's reduce-bytes/round against the 1-D plan, with the
    concept sets asserted identical before any timing.
  * **async A/B** — every driver × plans {4×1, 8×1, 2×4} under
    ``rounds="sync"`` vs the speculative double-buffered ``"async"``
    scheduler: per-round host-blocked vs dispatch latency split,
    concept sets asserted identical per pair before timing.

Writes BENCH_dist.json; headlines are the pruning byte ratio, the
1-D vs 2-D reduce-bytes ratio under the production rsag schedule, and
the best per-round host-blocked-time reduction from async rounds.
"""

from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.common import row
from repro.core import ClosureEngine, mrcbo, mrganter, mrganter_plus
from repro.core.engine import EngineStats
from repro.data import fca_datasets
from repro.dist.collectives import IMPLS
from repro.dist.shardplan import ShardPlan

ALGOS = {"mrganter+": mrganter_plus, "mrcbo": mrcbo, "mrganter": mrganter}


def _timed_run(ctx, plan: ShardPlan, *, local_prune: bool, keys_out=None) -> dict:
    """Warm-run protocol: one run populates the plan's jit caches, stats
    reset, then the steady-state run is timed.  ``keys_out`` (a list)
    receives the run's concept-key set for pre-timing identity checks."""
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    mrganter_plus(ctx, eng, local_prune=local_prune)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = mrganter_plus(ctx, eng, local_prune=local_prune)
    wall = time.perf_counter() - t0
    if keys_out is not None:
        from repro.core import bitset

        keys_out.append({bitset.key_bytes(y) for y in res.intents})
    st = eng.stats
    rounds = max(1, st.rounds)
    return {
        "plan": plan.describe(),
        "local_prune": local_prune,
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "closures_computed": st.closures_computed,
        "rounds": rounds,
        "reduce_bytes_total": st.modeled_comm_bytes,
        "reduce_bytes_per_round": st.modeled_comm_bytes // rounds,
    }


def _timed_rounds_run(ctx, algo: str, plan: ShardPlan, *, rounds: str,
                      keys_out=None, **kw) -> dict:
    """Warm-run A/B cell for the sync-vs-async round scheduler.

    Same protocol as :func:`_timed_run` but parameterised over driver and
    ``rounds`` mode, and reporting the host-blocked/dispatch latency split
    the speculative scheduler is built to move."""
    fn = ALGOS[algo]
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    fn(ctx, eng, pipeline="device", rounds=rounds, **kw)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = fn(ctx, eng, pipeline="device", rounds=rounds, **kw)
    wall = time.perf_counter() - t0
    if keys_out is not None:
        from repro.core import bitset

        keys_out.append({bitset.key_bytes(y) for y in res.intents})
    st = eng.stats
    nr = max(1, st.rounds)
    return {
        "algorithm": algo,
        "plan": plan.describe(),
        "rounds_mode": rounds,
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "rounds": nr,
        "host_blocked_s_per_round": round(st.host_blocked_s / nr, 6),
        "dispatch_s_per_round": round(st.dispatch_s / nr, 6),
        "d2h_transfers_per_round": round(st.d2h_transfers / nr, 2),
        "modeled_dispatch_bytes_per_round": st.modeled_dispatch_bytes // nr,
        "modeled_collective_bytes_per_round": st.modeled_collective_bytes // nr,
        "spec_rounds": st.spec_rounds,
        "spec_fallbacks": st.spec_fallbacks,
        "spec_discarded": st.spec_discarded,
    }


def run_async_ab(ctx, *, mrganter_cap: int = 40) -> tuple[list[dict], dict]:
    """sync-vs-async A/B over drivers × shard plans (§Async).

    Concept-set identity is asserted per cell pair BEFORE timing is
    reported; MRGanter (one concept per round) is capped so the lectic
    chain doesn't dominate the sweep — both arms get the same cap, so the
    identity check still binds."""
    grid = [
        ("mrganter+", dict(local_prune=True), None),
        ("mrcbo", {}, None),
        ("mrganter", {}, mrganter_cap),
    ]
    plans = ((4, 1), (8, 1), (2, 4))
    records, best = [], 0.0
    for algo, kw, cap in grid:
        for n_obj, n_cand in plans:
            plan_kw = dict(reduce_impl="rsag")
            if n_cand > 1:
                plan_kw["max_batch"] = 1024
            pair, keys = [], []
            for mode in ("sync", "async"):
                plan = ShardPlan.simulated(
                    n_obj, cand_parts=n_cand, **plan_kw
                )
                pair.append(_timed_rounds_run(
                    ctx, algo, plan, rounds=mode, keys_out=keys,
                    max_iterations=cap, **kw,
                ))
            if keys[0] != keys[1]:
                raise RuntimeError(
                    f"async concept set diverged: {algo} {n_obj}x{n_cand}"
                )
            sync_hb = pair[0]["host_blocked_s_per_round"]
            async_hb = pair[1]["host_blocked_s_per_round"]
            reduction = 1.0 - async_hb / max(sync_hb, 1e-12)
            for r in pair:
                r["concept_sets_identical"] = True
                r["host_blocked_reduction"] = round(reduction, 4)
            best = max(best, reduction)
            records.extend(pair)
    headline = {
        "grid": "3 drivers x {4x1, 8x1, 2x4} obj x cand, rsag",
        "host_blocked_reduction_best": round(best, 4),
        "concept_sets_identical": True,  # every pair checked pre-timing
    }
    return records, headline


def run(
    dataset: str = "census-income",
    scale: float = 0.001,
    shard_counts=(1, 2, 4, 8),
    prune_ab_parts: int = 8,
    out_path: str = "BENCH_dist.json",
) -> list[str]:
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)

    scaling = []
    for impl in IMPLS:
        for k in shard_counts:
            plan = ShardPlan.simulated(k, reduce_impl=impl)
            scaling.append(_timed_run(ctx, plan, local_prune=True))

    pruning = []
    for impl in IMPLS:
        plan = ShardPlan.simulated(prune_ab_parts, reduce_impl=impl)
        for prune in (False, True):
            pruning.append(_timed_run(ctx, plan, local_prune=prune))

    # 2-D A/B: 8 total devices split between the object and candidate
    # axes at a fixed per-device chunk budget.  Concept-set identity with
    # the 1-D plan is asserted BEFORE any timing is reported.
    cand_keys: list = []
    cand2d = []
    for n_obj, n_cand in ((8, 1), (4, 2), (2, 4)):
        plan = ShardPlan.simulated(
            n_obj, cand_parts=n_cand, reduce_impl="rsag", max_batch=1024
        )
        cand2d.append(
            _timed_run(ctx, plan, local_prune=True, keys_out=cand_keys)
        )
    cand_identical = all(k == cand_keys[0] for k in cand_keys[1:])
    if not cand_identical:
        raise RuntimeError("1-D vs 2-D concept sets diverged")

    async_ab, async_headline = run_async_ab(ctx)

    def _ab(impl: str) -> tuple[dict, dict]:
        off, on = (
            r for r in pruning if r["plan"]["reduce_impl"] == impl
        )
        return off, on

    off, on = _ab("rsag")
    one_d, best_2d = cand2d[0], min(
        cand2d[1:], key=lambda r: r["reduce_bytes_total"]
    )
    payload = {
        "dataset": dataclasses.asdict(spec),
        "scaling": scaling,
        "pruning_ab": pruning,
        "cand2d_ab": cand2d,
        "async_ab": async_ab,
        "headline_async": async_headline,
        "headline": {
            "plan": f"simulated {prune_ab_parts}-shard, rsag schedule",
            "reduce_bytes_per_round_no_prune": off["reduce_bytes_per_round"],
            "reduce_bytes_per_round_local_prune": on["reduce_bytes_per_round"],
            "reduce_bytes_ratio": round(
                off["reduce_bytes_total"] / max(1, on["reduce_bytes_total"]), 2
            ),
        },
        "headline_2d": {
            "plan_1d": "simulated 8×1 obj shards, rsag",
            "plan_2d": (
                f"simulated {best_2d['plan']['n_parts']}×"
                f"{best_2d['plan']['cand_parts']} obj×cand, rsag"
            ),
            "reduce_bytes_per_round_1d": one_d["reduce_bytes_per_round"],
            "reduce_bytes_per_round_2d": best_2d["reduce_bytes_per_round"],
            "reduce_bytes_ratio_1d_over_2d": round(
                one_d["reduce_bytes_total"]
                / max(1, best_2d["reduce_bytes_total"]),
                2,
            ),
            "concept_sets_identical": cand_identical,  # checked pre-timing
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = []
    for r in scaling:
        p = r["plan"]
        out.append(row(
            f"dist/scaling/{p['reduce_impl']}/k={p['n_parts']}",
            1e6 * r["wall_time_s"],
            f"reduce_B_per_round={r['reduce_bytes_per_round']}"
            f"|concepts={r['n_concepts']}|closures={r['closures_computed']}",
        ))
    for r in pruning:
        p = r["plan"]
        tag = "prune" if r["local_prune"] else "noprune"
        out.append(row(
            f"dist/prune_ab/{p['reduce_impl']}/k={p['n_parts']}/{tag}",
            1e6 * r["wall_time_s"],
            f"reduce_B_per_round={r['reduce_bytes_per_round']}"
            f"|closures={r['closures_computed']}",
        ))
    for r in cand2d:
        p = r["plan"]
        out.append(row(
            f"dist/cand2d/rsag/obj={p['n_parts']}xcand={p['cand_parts']}",
            1e6 * r["wall_time_s"],
            f"reduce_B_per_round={r['reduce_bytes_per_round']}"
            f"|concepts={r['n_concepts']}|rounds={r['rounds']}",
        ))
    out.append(row(
        "dist/headline_prune_bytes_ratio",
        payload["headline"]["reduce_bytes_ratio"],
        f"rsag_k{prune_ab_parts}_noprune_vs_prune|json={out_path}",
    ))
    out.append(row(
        "dist/headline_2d_bytes_ratio",
        payload["headline_2d"]["reduce_bytes_ratio_1d_over_2d"],
        f"rsag_8dev_1d_vs_2d|json={out_path}",
    ))
    for r in async_ab:
        p = r["plan"]
        out.append(row(
            f"dist/async_ab/{r['algorithm']}/"
            f"obj={p['n_parts']}xcand={p['cand_parts']}/{r['rounds_mode']}",
            1e6 * r["wall_time_s"],
            f"host_blocked_s_per_round={r['host_blocked_s_per_round']}"
            f"|dispatch_s_per_round={r['dispatch_s_per_round']}"
            f"|d2h_per_round={r['d2h_transfers_per_round']}"
            f"|spec_fb={r['spec_fallbacks']}",
        ))
    out.append(row(
        "dist/headline_async_host_blocked_reduction",
        async_headline["host_blocked_reduction_best"],
        f"best_cell_sync_vs_async|json={out_path}",
    ))
    return out
