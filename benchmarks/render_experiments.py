"""Regenerate the generated tables in EXPERIMENTS.md from dry-run records.

    PYTHONPATH=src python benchmarks/render_experiments.py
"""

from __future__ import annotations

import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import load_records, roofline_terms  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _fmt_bytes(b):
    return f"{b / 1e9:.2f} GB"


def dryrun_table() -> str:
    rows = [
        "| arch | shape | 16×16 | 2×16×16 | state GB/dev | cache GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    single = {(r["arch"], r["shape"]): r for r in load_records(f"{ROOT}/dryrun_single.jsonl")}
    multi = {(r["arch"], r["shape"]): r for r in load_records(f"{ROOT}/dryrun_multi.jsonl")}
    for key, r in single.items():
        m = multi.get(key, {})
        def status(x):
            s = x.get("status", "—")
            return {"ok": "✅", "skipped": "skip", "error": "❌"}.get(s, s)
        state = r.get("state_bytes_per_device", 0) / 1e9
        cache = r.get("cache_bytes_per_device", 0) / 1e9
        temp = r.get("memory_analysis", {}).get("temp_bytes", 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {status(r)} | {status(m)} "
            f"| {state:.2f} | {cache:.2f} | {temp:.2f} |"
        )
    n_ok = sum(r["status"] == "ok" for r in single.values())
    n_skip = sum(r["status"] == "skipped" for r in single.values())
    n_err = sum(r["status"] == "error" for r in single.values())
    rows.append("")
    rows.append(
        f"Single-pod: **{n_ok} ok / {n_skip} skipped / {n_err} errors**; "
        f"multi-pod: **{sum(r['status'] == 'ok' for r in multi.values())} ok / "
        f"{sum(r['status'] == 'skipped' for r in multi.values())} skipped / "
        f"{sum(r['status'] == 'error' for r in multi.values())} errors**."
    )
    return "\n".join(rows)


def roofline_table() -> str:
    out = []
    base = {(r["arch"], r["shape"]): r for r in load_records(f"{ROOT}/dryrun_baseline.jsonl")}
    for label, path in (
        ("optimized, 16×16 (primary)", "dryrun_single.jsonl"),
        ("optimized, 2×16×16", "dryrun_multi.jsonl"),
    ):
        recs = load_records(os.path.join(ROOT, path))
        out.append(f"\n**{label}**\n")
        out.append(
            "| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| useful/HLO | roofline_frac | vs baseline bound |"
        )
        out.append("|---|---|---|---|---|---|---|---|---|")
        for rec in recs:
            r = roofline_terms(rec)
            if r.get("status") == "skipped":
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | — |")
                continue
            if r.get("status") != "ok":
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | — |")
                continue
            b = base.get((r["arch"], r["shape"]))
            speedup = "—"
            if b is not None and b.get("status") == "ok" and "16×16 (primary)" in label:
                bb = roofline_terms(b)
                bound_b = max(bb["compute_s"], bb["memory_s"], bb["collective_s"])
                bound_o = max(r["compute_s"], r["memory_s"], r["collective_s"])
                speedup = f"{bound_b / bound_o:.1f}×" if bound_o else "—"
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
                f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
                f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
                f"| {r['roofline_fraction']:.4f} | {speedup} |"
            )
    return "\n".join(out)


def fused_ab_table() -> str:
    """§Roofline fused-vs-unfused table from BENCH_frontier.json."""
    with open(f"{ROOT}/BENCH_frontier.json") as f:
        payload = json.load(f)
    ab = payload["fused_ab"]
    rl = ab["roofline"]
    rows = [
        f"One average closure round on the A/B slice "
        f"(B={rl['B']}, N={rl['N']}, W={rl['W']}), VPU-aware model:",
        "",
        "| path | word-ops | HBM bytes | compute_s | memory_s | dominant "
        "| achieved roofline frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for path in ("fused", "unfused"):
        t = rl[path]
        rows.append(
            f"| {path} | {t['word_ops']:,} | {t['hbm_bytes']:,} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| **{t['dominant']}** | {t['achieved_fraction']:.3f} |"
        )
    k_rec, j_rec = ab["records"]
    rows.append("")
    rows.append(
        f"Correctness A/B on `{ab['dataset']['name']}` "
        f"({ab['dataset']['n_objects']} objects × "
        f"{ab['dataset']['n_attrs']} attrs): backend=`kernel` and "
        f"backend=`jnp` produced **identical concept sets** "
        f"({k_rec['n_concepts']} concepts, {k_rec['n_iterations']} "
        f"iterations each).  Interpret-mode wall times "
        f"({k_rec['wall_time_s']:.2f}s vs {j_rec['wall_time_s']:.2f}s) are "
        f"a correctness artifact, not a TPU projection."
    )
    return "\n".join(rows)


def async_ab_table() -> str:
    """§Async sync-vs-async scheduler table from BENCH_dist.json."""
    with open(f"{ROOT}/BENCH_dist.json") as f:
        payload = json.load(f)
    rows = [
        "| driver | plan (obj×cand) | rounds | wall s | host-blocked s/round "
        "| dispatch s/round | D2H xfers/round | spec fb | host-blocked Δ |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in payload["async_ab"]:
        p = r["plan"]
        red = r["host_blocked_reduction"]
        if r["rounds_mode"] != "async":
            delta = "—"
        elif red > 0:
            delta = f"**{red:+.1%}**"
        else:
            delta = f"{red:+.1%}"
        rows.append(
            f"| {r['algorithm']} | {p['n_parts']}×{p['cand_parts']} "
            f"| {r['rounds_mode']} | {r['wall_time_s']:.3f} "
            f"| {r['host_blocked_s_per_round']:.6f} "
            f"| {r['dispatch_s_per_round']:.6f} "
            f"| {r['d2h_transfers_per_round']:.2f} "
            f"| {r['spec_fallbacks']} | {delta} |"
        )
    h = payload["headline_async"]
    rows.append("")
    rows.append(
        f"Headline: best-cell per-round host-blocked reduction "
        f"**{h['host_blocked_reduction_best']:.1%}** (mrganter, all three "
        f"plan geometries land ≥95%); concept sets and iteration counts "
        f"identical for every cell pair (asserted before timing).  "
        f"Positive Δ = async blocked less."
    )
    return "\n".join(rows)


def obs_trace_table() -> str:
    """§Observability sync-vs-async span table from the committed
    TRACE_mine_sync.json / TRACE_mine_async.json timelines."""
    from repro.obs import async_overlaps, span_rollup

    rolls, overlaps = {}, {}
    for mode in ("sync", "async"):
        with open(f"{ROOT}/TRACE_mine_{mode}.json") as f:
            obj = json.load(f)
        rolls[mode] = span_rollup(obj["traceEvents"])
        overlaps[mode] = async_overlaps(obj)
    names = sorted(set(rolls["sync"]) | set(rolls["async"]))
    rows = [
        "| span | sync count | sync p50 ms | sync p95 ms "
        "| async count | async p50 ms | async p95 ms |",
        "|---|---|---|---|---|---|---|",
    ]
    for name in names:
        cells = []
        for mode in ("sync", "async"):
            r = rolls[mode].get(name)
            if r is None:
                cells += ["—", "—", "—"]
            else:
                cells += [str(r["count"]), f"{r['p50_s'] * 1e3:.2f}",
                          f"{r['p95_s'] * 1e3:.2f}"]
        rows.append(f"| `{name}` | " + " | ".join(cells) + " |")
    n_ov = len(overlaps["async"])
    n_spec = sum(
        o["span"].startswith("spec/dispatch") for o in overlaps["async"]
    )
    rows.append("")
    rows.append(
        f"Overlap census: the sync timeline has "
        f"**{len(overlaps['sync'])}** spans beginning inside an in-flight "
        f"round window (a strict staircase), the async timeline has "
        f"**{n_ov}** — including **{n_spec}** `spec/dispatch[r+1]` spans "
        f"inside round r's window, the speculative scheduler's signature "
        f"(`python -m repro.obs TRACE_mine_async.json "
        f"--expect-async-overlap` asserts it)."
    )
    return "\n".join(rows)


def serve_load_table() -> str:
    """§Serving-load QPS-vs-percentile table from BENCH_serve_load.json."""
    with open(f"{ROOT}/BENCH_serve_load.json") as f:
        payload = json.load(f)

    def ms(v):
        return "—" if v is None else f"{v * 1e3:.2f}"

    rows = [
        f"Open-loop Poisson arrivals on `{payload['dataset']['name']}` "
        f"({payload['concepts']} concepts, "
        f"{payload['workload']['slots']}-slot micro-batches, "
        f"{payload['workload']['max_wait_ms']:g} ms admission deadline); "
        f"offered load as a fraction of the calibrated "
        f"{payload['calibrated_ceiling_qps']:g} q/s zero-queueing ceiling:",
        "",
        "| offered | offered q/s | achieved q/s | e2e p50 ms | p95 ms "
        "| p99 ms | shed | occupancy | SLO |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for g in payload["grid"]:
        e = g["e2e"]
        verdict = "✅" if g.get("slo", {}).get("ok") else "❌"
        rows.append(
            f"| {g['offered_fraction']:g}× | {g['offered_qps']:g} "
            f"| {g['achieved_qps']:g} | {ms(e.get('p50'))} "
            f"| {ms(e.get('p95'))} | {ms(e.get('p99'))} "
            f"| {g['shed_rate']:.1%} | {g['occupancy_mean']:.0%} "
            f"| {verdict} |"
        )
    h = payload["headline"]
    churn = payload["update_churn"]
    rows.append("")
    knee = payload.get("saturation_knee_fraction")
    rows.append(
        f"Headline: **{h['sustained_qps']:g} q/s sustained** at "
        f"{h['offered_fraction']:g}× the ceiling with p99 "
        f"{ms(h['e2e_p99_s'])} ms and {h['shed_rate']:.1%} shed; "
        + (f"the saturation knee appears at {knee:g}× offered load.  "
           if knee is not None else "no saturation knee inside the grid.  ")
        + f"Queue answers are **bit-identical** to pre-formed batches "
        f"(asserted: `{h['bit_identical']}`).  Update churn "
        f"({churn['updates']} snapshot commits mid-load) is reported "
        f"separately — the first query after a swap blocks on the staged "
        f"snapshot's O(C²) order-table rebuild, so its e2e p99 of "
        f"{ms(churn['e2e'].get('p99'))} ms measures commit stalls, not "
        f"steady-state serving."
    )
    return "\n".join(rows)


def inject(md: str, marker: str, content: str) -> str:
    block = f"<!-- {marker} -->\n{content}\n<!-- /{marker} -->"
    if f"<!-- /{marker} -->" in md:
        return re.sub(
            rf"<!-- {marker} -->.*?<!-- /{marker} -->", block, md, flags=re.S
        )
    return md.replace(f"<!-- {marker} -->", block)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(path).read()
    # Each table renders from its own artifact; a missing artifact skips
    # that table (with a note) instead of aborting the whole regeneration.
    for marker, builder in (
        ("DRYRUN_TABLE", dryrun_table),
        ("ROOFLINE_TABLE", roofline_table),
        ("FUSED_AB_TABLE", fused_ab_table),
        ("ASYNC_AB_TABLE", async_ab_table),
        ("OBS_TRACE_TABLE", obs_trace_table),
        ("SERVE_LOAD_TABLE", serve_load_table),
    ):
        try:
            md = inject(md, marker, builder())
        except FileNotFoundError as e:
            print(f"skip {marker}: missing artifact ({e.filename})")
    open(path, "w").write(md)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
