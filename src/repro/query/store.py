"""ConceptStore — the mined lattice as a device-resident, queryable artifact.

The store owns one :class:`repro.dist.ShardPlan` (normally the same plan
that mined the intents) and keeps two kinds of state:

  * **object-sharded** — the packed context rows (``plan.place_rows``, the
    engine's placement) and the extent table ``ext_cols [N_pad, Wc]``:
    word ``wc`` of object ``g`` packs membership bits "g ∈ extent(c)" for
    concepts ``c ∈ [32·wc, 32·wc+32)``.  Extent queries and the streaming
    support recount run over these shards (one collective per batch).
  * **replicated snapshot** — a :class:`Snapshot`: the intent table in
    canonical index order, supports, the two-level hash index
    (head-attr × popcount, :mod:`repro.core.hashindex`) flattened to a
    sorted key array for two-sided ``searchsorted`` bucket probes, and the
    packed order tables (sub/superconcept sets + the covering relation)
    materialized by the subset-test matmul of :mod:`repro.core.lattice`'s
    jnp twin below.

Snapshots are immutable and double-buffered: :class:`repro.query.stream.
StreamUpdater` stages a successor while queries keep serving the active
one; ``commit()`` swaps a single reference.  Concept ids are positions in
the snapshot's canonical order and are only meaningful together with
``snapshot.version``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitset, hashindex, incremental
from repro.core.closure import batched_closure_np
from repro.core.context import FormalContext
from repro.dist.shardplan import ShardPlan
from repro.kernels.ops import bucket_size


# ---------------------------------------------------------------------------
# device primitives (jnp twins of the host index/lattice machinery)
# ---------------------------------------------------------------------------


def popcount_jnp(x: jax.Array) -> jax.Array:
    """Per-set popcount of packed ``[..., W]`` uint32 sets → int32."""
    return lax.population_count(x.astype(jnp.uint32)).sum(
        axis=-1, dtype=jnp.int32
    )


def pack_bool_jnp(dense: jax.Array) -> jax.Array:
    """Pack a bool array ``[..., 32·Wc]`` into ``[..., Wc]`` uint32 words
    (device twin of ``bitset.pack_bool``; the last dim must already be a
    multiple of 32)."""
    *lead, n = dense.shape
    b = dense.reshape(*lead, n // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (b.astype(jnp.uint32) * weights).sum(axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_attrs",))
def order_tables_jnp(intents: jax.Array, n_concepts, *, n_attrs: int):
    """Subset-test matmul → packed order tables, all on device.

    ``leq[i, j] = intent_i ⊆ intent_j`` via one popcount matmul over the
    unpacked bit-planes; the covering relation is the transitive reduction
    ``strict & ~(strict ∘ strict)`` (second matmul) — the device twin of
    ``repro.core.lattice.subset_matrix`` / ``covering_matmul``.

    Returns ``(sub_rows, sup_rows, children_rows, parents_rows)``, each
    ``[Cb, Wc]`` uint32 with ``Wc = Cb/32``: row ``c`` packs, over concept
    ids ``d``, the strict subconcepts of ``c`` (``intent_c ⊂ intent_d``),
    its strict superconcepts, the concepts ``c`` covers (the
    ``ConceptLattice.children`` convention: ``d``'s intent ⊂ ``c``'s with
    nothing between) and the concepts covering ``c``.
    """
    Cb, W = intents.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((intents[:, :, None] >> shifts) & jnp.uint32(1)).reshape(Cb, W * 32)
    bits = bits[:, :n_attrs].astype(jnp.float32)
    sizes = bits.sum(axis=1)
    inter = bits @ bits.T  # [Cb, Cb] — |y_i ∩ y_j|
    valid = jnp.arange(Cb) < n_concepts
    leq = (inter == sizes[:, None]) & valid[:, None] & valid[None, :]
    strict = leq & ~jnp.eye(Cb, dtype=bool)
    via = (strict.astype(jnp.float32) @ strict.astype(jnp.float32)) > 0
    cover = strict & ~via  # cover[d, c]: d ∈ children[c]
    sub_rows = pack_bool_jnp(strict)  # row c: {d : intent_c ⊂ intent_d}
    sup_rows = pack_bool_jnp(strict.T)  # row c: {d : intent_d ⊂ intent_c}
    children_rows = pack_bool_jnp(cover.T)
    parents_rows = pack_bool_jnp(cover)
    return sub_rows, sup_rows, children_rows, parents_rows


@functools.partial(jax.jit, static_argnames=("n_attrs", "probe"))
def lookup_ids_jnp(
    queries: jax.Array,
    intents: jax.Array,
    skeys: jax.Array,
    n_concepts,
    *,
    n_attrs: int,
    probe: int,
) -> jax.Array:
    """Two-level-hash concept lookup for a batch of (closed) intents.

    Level-1/level-2 keys (head attribute, popcount) flatten to
    ``hashindex.bucket_key``; the snapshot's intent table is sorted by that
    key, so the bucket is one ``searchsorted`` plus a static ``probe``-wide
    window scan (``probe`` ≥ the snapshot's widest bucket) — O(probe·W)
    per query instead of O(C·W).  Returns concept ids, -1 for misses.
    """
    heads = hashindex.batch_heads_jnp(queries)
    lengths = popcount_jnp(queries)
    keys = hashindex.bucket_key(heads, lengths, n_attrs).astype(skeys.dtype)
    lo = jnp.searchsorted(skeys, keys, side="left")
    window = lo[:, None] + jnp.arange(probe)[None, :]  # [B, probe]
    safe = jnp.clip(window, 0, intents.shape[0] - 1)
    hit = (
        (window < n_concepts)
        & (skeys[safe] == keys[:, None])
        & jnp.all(intents[safe] == queries[:, None, :], axis=-1)
    )
    return jnp.max(jnp.where(hit, window, -1), axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable, device-resident lattice version.

    Replicated arrays are padded to ``cap`` (a power of two ≥ 32, so the
    packed order tables stay word-aligned); rows past ``n_concepts`` are
    padding every query masks by id.  ``ext_cols`` is the object-sharded
    extent table (see module docstring) riding with the snapshot because a
    staged update grows it together with the intent set.
    """

    version: int
    n_concepts: int
    cap: int
    max_bucket: int
    intents: jax.Array  # [cap, W] uint32, canonical (bucket-key) order
    supports: jax.Array  # [cap] int32
    skeys: jax.Array  # [cap] int32, ascending; pads = int32 max
    sub_rows: jax.Array  # [cap, Wc]
    sup_rows: jax.Array  # [cap, Wc]
    children_rows: jax.Array  # [cap, Wc]
    parents_rows: jax.Array  # [cap, Wc]
    ext_cols: jax.Array  # object-sharded [N_pad, Wc]
    intents_np: np.ndarray  # [C, W] host copy (oracles, export)
    supports_np: np.ndarray  # [C]

    @property
    def probe(self) -> int:
        """Static bucket-scan window for ``lookup_ids_jnp``."""
        return bucket_size(max(1, self.max_bucket), minimum=4)


def canonical_order(intents: np.ndarray, n_attrs: int) -> np.ndarray:
    """Sort permutation for the snapshot's canonical concept order:
    ascending two-level bucket key, packed words as the tiebreak."""
    heads = hashindex.batch_heads(intents)
    lengths = bitset.popcount(intents)
    keys = hashindex.bucket_key(heads, lengths, n_attrs)
    words = tuple(intents[:, w] for w in reversed(range(intents.shape[1])))
    return np.lexsort(words + (keys,))


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StoreState:
    """Everything one store version consists of: the context, its device
    placement, and the snapshot built against it.  Immutable — a commit
    swaps the store's single reference to one of these, so a concurrent
    query batch reads a consistent (rows, snapshot) pair no matter when
    the swap lands."""

    ctx: FormalContext
    rows: jax.Array
    n_pad: int
    N_padded: int
    snapshot: Snapshot | None


class ConceptStore:
    """Device-resident concept store over one ShardPlan.

    ``build`` places the context once (the mining engine's placement can be
    reused by passing its plan) and materializes the first snapshot; the
    store then serves :class:`repro.query.engine.QueryEngine` reads and
    :class:`repro.query.stream.StreamUpdater` writes.
    """

    def __init__(self, ctx: FormalContext, plan: ShardPlan | None = None):
        self.plan = plan or ShardPlan.simulated(1)
        rows, n_pad = ctx.padded_rows(self.plan.row_alignment)
        self._state = StoreState(
            ctx=ctx,
            rows=self.plan.place_rows(rows),
            n_pad=n_pad,
            N_padded=rows.shape[0],
            snapshot=None,
        )
        self._ext_step = self._build_ext_step()
        self._sup_step = None  # supports-only twin, built on first filter
        self._staged: StoreState | None = None

    # one consistent view per read — query batches grab this once
    @property
    def state(self) -> StoreState:
        return self._state

    @property
    def ctx(self) -> FormalContext:
        return self._state.ctx

    @property
    def rows(self) -> jax.Array:
        return self._state.rows

    @property
    def n_pad(self) -> int:
        return self._state.n_pad

    @property
    def N_padded(self) -> int:
        return self._state.N_padded

    @property
    def snapshot(self) -> Snapshot | None:
        return self._state.snapshot

    # -- construction ------------------------------------------------------

    @classmethod
    def build(
        cls,
        ctx: FormalContext,
        intents,
        *,
        plan: ShardPlan | None = None,
        min_support: int | None = None,
    ) -> "ConceptStore":
        """``min_support`` keeps only the frequent (iceberg) concepts — one
        SPMD support pass filters before the snapshot materializes."""
        store = cls(ctx, plan)
        arr = (
            incremental.as_intent_array(intents)
            if len(intents)
            else np.zeros((0, ctx.W), np.uint32)  # iceberg can mine nothing
        )
        arr = np.unique(arr, axis=0)
        if min_support is not None and arr.shape[0]:
            C = arr.shape[0]
            buf = np.full(
                (bucket_size(C, minimum=8), ctx.W), 0xFFFFFFFF, np.uint32
            )
            buf[:C] = arr
            sups = store._supports_only(buf, store.rows, ctx.n_objects)
            arr = arr[sups[:C] >= int(min_support)]
        store._state = dataclasses.replace(
            store._state, snapshot=store.make_snapshot(arr, version=0)
        )
        return store

    def iceberg(self, min_support: int) -> "ConceptStore":
        """A new store over the same context/plan serving only the active
        snapshot's concepts with support ≥ ``min_support`` — the
        iceberg-filtered view (supports come from the snapshot; no
        recount decides membership)."""
        snap = self.snapshot
        if snap is None:
            raise RuntimeError("no active snapshot to filter")
        store = ConceptStore(self.ctx, self.plan)
        keep = snap.intents_np[snap.supports_np >= int(min_support)]
        store._state = dataclasses.replace(
            store._state,
            snapshot=store.make_snapshot(keep, version=snap.version),
        )
        return store

    def make_snapshot(
        self,
        intents_np: np.ndarray,
        *,
        version: int,
        rows_dev: jax.Array | None = None,
        ctx: FormalContext | None = None,
    ) -> Snapshot:
        """Materialize a snapshot for ``intents_np`` (distinct, unordered).

        ``rows_dev``/``ctx`` default to the store's active context; the
        stream updater passes the staged (grown) ones.  Extent columns
        and supports come from one mixed-out-spec plan-SPMD region per
        concept chunk (``_build_ext_step`` — the extent pack stays on the
        shards; padded context rows are masked by global row index, no
        pad correction needed); the order tables are two device matmuls
        (``order_tables_jnp``).
        """
        ctx = ctx or self.ctx
        rows_dev = self.rows if rows_dev is None else rows_dev
        m, W = ctx.n_attrs, ctx.W

        perm = canonical_order(intents_np, m)
        arr = intents_np[perm]
        C = arr.shape[0]
        cap = bucket_size(C, minimum=32)
        heads = hashindex.batch_heads(arr)
        lengths = bitset.popcount(arr)
        keys = hashindex.bucket_key(heads, lengths, m).astype(np.int32)
        max_bucket = int(np.bincount(keys - keys.min()).max()) if C else 1

        buf = np.full((cap, W), 0xFFFFFFFF, np.uint32)
        buf[:C] = arr
        skeys = np.full((cap,), np.iinfo(np.int32).max, np.int32)
        skeys[:C] = keys

        plan = self.plan
        intents_dev = plan.replicate(buf)
        skeys_dev = plan.replicate(skeys)

        # Extent table + supports from ONE mixed-out-spec SPMD pass per
        # concept chunk: each region's subset-test matrix yields the
        # object-sharded packed extent columns (ext_cols[g, wc] packs
        # g ∈ extent(c) over the 32 concepts of word wc — staying on the
        # shards, never visiting the host) and the psum-reduced supports.
        # Padded intents are all-ones: only padded (all-ones) context rows
        # could contain them, and those are masked by the global row index,
        # so pad concepts get zero columns and zero support.
        ext_cols, sup_buf = self._ext_supports(buf, rows_dev, ctx.n_objects)
        supports = sup_buf[:C]

        tables = order_tables_jnp(intents_dev, jnp.int32(C), n_attrs=m)
        sub_rows, sup_rows, children_rows, parents_rows = (
            plan.replicate(t) for t in tables
        )

        return Snapshot(
            version=version,
            n_concepts=C,
            cap=cap,
            max_bucket=max(1, max_bucket),
            intents=intents_dev,
            supports=plan.replicate(sup_buf),
            skeys=skeys_dev,
            sub_rows=sub_rows,
            sup_rows=sup_rows,
            children_rows=children_rows,
            parents_rows=parents_rows,
            ext_cols=ext_cols,
            intents_np=arr,
            supports_np=supports,
        )

    # -- device extent build + support recount (mixed out-spec regions) -----

    def _build_ext_step(self):
        """One SPMD region: per-shard subset test of a concept chunk
        against the local context rows → (packed extent columns, staying
        object-sharded via the plan's mixed ``out_shard``; supports,
        psum-reduced and replicated).  The ROADMAP's device-side extent
        build: the pack never round-trips through the host."""
        plan = self.plan
        axes = plan.reduce_axes

        def body(rows_local, cands, n_objects):
            # [Nl, B]: concept c's intent ⊆ row g  ⟺  g ∈ extent(c)
            sub = self._masked_subset(rows_local, cands, n_objects)
            supports = lax.psum(sub.sum(axis=0, dtype=jnp.int32), axes)
            return pack_bool_jnp(sub), supports

        return jax.jit(plan.spmd(body, n_rep=2, out_shard=(True, False)))

    def _masked_subset(self, rows_local, cands, n_objects):
        """``sub[g, c] = intent_c ⊆ row_g`` for the local shard, with the
        padded context rows masked out via the global row index — the one
        kernel both the extent build and the supports-only filter share."""
        n_local = rows_local.shape[0]
        sub = jnp.all(
            (cands[None, :, :] & ~rows_local[:, None, :]) == 0, axis=-1
        )
        start = self.plan.shard_index() * n_local
        real = (start + jnp.arange(n_local)) < n_objects
        return sub & real[:, None]

    def _supports_only(
        self, buf: np.ndarray, rows_dev: jax.Array, n_objects: int
    ) -> np.ndarray:
        """Psum support recount without the extent pack — the cheap kernel
        for pre-snapshot filters (``build(min_support=...)``), where the
        extents of dropped concepts would be thrown away."""
        if self._sup_step is None:
            plan = self.plan
            axes = plan.reduce_axes

            def body(rows_local, cands, n_objects):
                sub = self._masked_subset(rows_local, cands, n_objects)
                return lax.psum(sub.sum(axis=0, dtype=jnp.int32), axes)

            self._sup_step = jax.jit(plan.spmd(body, n_rep=2))
        cap = buf.shape[0]
        step = min(cap, 4096)
        parts = []
        for lo in range(0, cap, step):
            parts.append(np.asarray(self._sup_step(
                rows_dev, jnp.asarray(buf[lo : lo + step]),
                jnp.int32(n_objects),
            )))
        return np.concatenate(parts)

    def _ext_supports(
        self, buf: np.ndarray, rows_dev: jax.Array, n_objects: int
    ) -> tuple[jax.Array, np.ndarray]:
        """Extent columns + supports for a padded intent table ``buf``
        [cap, W] (cap a power of two ≥ 32; pad rows all-ones).  Chunks of
        ≤4096 concepts bound the per-region subset matrix; chunk columns
        concatenate on device in the plan's sharded row layout."""
        cap = buf.shape[0]
        step = min(cap, 4096)
        ext_parts, sup_parts = [], []
        for lo in range(0, cap, step):
            ext, sup = self._ext_step(
                rows_dev,
                jnp.asarray(buf[lo : lo + step]),
                jnp.int32(n_objects),
            )
            ext_parts.append(ext)
            sup_parts.append(np.asarray(sup))
        ext_cols = (
            ext_parts[0]
            if len(ext_parts) == 1
            else jnp.concatenate(ext_parts, axis=-1)
        )
        return ext_cols, np.concatenate(sup_parts)

    # -- double-buffered commit protocol -----------------------------------

    def stage(self, state: StoreState):
        """Install a staged successor; the active snapshot keeps serving."""
        self._staged = state

    def commit(self) -> Snapshot:
        """Atomically swap the staged state in (one reference assignment —
        an in-flight query batch finishes on whichever state it read)."""
        if self._staged is None:
            raise RuntimeError("no staged update to commit")
        self._state, self._staged = self._staged, None
        return self._state.snapshot

    # -- introspection ------------------------------------------------------

    def describe(self) -> dict:
        snap = self.snapshot
        return {
            "plan": self.plan.describe(),
            "objects": self.ctx.n_objects,
            "attrs": self.ctx.n_attrs,
            "version": None if snap is None else snap.version,
            "concepts": None if snap is None else snap.n_concepts,
            "cap": None if snap is None else snap.cap,
            "max_bucket": None if snap is None else snap.max_bucket,
        }


def host_supports(ctx: FormalContext, intents_np: np.ndarray) -> np.ndarray:
    """Host oracle for the SPMD support recount (tests/benchmarks)."""
    _, s = batched_closure_np(ctx.rows, intents_np, ctx.attr_mask())
    return s.astype(np.int32)
