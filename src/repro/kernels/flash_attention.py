"""Pallas TPU flash-attention (forward) — the §Roofline next lever.

EXPERIMENTS.md §Roofline identifies the flash softmax chain's elementwise
HBM traffic as the dominant term for most train/prefill cells; this kernel
is the fix on real hardware: scores/probabilities live only in VMEM, HBM
sees q/k/v/out once.

Structure (classic TPU flash forward):

  * grid = (B·H, S/q_blk, T/kv_blk) — kv is the last (sequential) axis, so
    the fp32 running (m, l, acc) scratch persists across kv steps for a
    fixed (head, q-block); initialized at ki == 0, emitted at the last step.
  * GQA without materializing repeated K/V: the k/v BlockSpec index_map
    folds the q-head → kv-head mapping (h // G), so each grid step reads
    the right shared KV block directly from HBM.
  * causal masking, sliding windows, and gemma2-style logit softcaps are
    computed from block coordinates; fully-masked blocks short-circuit via
    ``pl.when`` (scores never computed).

Supports the serving/prefill forward; the training path would need the
matching backward kernel (dq/dk/dv with recomputed probabilities) — left
as the documented next step; the pure-jnp `blockwise_attention` remains
the differentiable path.

Validated in interpret mode against a plain-softmax oracle (`ref.py`) over
shape/window/softcap sweeps.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30
DEFAULT_Q_BLK = 128
DEFAULT_KV_BLK = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, logit_cap, kv_blk, q_blk, seq_len,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_blk
    kv_start = ki * kv_blk
    # Entire block strictly above the diagonal ⇒ skip (causal).
    run = (not causal) or (kv_start <= q_start + q_blk - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # [q_blk, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [kv_blk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [q_blk, kv_blk]
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
        valid = kv_pos < seq_len
        if causal:
            valid &= kv_pos <= q_pos
        if window is not None:
            valid &= q_pos - kv_pos < window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_cap", "q_blk", "kv_blk", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # [B, H, S, hd]
    k: jax.Array,  # [B, KV, T, hd]
    v: jax.Array,  # [B, KV, T, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_blk: int = DEFAULT_Q_BLK,
    kv_blk: int = DEFAULT_KV_BLK,
    interpret: bool = True,
) -> jax.Array:
    """Returns [B, H, S, hd].  S/T padded internally to block multiples."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"H={H} must be a multiple of KV={KV}")
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    q_blk = min(q_blk, max(8, S))
    kv_blk = min(kv_blk, max(8, T))
    s_pad, t_pad = -S % q_blk, -T % kv_blk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    Sp, Tp = S + s_pad, T + t_pad

    qf = q.reshape(B * H, Sp, hd)
    grid = (B * H, Sp // q_blk, Tp // kv_blk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, logit_cap=logit_cap,
        kv_blk=kv_blk, q_blk=q_blk, seq_len=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
            # GQA: fold q-head → kv-head into the index_map (h // G).
            pl.BlockSpec(
                (1, 1, kv_blk, hd),
                lambda bh, qi, ki, H=H, G=G: (bh // H, (bh % H) // G, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, kv_blk, hd),
                lambda bh, qi, ki, H=H, G=G: (bh // H, (bh % H) // G, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(B, H, Sp, hd)[:, :, :S, :]
