"""arctic-480b [moe] — 128 experts top-2 with a dense residual FFN in
parallel (dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base]."""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual branch
    vocab_size=32_000,
    head_dim=128,
    rope_kind="standard",
    rope_theta=10_000.0,
    mlp_kind="swiglu",
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,
    ),
)
