"""Fused frontier & serving kernels ≡ jnp oracles (interpret mode).

The engine's ``backend="kernel"`` routes every ``_frontier_cache`` step
variant through the fused Pallas kernels (repro.kernels.frontier) and the
query engine's batched serving paths through repro.kernels.serve.  The
jnp builders stay in the tree as bit-exact oracles — every test here is
an equality assertion against them, across drivers, object-shard counts
and candidate-shard counts.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClosureEngine, FormalContext, mrcbo, mrganter_plus
from repro.core.closure import batched_closure_np
from repro.dist.shardplan import ShardPlan
from repro.kernels import frontier as fkern
from repro.kernels import serve as skern
from repro.query import ConceptStore, QueryEngine
from repro.query.engine import QueryConfig
from repro.rules import RuleIndex, mine_iceberg
from repro.rules.basis import extract_bases


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(60, 24, 0.35, seed=42)


def _sorted_intents(intents):
    arr = np.stack([np.asarray(y, dtype=np.uint32) for y in intents])
    return arr[np.lexsort(arr.T[::-1])]


# ---------------------------------------------------------------------------
# Direct kernel-vs-oracle unit tests
# ---------------------------------------------------------------------------


def _fused_case(N=100, m=40, B=16, seed=7, block_n=64):
    ctx = FormalContext.synthetic(N, m, 0.3, seed=seed)
    cands = FormalContext.synthetic(B, m, 0.1, seed=seed + 1).rows
    rows_p, n_pad = ctx.padded_rows(block_n)
    oc, os_ = batched_closure_np(ctx.rows, cands, ctx.attr_mask())
    mask = jnp.asarray(ctx.attr_mask()[None, :])
    return ctx, jnp.asarray(rows_p), jnp.asarray(cands), mask, n_pad, oc, os_


def test_fused_plain_matches_oracle():
    ctx, rows, cands, mask, n_pad, oc, os_ = _fused_case()
    gc, sup, keep = fkern.fused_closure_call(
        rows, cands, mask, fkern.pack_scalars(cands.shape[0], 0, n_pad, 0),
        block_n=64,
    )
    np.testing.assert_array_equal(np.asarray(gc), oc)
    np.testing.assert_array_equal(np.asarray(sup), os_)
    assert np.asarray(keep).all()


def test_fused_iceberg_matches_oracle():
    ctx, rows, cands, mask, n_pad, oc, os_ = _fused_case()
    for min_sup in (1, 5, ctx.n_objects + 1):
        gc, sup, keep = fkern.fused_closure_call(
            rows, cands, mask,
            fkern.pack_scalars(cands.shape[0], min_sup, n_pad, 0),
            iceberg=True, block_n=64,
        )
        np.testing.assert_array_equal(np.asarray(sup), os_)
        np.testing.assert_array_equal(np.asarray(keep), os_ >= min_sup)
        # closures are computed for every candidate; ``keep`` is the only
        # filter signal — compaction happens downstream of the kernel
        np.testing.assert_array_equal(np.asarray(gc), oc)


def test_fused_validity_window_and_row_off():
    """Candidates at chunk-global index ≥ n_valid are masked out; row_off
    shifts the block's window exactly like the 2-D per-block offset."""
    ctx, rows, cands, mask, n_pad, oc, os_ = _fused_case()
    B = cands.shape[0]
    n_valid = B - 3
    gc, sup, keep = fkern.fused_closure_call(
        rows, cands, mask, fkern.pack_scalars(n_valid, 0, n_pad, 0),
        block_n=64,
    )
    np.testing.assert_array_equal(
        np.asarray(keep), np.arange(B) < n_valid
    )
    np.testing.assert_array_equal(np.asarray(gc), oc)
    # row_off: this block covers chunk rows [off, off+B) of a longer batch
    off = 8
    _, _, keep2 = fkern.fused_closure_call(
        rows, cands, mask, fkern.pack_scalars(n_valid, 0, n_pad, off),
        block_n=64,
    )
    np.testing.assert_array_equal(
        np.asarray(keep2), (np.arange(B) + off) < n_valid
    )


def test_map_plus_filter_equals_fused():
    """Mode B decomposition (map kernel → filter kernel) reproduces the
    fully fused Mode A outputs when run on the whole context."""
    ctx, rows, cands, mask, n_pad, oc, os_ = _fused_case()
    B = cands.shape[0]
    min_sup = 4
    gc_f, sup_f, keep_f = fkern.fused_closure_call(
        rows, cands, mask, fkern.pack_scalars(B, min_sup, n_pad, 0),
        iceberg=True, block_n=64,
    )
    loc, raw = fkern.map_closure_call(rows, cands, mask, block_n=64)
    raw = raw - n_pad  # pad correction rides the reduce in Mode B
    sup_m, keep_m = fkern.filter_call(
        loc, raw, fkern.pack_scalars(B, min_sup, 0, 0), iceberg=True,
    )
    np.testing.assert_array_equal(np.asarray(loc), np.asarray(gc_f))
    np.testing.assert_array_equal(np.asarray(sup_m), np.asarray(sup_f))
    np.testing.assert_array_equal(np.asarray(keep_m), np.asarray(keep_f))


def test_supports_fused_gate():
    assert fkern.supports_fused("kernel", 4)
    assert fkern.supports_fused("kernel", fkern.MAX_W)
    assert not fkern.supports_fused("kernel", fkern.MAX_W + 1)
    assert not fkern.supports_fused("jnp", 4)
    assert not fkern.supports_fused("matmul", 4)


# ---------------------------------------------------------------------------
# Pipeline property tests: every driver/variant, 1-D and 2-D plans
# ---------------------------------------------------------------------------

DRIVERS = [
    ("mrganter+", lambda c, e: mrganter_plus(c, e, pipeline="device")),
    ("mrganter+dc", lambda c, e: mrganter_plus(
        c, e, pipeline="device", dedupe_candidates=True)),
    ("mrganter+dc+dz", lambda c, e: mrganter_plus(
        c, e, pipeline="device", dedupe_candidates=True,
        dedupe_closures=True)),
    ("mrganter+iceberg", lambda c, e: mrganter_plus(
        c, e, pipeline="device", dedupe_candidates=True, min_support=6)),
    ("mrcbo", lambda c, e: mrcbo(c, e, pipeline="device")),
    ("mrcbo+iceberg", lambda c, e: mrcbo(
        c, e, pipeline="device", min_support=6)),
]


@pytest.mark.parametrize("name,run", DRIVERS, ids=[d[0] for d in DRIVERS])
@pytest.mark.parametrize("n_parts,cand_parts", [
    (1, 1), (2, 1), (1, 2), (2, 2),
])
def test_kernel_backend_equals_jnp(ctx, name, run, n_parts, cand_parts):
    results = {}
    for backend in ("kernel", "jnp"):
        plan = ShardPlan.simulated(
            n_parts, cand_parts=cand_parts, block_n=64
        )
        eng = ClosureEngine(ctx, plan=plan, backend=backend)
        results[backend] = run(ctx, eng)
    rk, rj = results["kernel"], results["jnp"]
    assert rk.n_concepts == rj.n_concepts
    assert rk.n_iterations == rj.n_iterations
    np.testing.assert_array_equal(
        _sorted_intents(rk.intents), _sorted_intents(rj.intents)
    )


# ---------------------------------------------------------------------------
# Serving kernels: QueryEngine backend="kernel" ≡ backend="jnp"
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served(ctx):
    plan = ShardPlan.simulated(2, block_n=64)
    eng = ClosureEngine(ctx, plan=plan, backend="jnp")
    res = mine_iceberg(ctx, eng, min_support=4)
    out = {}
    for backend in ("kernel", "jnp"):
        store = ConceptStore.build(
            ctx, res.intents, plan=ShardPlan.simulated(2, block_n=64)
        )
        out[backend] = QueryEngine(
            store, QueryConfig(slots=8, backend=backend)
        )
    return out


def _queries(ctx, n=11, seed=0):
    rng = np.random.default_rng(seed)
    return ctx.rows[rng.integers(0, ctx.n_objects, n)]


@pytest.mark.parametrize("k", [1, 3, 7])
def test_serve_topk_kernel_equals_jnp(ctx, served, k):
    qs = _queries(ctx)
    ik, vk = served["kernel"].topk_batch(qs, k=k)
    ij, vj = served["jnp"].topk_batch(qs, k=k)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ij))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vj))


def test_serve_closure_batch_kernel_equals_jnp(ctx, served):
    qs = _queries(ctx, n=9, seed=3)
    for a, b in zip(
        served["kernel"].closure_batch(qs), served["jnp"].closure_batch(qs)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("rank_by,k", [
    ("confidence", 1), ("confidence", 4), ("lift", 4),
])
def test_serve_rules_kernel_equals_jnp(ctx, served, rank_by, k):
    store = served["jnp"].store
    basis = extract_bases(store, min_conf=0.4)
    index = RuleIndex.build(basis, plan=ShardPlan.simulated(2, block_n=64))
    qs = _queries(ctx, n=6, seed=5)
    outs = {
        b: served[b].rules_batch(
            index, qs, k=k, min_conf=0.4, rank_by=rank_by
        )
        for b in ("kernel", "jnp")
    }
    for a, b in zip(outs["kernel"], outs["jnp"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
