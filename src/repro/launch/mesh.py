"""Mesh construction for the production pods and local testing.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax call, and eager mesh construction here would break that.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(
    data: int | None = None, model: int = 1, pod: int = 1, cand: int = 1
):
    """Mesh over whatever devices exist (CPU tests: 1 or 8 fake devices).

    ``cand > 1`` prepends a candidate axis (the FCA ShardPlan's 2-D
    frontier-axis decomposition picks it up by name)."""
    n = len(jax.devices())
    if data is None:
        data = n // (model * pod * cand)
    dims = []
    if cand > 1:
        dims.append(("cand", cand))
    if pod > 1:
        dims.append(("pod", pod))
    dims += [("data", data), ("model", model)]
    return compat.make_mesh(
        tuple(s for _, s in dims), tuple(a for a, _ in dims)
    )


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes present in this mesh (pod first).

    Same vocabulary the FCA ShardPlan uses for its object partition —
    one definition, shared via repro.dist.partition.
    """
    from repro.dist.partition import object_axes

    return object_axes(mesh)
