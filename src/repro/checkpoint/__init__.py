from repro.checkpoint.ckpt import (
    CheckpointManager,
    have_zstd,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "have_zstd",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
