"""repro.rules — distributed iceberg mining and basis extraction.

Turns mined concepts into served knowledge, the workload every production
FCA deployment actually runs (Chunduri & Cherukuri's Spark reproduction;
the Apriori-on-MapReduce lineage):

  * **iceberg mining** — ``min_support`` fused inside the MR* drivers'
    SPMD rounds (:mod:`repro.core.mr` / :mod:`repro.core.frontier`):
    infrequent candidates are compacted away right after the support psum,
    so they never re-expand and every later round's reduce is sized by the
    frequent survivors.  :func:`mine_iceberg` resolves count-or-fraction
    thresholds; ``ConceptStore.build(min_support=...)`` / ``.iceberg()``
    give the filtered store view.
  * **basis extraction** (:mod:`repro.rules.basis`) — the Duquenne–Guigues
    implication base and the Luxenburger partial-rule base of the stored
    family, computed as batched device passes over the store's intent
    table and covering relation; host brute-force oracles ride along for
    testing.
  * **serving** (:mod:`repro.rules.index` + ``QueryEngine.rules_batch``) —
    the combined basis as a device-resident ``RuleIndex`` answered in
    fixed-slot micro-batches: premise→consequent closure, min-confidence
    filtering, top-k by confidence or lift.
"""

from repro.rules.basis import (
    RuleBasis,
    RuleSet,
    dg_basis,
    dg_basis_host,
    extract_bases,
    luxenburger_from_snapshot,
    luxenburger_host,
)
from repro.rules.index import RuleIndex
from repro.rules.mining import mine_iceberg, resolve_min_support

__all__ = [
    "RuleBasis",
    "RuleSet",
    "RuleIndex",
    "dg_basis",
    "dg_basis_host",
    "extract_bases",
    "luxenburger_from_snapshot",
    "luxenburger_host",
    "mine_iceberg",
    "resolve_min_support",
]
