"""Fault-tolerant training driver.

Responsibilities beyond calling the step:
  * periodic (optionally async) checkpoints via CheckpointManager;
  * **restart-on-failure**: any exception in a step (device loss, NaN-guard,
    injected faults in tests) triggers restore-from-latest + replay — the
    data pipeline is step-indexed so replayed batches are bit-identical;
  * **elastic restart**: `resume(mesh=new_mesh)` re-partitions the restored
    state onto a different mesh shape;
  * NaN guard: a non-finite loss is treated as a failure (restore + skip).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_ckpt: bool = False
    max_retries: int = 3
    nan_guard: bool = True


class Trainer:
    def __init__(
        self,
        step_fn: Callable,  # (state, batch) -> (state, metrics); jitted by caller
        init_state_fn: Callable,  # () -> state
        batch_iter_fn: Callable,  # (start_step) -> iterator of (step, batch)
        cfg: TrainerConfig,
        state_shardings=None,
        fault_hook: Callable | None = None,  # test hook: (step) -> None, may raise
    ):
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.batch_iter_fn = batch_iter_fn
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, async_save=cfg.async_ckpt)
        self.history: list[dict] = []
        self.n_restarts = 0

    def _fresh_or_restored(self):
        state = self.init_state_fn()
        latest = self.ckpt.latest()
        if latest is not None:
            state = self.ckpt.restore(state, self.state_shardings, step=latest)
            start = int(np.asarray(jax.device_get(state["step"])))
            log.info("restored checkpoint at step %d", start)
            return state, start
        return state, 0

    def run(self) -> dict:
        cfg = self.cfg
        retries = 0
        state, start = self._fresh_or_restored()
        it = self.batch_iter_fn(start)
        step = start
        t0 = time.perf_counter()
        while step < cfg.total_steps:
            try:
                data_step, batch = next(it)
                assert data_step == step, (data_step, step)
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state, metrics = self.step_fn(state, batch)
                loss = float(np.asarray(jax.device_get(metrics["loss"])))
                if cfg.nan_guard and not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}: {loss}")
                self.history.append({"step": step, **{k: float(np.asarray(jax.device_get(v))) for k, v in metrics.items()}})
                step += 1
                retries = 0
                if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                    self.ckpt.save(step, state)
            except (Exception,) as e:  # noqa: BLE001 — restart-from-checkpoint path
                retries += 1
                self.n_restarts += 1
                log.warning("step %d failed (%s); restart %d/%d", step, e, retries, cfg.max_retries)
                if retries > cfg.max_retries:
                    raise
                self.ckpt.wait()
                state, step = self._fresh_or_restored()
                it = self.batch_iter_fn(step)
        self.ckpt.wait()
        return {
            "final_state": state,
            "steps": step,
            "wall_time_s": time.perf_counter() - t0,
            "n_restarts": self.n_restarts,
            "history": self.history,
        }
