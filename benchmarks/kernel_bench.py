"""Pallas closure-kernel micro-bench (interpret mode on CPU) vs oracles.

Wall times here are *not* TPU projections (interpret mode runs the kernel
body in Python/XLA-CPU); the point is the work-per-call census used in the
§Roofline discussion plus regression tracking of the jnp reference path.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import ClosureEngine, FormalContext, mrcbo, mrganter_plus
from repro.core.closure import batched_closure_np
from repro.core.engine import EngineStats
from repro.data import fca_datasets
from repro.kernels import ops


def run(shapes=((2048, 128, 256), (8192, 512, 64))) -> list[str]:
    out = []
    for N, m, B in shapes:
        ctx = FormalContext.synthetic(N, m, 0.15, seed=1)
        cands = FormalContext.synthetic(B, m, 0.05, seed=2).rows
        rows_p, _ = ctx.padded_rows(256)
        rows_j, cands_j = jnp.asarray(rows_p), jnp.asarray(cands)

        # warm + time the jnp reference path (jit, no pallas)
        f_ref = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=False
        )[0].block_until_ready()
        f_ref()
        _, t_ref = timed(f_ref)

        # numpy oracle
        _, t_np = timed(batched_closure_np, ctx.rows, cands, ctx.attr_mask())

        # pallas interpret (correctness-path cost only)
        f_k = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=True
        )[0].block_until_ready()
        f_k()
        _, t_k = timed(f_k)

        # work census in word-ops: one AND-accumulate sweep touches every
        # (candidate, row, word) cell, so the real packed width
        # bitset.n_words(m) = ceil(m/32) is the third factor — pricing
        # every shape at bucket_size(1) = 8 words misstated BNW for any m
        # outside (224, 256].
        W = max(1, (m + 31) // 32)
        work = B * N * W
        out.append(row(
            f"kernel/closure/N={N},m={m},B={B}/jnp_ref", 1e6 * t_ref,
            f"numpy_us={1e6 * t_np:.0f}|pallas_interpret_us={1e6 * t_k:.0f}"
            f"|BNW={work}",
        ))

    out.extend(run_equivalence())
    return out


def run_equivalence(N: int = 160, m: int = 40, B: int = 16) -> list[str]:
    """Small-shape interpret-mode equivalence pass: the Pallas closure
    kernel AND the fused frontier-step kernels must agree bit-for-bit with
    their oracles.  Asserted here so the tier-1 benchmark smoke actually
    exercises the kernel path (wall-time records keep ``use_kernel=False``
    — interpret mode is a correctness tool, not a TPU projection)."""
    from repro.core.closure import batched_closure_np as np_oracle
    from repro.kernels import frontier as fkern

    ctx = FormalContext.synthetic(N, m, 0.3, seed=5)
    cands = FormalContext.synthetic(B, m, 0.08, seed=6).rows
    rows_p, n_pad = ctx.padded_rows(64)
    rows_j, cands_j = jnp.asarray(rows_p), jnp.asarray(cands)

    def check():
        kc, ks = ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, block_n=64, use_kernel=True
        )
        oc, os_ = np_oracle(ctx.rows, cands, ctx.attr_mask())
        np.testing.assert_array_equal(np.asarray(kc), oc)
        np.testing.assert_array_equal(np.asarray(ks), os_)
        # fused frontier step: closure → support → iceberg filter, one pass
        gc, sup, keep = fkern.fused_closure_call(
            rows_j, cands_j, jnp.asarray(ctx.attr_mask()[None, :]),
            fkern.pack_scalars(B, 3, n_pad, 0), iceberg=True, block_n=64,
        )
        np.testing.assert_array_equal(np.asarray(gc), oc)
        np.testing.assert_array_equal(np.asarray(sup), os_)
        np.testing.assert_array_equal(np.asarray(keep), os_ >= 3)
        return True

    check()
    _, t = timed(check)
    return [row(
        f"kernel/equivalence/N={N},m={m},B={B}", 1e6 * t,
        "paths=closure_pallas,fused_iceberg|bit_identical=asserted",
    )]


# ---------------------------------------------------------------------------
# Frontier pipeline: host-loop vs device-resident drivers (EXPERIMENTS §Perf)
# ---------------------------------------------------------------------------


def _timed_driver(ctx, algo, *, n_parts, backend, pipeline, **kw):
    rec, _ = _timed_driver_res(
        ctx, algo, n_parts=n_parts, backend=backend, pipeline=pipeline, **kw
    )
    return rec


def _timed_driver_res(ctx, algo, *, n_parts, backend, pipeline, **kw):
    """Warm-run protocol: build the engine, run once to populate every jit
    cache (the engine's sharded step is per-instance), reset the stats
    ledger, then time the steady-state run.  Returns (record, result)."""
    eng = ClosureEngine(ctx, n_parts=n_parts, backend=backend)
    algo(ctx, eng, pipeline=pipeline, **kw)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    res = algo(ctx, eng, pipeline=pipeline, **kw)
    wall = time.perf_counter() - t0
    st = eng.stats
    it = max(1, res.n_iterations - 1)  # expansion rounds
    return {
        "algorithm": res.algorithm,
        "pipeline": pipeline,
        "backend": backend,
        "options": {k: v for k, v in kw.items()},
        "wall_time_s": round(wall, 4),
        "n_concepts": res.n_concepts,
        "n_iterations": res.n_iterations,
        "closures_computed": st.closures_computed,
        "h2d_transfers_per_iter": round(st.h2d_transfers / it, 2),
        "d2h_transfers_per_iter": round(st.d2h_transfers / it, 2),
        "h2d_bytes": st.h2d_bytes,
        "d2h_bytes": st.d2h_bytes,
        "modeled_comm_bytes": st.modeled_comm_bytes,
    }, res


def _canon_intents(intents):
    arr = np.stack([np.asarray(y, dtype=np.uint32) for y in intents])
    return arr[np.lexsort(arr.T[::-1])]


def run_frontier(
    dataset: str = "census-income",
    scale: float = 0.002,
    n_parts: int = 4,
    out_path: str = "BENCH_frontier.json",
) -> list[str]:
    """Host-loop vs device-resident frontier pipeline on the largest
    bundled dataset (Table 7), simulated multi-part engine.

    The headline record is paper-faithful MRGanter+ (host loop, no dedupe)
    against the production device pipeline (on-device seed dedupe) — the
    acceptance bar is ≥2× end-to-end.  A backend sweep (kernel/jnp/matmul)
    runs on a reduced slice since Pallas interpret mode is a correctness
    tool, not a wall-clock one.
    """
    ctx, spec = fca_datasets.load(dataset, scale=scale, seed=0)
    records = []
    grid = [
        (mrganter_plus, "host", "jnp", {}),
        (mrganter_plus, "host", "jnp", {"dedupe_candidates": True}),
        (mrganter_plus, "device", "jnp", {"dedupe_candidates": True}),
        (mrganter_plus, "device", "jnp",
         {"dedupe_candidates": True, "dedupe_closures": True}),
        (mrcbo, "host", "jnp", {}),
        (mrcbo, "device", "jnp", {}),
    ]
    for algo, pipeline, backend, kw in grid:
        records.append(
            _timed_driver(
                ctx, algo, n_parts=n_parts, backend=backend,
                pipeline=pipeline, **kw,
            )
        )

    # backend sweep on a reduced slice (kernel = interpret mode on CPU)
    ctx_s, spec_s = fca_datasets.load(dataset, scale=scale / 4, seed=0)
    sweep = []
    for backend in ("kernel", "jnp", "matmul"):
        sweep.append(
            _timed_driver(
                ctx_s, mrganter_plus, n_parts=n_parts, backend=backend,
                pipeline="device", dedupe_candidates=True,
            )
        )

    # fused-vs-unfused A/B: backend="kernel" routes the device pipeline's
    # frontier steps through the fused Pallas kernels (interpret mode on
    # CPU, so wall times are a correctness A/B, not a TPU projection).
    # Concept-set identity is asserted; the roofline entry prices one
    # average closure round under the VPU-aware model for both paths.
    from benchmarks import roofline

    ab = {}
    for backend in ("kernel", "jnp"):
        ab[backend] = _timed_driver_res(
            ctx_s, mrganter_plus, n_parts=1, backend=backend,
            pipeline="device", dedupe_candidates=True,
        )
    k_rec, k_res = ab["kernel"]
    j_rec, j_res = ab["jnp"]
    assert k_rec["n_concepts"] == j_rec["n_concepts"]
    np.testing.assert_array_equal(
        _canon_intents(k_res.intents), _canon_intents(j_res.intents)
    )
    rounds = max(1, k_rec["n_iterations"] - 1)
    B_round = max(8, k_rec["closures_computed"] // rounds)
    N_round = ctx_s.n_objects + (-ctx_s.n_objects % 256)
    fused_terms = roofline.closure_path_terms(
        B_round, N_round, ctx_s.W, path="fused"
    )
    unfused_terms = roofline.closure_path_terms(
        B_round, N_round, ctx_s.W, path="unfused"
    )
    fused_ab = {
        "dataset": dataclasses.asdict(spec_s),
        "note": (
            "interpret-mode wall times — correctness A/B, not a TPU "
            "projection; roofline terms model one average closure round"
        ),
        "records": [k_rec, j_rec],
        "concepts_identical": True,
        "roofline": {
            "B": B_round,
            "N": N_round,
            "W": ctx_s.W,
            "fused": fused_terms,
            "unfused": unfused_terms,
        },
    }

    # async transfer-batching A/B: the speculative scheduler packs each
    # round's scalar count + survivor payload into ONE device array and
    # starts the D2H copy at dispatch, vs the sync path's separate scalar
    # readback + payload download.  Concept sets asserted identical.
    tb = {}
    for mode in ("sync", "async"):
        tb[mode] = _timed_driver_res(
            ctx, mrganter_plus, n_parts=n_parts, backend="jnp",
            pipeline="device", rounds=mode, dedupe_candidates=True,
            dedupe_closures=True,
        )
    s_rec, s_res = tb["sync"]
    a_rec, a_res = tb["async"]
    np.testing.assert_array_equal(
        _canon_intents(s_res.intents), _canon_intents(a_res.intents)
    )
    async_tb = {
        "records": [s_rec, a_rec],
        "concepts_identical": True,
        "d2h_transfers_per_iter_sync": s_rec["d2h_transfers_per_iter"],
        "d2h_transfers_per_iter_async": a_rec["d2h_transfers_per_iter"],
        "d2h_bytes_delta": a_rec["d2h_bytes"] - s_rec["d2h_bytes"],
    }

    base = next(
        r for r in records
        if r["pipeline"] == "host" and r["algorithm"] == "mrganter+"
        and not r["options"]
    )
    best = min(
        (r for r in records
         if r["pipeline"] == "device" and r["algorithm"] == "mrganter+"),
        key=lambda r: r["wall_time_s"],
    )
    speedup = base["wall_time_s"] / best["wall_time_s"]
    payload = {
        "dataset": dataclasses.asdict(spec),
        "n_parts": n_parts,
        "records": records,
        "backend_sweep": {
            "dataset": dataclasses.asdict(spec_s),
            "records": sweep,
        },
        "fused_ab": fused_ab,
        "async_transfer_batching": async_tb,
        "headline": {
            "baseline": "mrganter+ host-loop (paper-faithful)",
            "candidate": "mrganter+ device pipeline",
            "speedup_x": round(speedup, 2),
            "h2d_bytes_ratio": round(
                base["h2d_bytes"] / max(1, best["h2d_bytes"]), 1
            ),
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    out = []
    for r in records + sweep:
        name = (
            f"frontier/{r['algorithm']}/{r['pipeline']}/{r['backend']}"
            + ("+dc" if r["options"].get("dedupe_candidates") else "")
            + ("+dz" if r["options"].get("dedupe_closures") else "")
        )
        out.append(row(
            name, 1e6 * r["wall_time_s"],
            f"concepts={r['n_concepts']}|closures={r['closures_computed']}"
            f"|h2d_B={r['h2d_bytes']}|d2h_B={r['d2h_bytes']}",
        ))
    out.append(row(
        "frontier/headline_speedup", speedup,
        f"devices_beat_host_x{speedup:.2f}|json={out_path}",
    ))
    out.append(row(
        "frontier/async_transfer_batching", 1e6 * a_rec["wall_time_s"],
        f"concepts_identical=True"
        f"|d2h_per_iter_sync={s_rec['d2h_transfers_per_iter']}"
        f"|d2h_per_iter_async={a_rec['d2h_transfers_per_iter']}"
        f"|d2h_B_delta={async_tb['d2h_bytes_delta']}",
    ))
    out.append(row(
        "frontier/fused_ab", 1e6 * k_rec["wall_time_s"],
        f"concepts_identical=True|jnp_us={1e6 * j_rec['wall_time_s']:.0f}"
        f"|fused_frac={fused_terms['achieved_fraction']:.3f}"
        f"|unfused_frac={unfused_terms['achieved_fraction']:.3f}",
    ))
    return out
