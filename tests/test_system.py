"""End-to-end behaviour tests for the paper's system.

The full pipeline on one CPU device: load a (synthetic, Table-7-matched)
dataset → mine concepts with every algorithm → identical lattices; then an
end-to-end ~1M-param LM training run through the fault-tolerant trainer.
"""

import numpy as np

from repro.core import (
    ClosureEngine,
    all_closures_batched,
    bitset,
    build_lattice,
    close_by_one,
    mrcbo,
    mrganter_plus,
    paper_context,
)
from repro.data import fca_datasets


def _keys(intents):
    return {bitset.key_bytes(y) for y in intents}


def test_full_fca_pipeline_on_paper_scale_data():
    ctx, spec = fca_datasets.load("mushroom", scale=0.02, seed=1)
    assert spec.n_attrs == 125  # Table 7 attribute count preserved
    ref = _keys(all_closures_batched(ctx))

    eng = ClosureEngine(ctx, n_parts=4, reduce_impl="rsag")
    res = mrganter_plus(ctx, eng, dedupe_candidates=True)
    assert _keys(res.intents) == ref
    assert res.n_iterations < len(ref)  # the paper's headline result

    res2 = mrcbo(ctx, ClosureEngine(ctx, n_parts=4))
    assert _keys(res2.intents) == ref


def test_lattice_structure_paper_example():
    ctx = paper_context()
    intents = all_closures_batched(ctx)
    lat = build_lattice(ctx, intents)
    assert lat.n_concepts == 21
    # top is ⟨O, ∅⟩, bottom is ⟨∅, P⟩ (Table 2's F_1 / F_21)
    assert bitset.popcount(lat.intents[lat.top()]) == 0
    assert bitset.popcount(lat.intents[lat.bottom()]) == 7
    assert lat.extents[lat.top()].sum() == 6
    assert lat.extents[lat.bottom()].sum() == 0
    # every concept's extent' == intent (closure consistency)
    from repro.core.closure import intent_of_extent_np

    for i in range(lat.n_concepts):
        intent = intent_of_extent_np(ctx.rows, lat.extents[i], ctx.attr_mask())
        assert np.array_equal(intent, lat.intents[i])


def test_end_to_end_training_example(tmp_path):
    """The examples/train_lm.py path: ~1M-param model, loss must drop."""
    import examples.train_lm as ex

    result = ex.main(total_steps=12, ckpt_dir=str(tmp_path), arch="mamba2-370m")
    hist = result["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert result["n_restarts"] == 0
