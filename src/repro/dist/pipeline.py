"""GPipe-style pipeline parallelism over one mesh axis.

Each shard along ``axis_name`` owns one pipeline stage's weights; micro-
batches stream through the ring with one ``ppermute`` hop per tick.  The
schedule is the classic trapezoid: ``n_micro + n_stages - 1`` ticks, stage
``s`` busy on microbatch ``t - s`` at tick ``t``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat


def pipeline_apply(stage_fn, stage_weights, x, mesh, *, axis_name: str = "model"):
    """Apply ``n_stages`` chained stages to microbatched input.

    stage_fn:      ``(W_s, x_mb) -> y_mb`` for one stage on one microbatch.
    stage_weights: ``[n_stages, ...]`` — leading dim sharded over
                   ``axis_name`` (one stage per shard).
    x:             ``[n_micro, ...mb_shape]`` microbatches, replicated.

    Returns ``[n_micro, ...mb_shape]``: every microbatch pushed through all
    stages in order — numerically identical to the sequential loop.
    """
    n_stages = mesh.shape[axis_name]
    if stage_weights.shape[0] != n_stages:
        raise ValueError(
            f"{stage_weights.shape[0]} stages vs {axis_name}={n_stages} shards"
        )
    n_micro = x.shape[0]
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(W_local, x_full):
        s = lax.axis_index(axis_name)
        buf = jnp.zeros_like(x_full[0])
        out = jnp.zeros_like(x_full)
        for t in range(n_micro + n_stages - 1):
            mb = t - s  # microbatch on this stage at this tick
            active = (mb >= 0) & (mb < n_micro)
            feed = jnp.where(t < n_micro, x_full[min(t, n_micro - 1)], 0)
            inp = jnp.where(s == 0, feed, buf)
            y = stage_fn(W_local[0], inp)
            y = jnp.where(active, y, 0)
            idx = jnp.clip(mb, 0, n_micro - 1)
            take = active & (s == n_stages - 1)
            out = out.at[idx].set(jnp.where(take, y, out[idx]))
            buf = lax.ppermute(y, axis_name, fwd)
        # only the last stage holds real outputs; sum-combine across shards
        out = jnp.where(s == n_stages - 1, out, 0)
        return lax.psum(out, axis_name)

    smapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_vma=False,
    )
    return jax.jit(smapped)(stage_weights, x)
