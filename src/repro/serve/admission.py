"""Continuous admission queue — asynchronous arrivals packed into the
QueryEngine's fixed-slot micro-batches.

The ROADMAP's fleet-scale serving item in one sentence: *"add an async
queue that packs arriving queries into slots instead of requiring
pre-formed batches."*  This is that queue.  Requests arrive one at a
time (:meth:`AdmissionQueue.submit`), join a bounded per-kind queue, and
dispatch as ONE engine micro-batch when either trigger fires:

* **full** — a kind has :attr:`~AdmissionConfig.slots` waiting requests:
  dispatch immediately (the batch is exactly one padded SPMD round, so a
  full batch never waits on the deadline);
* **deadline** — the oldest waiting request has aged
  :attr:`~AdmissionConfig.max_wait_s`: dispatch the partial batch
  (:meth:`poll`), trading slot occupancy for bounded queueing delay.

Admission is *bounded*: a kind whose queue already holds
:attr:`~AdmissionConfig.depth` requests sheds new arrivals at submit
time (ticket marked, ``serve_shed_total`` counted) — under overload the
queue degrades by rejecting, never by growing without limit.

Results are **bit-identical to pre-formed batches**: a dispatch slices
at most ``slots`` tickets and hands their rows to the very same
``closure_batch`` / ``topk_batch`` / ``rules_batch`` / ``lookup_batch``
steps a pre-formed batch would run — each micro-batch is a pure function
of (snapshot, rows), so any grouping of the same query set yields the
same per-query answers (asserted in tests/test_serve_load.py).
Snapshot swaps (``StreamUpdater.commit``) interleave safely: every
engine batch reads one consistent ``store.state`` at entry.

Telemetry rides the engine's own registry (one exporter snapshot covers
queue + engine): ``serve_queue_depth``/``serve_slot_occupancy`` gauges,
``serve_submitted_total``/``serve_shed_total``/``serve_dispatch_total``
counters, ``serve_admission_wait_s``/``serve_e2e_s`` HDR histograms, and
a ``serve/dispatch`` span per micro-batch in the PR-8 tracer.  The
dataclass view (:class:`ServeStats`) rides ``dataclasses.asdict`` into
the CLI/bench JSON like every other stats tier.

Threading: :meth:`submit` is thread-safe; dispatches serialize on one
lock (the engine's jitted steps are pure, but its stats are not).  A
background dispatcher thread (:meth:`start`/:meth:`stop`) drives
deadlines for live serving; the open-loop load generator drives
:meth:`poll` itself for deterministic measurement.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.obs import StatsBase
from repro.obs import trace as obs

# Queue-servable query kinds (updates go to StreamUpdater — commit is a
# single-flight snapshot swap, not a slot-packable request).
KINDS = ("closure", "topk", "lookup", "rules")


@dataclass
class AdmissionConfig:
    max_wait_s: float = 0.002  # deadline: oldest ticket age before dispatch
    depth: int = 512  # per-kind pending bound; beyond it, shed
    topk_k: int = 5  # k for "topk" dispatches
    rules_k: int = 5  # top-k rules per "rules" query
    rules_min_conf: float = 0.0
    rules_rank_by: str = "confidence"


@dataclass
class ServeStats(StatsBase):
    """Admission-side stats; latency percentiles (``admission_wait``,
    ``e2e``) inherit from :class:`repro.obs.StatsBase`."""

    submitted: int = 0
    admitted: int = 0
    shed: int = 0
    completed: int = 0
    dispatches: int = 0
    dispatch_causes: dict = field(default_factory=dict)
    occupancy_sum: float = 0.0
    by_kind: dict = field(default_factory=dict)

    @property
    def shed_rate(self) -> float:
        return self.shed / self.submitted if self.submitted else 0.0

    @property
    def occupancy_mean(self) -> float:
        return self.occupancy_sum / self.dispatches if self.dispatches else 0.0


class Ticket:
    """One admitted (or shed) request.

    ``result`` is the per-query row of the engine batch output (a tuple
    of arrays for closure/topk/rules, a scalar id for lookup); ``None``
    until dispatched, forever ``None`` when ``shed``.  ``arrival_s`` is
    the *offered* arrival time — the open-loop load generator backdates
    it to the scheduled arrival so queueing delay accrued while the host
    was busy is charged to the latency, not silently omitted
    (coordinated-omission-free measurement).
    """

    __slots__ = (
        "kind", "payload", "arrival_s", "shed", "dispatch_s", "done_s",
        "result",
    )

    def __init__(self, kind: str, payload, arrival_s: float):
        self.kind = kind
        self.payload = payload
        self.arrival_s = arrival_s
        self.shed = False
        self.dispatch_s: float | None = None
        self.done_s: float | None = None
        self.result = None

    @property
    def done(self) -> bool:
        return self.shed or self.done_s is not None

    @property
    def e2e_s(self) -> float | None:
        return None if self.done_s is None else self.done_s - self.arrival_s


class AdmissionQueue:
    def __init__(
        self,
        engine,
        cfg: AdmissionConfig | None = None,
        *,
        rules_index=None,
        clock=time.monotonic,
    ):
        self.engine = engine
        self.cfg = cfg or AdmissionConfig()
        self.rules_index = rules_index
        self.clock = clock
        self.slots = engine.cfg.slots
        self.stats = ServeStats()
        # one registry across queue + engine: a single /metrics snapshot
        # carries queue depth AND the engine's schedule census
        self.registry = engine.stats.registry
        self._queues: dict[str, deque[Ticket]] = {k: deque() for k in KINDS}
        self._lock = threading.Lock()  # guards queues + admission counters
        self._dispatch_lock = threading.Lock()  # serializes engine batches
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- admission -----------------------------------------------------------

    def submit(self, kind: str, payload, *, arrival_s: float | None = None) -> Ticket:
        """Admit one request (thread-safe); returns its ticket.

        Sheds (ticket.shed, result stays None) when the kind's queue is
        at ``depth``.  A submission that fills a batch dispatches it
        inline — "full" never waits for the poller.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose {KINDS}")
        if kind == "rules" and self.rules_index is None:
            raise ValueError("rules queries need an AdmissionQueue rules_index")
        now = self.clock()
        ticket = Ticket(kind, payload, now if arrival_s is None else arrival_s)
        st = self.stats
        with self._lock:
            q = self._queues[kind]
            st.submitted += 1
            st.by_kind[kind] = st.by_kind.get(kind, 0) + 1
            self.registry.counter("serve_submitted_total", kind=kind)
            if len(q) >= self.cfg.depth:
                ticket.shed = True
                st.shed += 1
                self.registry.counter("serve_shed_total", kind=kind)
                return ticket
            st.admitted += 1
            q.append(ticket)
            depth = len(q)
            full = depth >= self.slots
            self.registry.gauge("serve_queue_depth", depth, kind=kind)
        if full:
            self._dispatch(kind, "full")
        return ticket

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def next_deadline_in(self, now: float | None = None) -> float:
        """Seconds until the oldest waiting ticket's deadline fires
        (may be ≤ 0 when already due); +inf when idle."""
        now = self.clock() if now is None else now
        with self._lock:
            oldest = [q[0].arrival_s for q in self._queues.values() if q]
        if not oldest:
            return float("inf")
        return min(t + self.cfg.max_wait_s - now for t in oldest)

    # -- dispatch ------------------------------------------------------------

    def poll(self, now: float | None = None) -> int:
        """Dispatch every kind whose deadline has fired or whose queue
        filled between polls.  Returns the number of batches dispatched."""
        now = self.clock() if now is None else now
        n = 0
        for kind in KINDS:
            while True:
                with self._lock:
                    q = self._queues[kind]
                    if not q:
                        break
                    full = len(q) >= self.slots
                    due = now - q[0].arrival_s >= self.cfg.max_wait_s
                if full:
                    n += self._dispatch(kind, "full")
                elif due:
                    n += self._dispatch(kind, "deadline")
                    break  # partial batch drained the queue for this kind
                else:
                    break
        return n

    def flush(self) -> int:
        """Dispatch everything pending regardless of deadlines (end of a
        load run / shutdown drain).  Returns batches dispatched."""
        n = 0
        while self.pending():
            for kind in KINDS:
                while True:
                    with self._lock:
                        empty = not self._queues[kind]
                    if empty:
                        break
                    n += self._dispatch(kind, "flush")
        return n

    def _take(self, kind: str) -> list[Ticket]:
        with self._lock:
            q = self._queues[kind]
            batch = [q.popleft() for _ in range(min(self.slots, len(q)))]
            self.registry.gauge("serve_queue_depth", len(q), kind=kind)
        return batch

    def _dispatch(self, kind: str, cause: str) -> int:
        with self._dispatch_lock:
            batch = self._take(kind)
            if not batch:
                return 0
            t_dispatch = self.clock()
            occupancy = len(batch) / self.slots
            with obs.current().span(
                "serve/dispatch", kind=kind, cause=cause, n=len(batch),
                occupancy=round(occupancy, 4),
            ):
                results = self._run(kind, batch)
            t_done = self.clock()
        st = self.stats
        reg = self.registry
        reg.observe("serve_slot_occupancy", occupancy)
        reg.counter("serve_dispatch_total", kind=kind, cause=cause)
        with self._lock:
            st.dispatches += 1
            st.dispatch_causes[cause] = st.dispatch_causes.get(cause, 0) + 1
            st.occupancy_sum += occupancy
            st.completed += len(batch)
        for ticket, result in zip(batch, results):
            ticket.dispatch_s = t_dispatch
            ticket.done_s = t_done
            ticket.result = result
            wait = t_dispatch - ticket.arrival_s
            e2e = t_done - ticket.arrival_s
            reg.observe("serve_admission_wait_s", wait, kind=kind)
            reg.observe("serve_e2e_s", e2e, kind=kind)
            st.observe_latency("admission_wait", wait)
            st.observe_latency("e2e", e2e)
        return 1

    def _run(self, kind: str, batch: list[Ticket]) -> list:
        """One engine micro-batch for ≤ slots tickets → per-ticket rows.
        The same batch entry points a pre-formed batch would call — the
        bit-identity guarantee lives here."""
        qe, cfg = self.engine, self.cfg
        arr = np.stack([t.payload for t in batch])
        if kind == "closure":
            closures, supports, ids = qe.closure_batch(arr)
            return list(zip(closures, supports, ids))
        if kind == "topk":
            ids, vals = qe.topk_batch(arr, k=cfg.topk_k)
            return list(zip(ids, vals))
        if kind == "lookup":
            return list(qe.lookup_batch(arr))
        ids, scores, cons = qe.rules_batch(
            self.rules_index, arr, k=cfg.rules_k,
            min_conf=cfg.rules_min_conf, rank_by=cfg.rules_rank_by,
        )
        return list(zip(ids, scores, cons))

    # -- background dispatcher (live serving) --------------------------------

    def start(self, idle_sleep_s: float = 0.0005) -> None:
        """Run a daemon dispatcher thread that fires deadlines."""
        if self._thread is not None:
            raise RuntimeError("dispatcher already running")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.poll()
                wait = self.next_deadline_in()
                if wait == float("inf"):
                    wait = idle_sleep_s
                if wait > 0:
                    self._stop.wait(min(wait, idle_sleep_s * 20))

        self._thread = threading.Thread(
            target=loop, daemon=True, name="repro-admission"
        )
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10)
        self._thread = None
        if drain:
            self.flush()

    def describe(self) -> dict:
        st = self.stats
        return {
            "slots": self.slots,
            "max_wait_s": self.cfg.max_wait_s,
            "depth": self.cfg.depth,
            "shed_rate": round(st.shed_rate, 6),
            "occupancy_mean": round(st.occupancy_mean, 4),
            "stats": dataclasses.asdict(st),
        }
