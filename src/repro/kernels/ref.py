"""Pure-jnp oracle for the Pallas closure kernel.

Same contract as ``closure.closure_pallas`` (block-aligned padded inputs,
raw un-masked/un-corrected outputs) so tests can assert bit-equality, plus
the fully-corrected convenience entry matching ``ops.batched_closure``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

FULL_WORD = jnp.uint32(0xFFFFFFFF)


def closure_ref(
    rows: jax.Array, cands: jax.Array, fused_reduce: bool = True
) -> tuple[jax.Array, jax.Array]:
    """rows [N, W], cands [B, W] → (closures [B, W], supports [B] int32).

    ``fused_reduce``: lax.reduce with an AND monoid (XLA input-fuses the
    select; nothing [B,N,W]-sized touches HBM) vs the naive scan fold —
    the §Perf baseline.  Outputs are bit-identical (AND is associative
    and commutative).
    """
    rows = rows.astype(jnp.uint32)
    cands = cands.astype(jnp.uint32)
    match = jnp.all(
        (rows[None, :, :] & cands[:, None, :]) == cands[:, None, :], axis=-1
    )  # [B, N]
    sel = jnp.where(match[:, :, None], rows[None, :, :], FULL_WORD)
    if fused_reduce:
        closures = jax.lax.reduce(
            sel, FULL_WORD, lambda a, b: jax.lax.bitwise_and(a, b), dimensions=(1,)
        )
    else:
        def _and_fold(acc, row):
            return acc & row, None

        init = jnp.full(sel.shape[::2], FULL_WORD, dtype=jnp.uint32)  # [B, W]
        closures, _ = jax.lax.scan(_and_fold, init, jnp.moveaxis(sel, 1, 0))
    supports = match.sum(axis=-1, dtype=jnp.int32)
    return closures, supports
