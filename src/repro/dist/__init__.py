"""Distribution substrate: collectives, logical-axis partitioning,
pipeline parallelism, and gradient compression.

``collectives`` is the FCA reduce phase (paper Theorem 2: global closure =
bitwise-AND of per-partition local closures); the rest serves the LM
training/serving half of the system.
"""
