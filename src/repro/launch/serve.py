"""Serving launcher: batched greedy/temperature generation.

    python -m repro.launch.serve --arch codeqwen1.5-7b --reduced \
        --prompts "1,2,3;4,5" --max-new 16
"""

from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.models import transformer
from repro.serve.engine import ServeConfig, ServeEngine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--prompts", default="1,2,3;4,5,6,7")
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = transformer.init_params(cfg, seed=0)
    prompts = [
        [int(t) % cfg.vocab_size for t in chunk.split(",") if t.strip()]
        for chunk in args.prompts.split(";")
    ]
    eng = ServeEngine(
        cfg, params,
        ServeConfig(max_len=args.max_len, batch_slots=max(4, len(prompts)),
                    greedy=args.temperature == 0.0,
                    temperature=max(args.temperature, 1e-6)),
    )
    for prompt, out in zip(prompts, eng.generate(prompts, args.max_new)):
        print(f"{prompt} → {out}")


if __name__ == "__main__":
    main()
