"""OpenMetrics exporter — the `repro.obs` Registry as scrapeable text.

The serving tier's observability contract: any :class:`repro.obs.Registry`
snapshot renders as OpenMetrics text (the Prometheus exposition format's
standardized successor) via :func:`to_openmetrics`, and
:class:`MetricsServer` serves it over a stdlib HTTP endpoint
(``fca serve --metrics-port``) so a Prometheus scraper — or ``curl`` —
reads live queue-depth gauges, shed counters, and latency histograms
while the admission queue is under load.

Rendering rules (the strict subset of the OpenMetrics 1.0 spec we emit,
all enforced by :func:`parse_openmetrics`, the round-trip validator the
tests and CI run):

* metric names sanitize to ``[a-zA-Z_:][a-zA-Z0-9_:]*``; a trailing
  ``_s`` (our seconds convention) renders as ``_seconds``.
* counter sample names end in ``_total`` (the family name drops it).
* histograms emit cumulative ``_bucket{le="..."}`` series over the
  registry's log-bucket upper edges — including the explicit underflow
  bucket at the 1 µs floor — plus ``_count`` and ``_sum``; the
  ``le="+Inf"`` bucket equals ``_count``.
* label values escape ``\\``, ``"`` and newlines; families are sorted,
  each declared once, and the exposition ends with ``# EOF``.

``python -m repro.obs.export FILE`` validates a saved exposition (CI's
serve-load smoke scrapes ``--metrics-dump`` output through exactly this).
"""

from __future__ import annotations

import math
import re
import threading

from repro.obs.metrics import Histogram, Registry

CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_BAD_CHARS = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize_name(name: str) -> str:
    """A registry metric name as an OpenMetrics family name."""
    if name.endswith("_s"):
        name = name[:-2] + "_seconds"
    name = _BAD_CHARS.sub("_", name)
    if not name or not _NAME_OK.match(name):
        name = "_" + name
    return name


def _escape(value) -> str:
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def _labels_str(labels, extra=()) -> str:
    items = [*labels, *extra]
    if not items:
        return ""
    body = ",".join(
        f'{_BAD_CHARS.sub("_", str(k))}="{_escape(v)}"' for k, v in items
    )
    return "{" + body + "}"


def _num(v: float) -> str:
    f = float(v)
    if f == math.inf:
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _histogram_lines(name: str, labels, h: Histogram) -> list[str]:
    out = []
    cum = 0
    for edge, count in h.bucket_edges():
        cum += count
        out.append(
            f"{name}_bucket{_labels_str(labels, (('le', _num(edge)),))} {cum}"
        )
    out.append(f"{name}_bucket{_labels_str(labels, (('le', '+Inf'),))} {h.count}")
    out.append(f"{name}_count{_labels_str(labels)} {h.count}")
    out.append(f"{name}_sum{_labels_str(labels)} {_num(h.sum)}")
    return out


def to_openmetrics(registry: Registry, *, help_text: dict | None = None) -> str:
    """Render one registry snapshot as OpenMetrics text.

    ``help_text`` optionally maps *registry* metric names to HELP lines.
    The output always terminates with ``# EOF`` and round-trips
    :func:`parse_openmetrics`.
    """
    help_text = help_text or {}
    lines: list[str] = []
    seen: set[str] = set()
    for name, typ, series in registry.families():
        fam = sanitize_name(name)
        if typ == "counter" and fam.endswith("_total"):
            fam = fam[: -len("_total")]
        if fam in seen:  # same name as two types: disambiguate by suffix
            fam = f"{fam}_{typ}"
        seen.add(fam)
        lines.append(f"# TYPE {fam} {typ}")
        if fam.endswith("_seconds"):
            lines.append(f"# UNIT {fam} seconds")
        if name in help_text:
            lines.append(f"# HELP {fam} {_escape(help_text[name])}")
        for labels, value in series:
            if typ == "counter":
                lines.append(f"{fam}_total{_labels_str(labels)} {_num(value)}")
            elif typ == "gauge":
                lines.append(f"{fam}{_labels_str(labels)} {_num(value)}")
            else:
                lines.extend(_histogram_lines(fam, labels, value))
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# validator / parser — the acceptance check "parses as valid OpenMetrics"
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)(?: (?P<ts>[^ ]+))?$"
)
_LABEL_RE = re.compile(
    r'(?P<key>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<val>(?:[^"\\]|\\.)*)"'
)
_SUFFIXES = {
    "counter": ("_total", "_created"),
    "histogram": ("_bucket", "_count", "_sum", "_created"),
    "gauge": ("",),
}


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    return float(s)  # raises ValueError on junk — caller wraps


def parse_openmetrics(text: str) -> dict:
    """Parse (and strictly validate) an OpenMetrics exposition.

    Returns ``{family: {"type": ..., "samples": [(name, labels, value)]}}``.
    Raises ``ValueError`` on: missing ``# EOF`` terminator, samples with
    no prior TYPE declaration, sample names outside the family's allowed
    suffix set, re-declared families, malformed label syntax,
    non-cumulative histogram buckets, or a ``+Inf`` bucket that
    disagrees with ``_count``.
    """
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    if not lines or lines[-1] != "# EOF":
        raise ValueError("exposition must terminate with '# EOF'")
    families: dict[str, dict] = {}
    for i, line in enumerate(lines[:-1]):
        if not line:
            raise ValueError(f"line {i}: blank lines are not allowed")
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 3 or parts[0] != "#":
                raise ValueError(f"line {i}: malformed metadata {line!r}")
            kind, fam = parts[1], parts[2]
            if kind == "TYPE":
                typ = parts[3] if len(parts) > 3 else ""
                if typ not in _SUFFIXES:
                    raise ValueError(f"line {i}: unsupported type {typ!r}")
                if fam in families:
                    raise ValueError(f"line {i}: family {fam!r} re-declared")
                if not _NAME_OK.match(fam):
                    raise ValueError(f"line {i}: invalid family name {fam!r}")
                families[fam] = {"type": typ, "samples": []}
            elif kind in ("HELP", "UNIT"):
                if fam not in families:
                    raise ValueError(
                        f"line {i}: {kind} for undeclared family {fam!r}"
                    )
            elif kind == "EOF":
                raise ValueError(f"line {i}: '# EOF' before the last line")
            else:
                raise ValueError(f"line {i}: unknown metadata {kind!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {i}: malformed sample {line!r}")
        name = m.group("name")
        raw = m.group("labels")
        labels: dict[str, str] = {}
        if raw:
            consumed = _LABEL_RE.sub("", raw).replace(",", "").strip()
            if consumed:
                raise ValueError(f"line {i}: malformed labels {raw!r}")
            labels = {g["key"]: g["val"] for g in _LABEL_RE.finditer(raw)}
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            raise ValueError(
                f"line {i}: non-numeric value {m.group('value')!r}"
            ) from None
        fam = _family_of(name, labels, families)
        if fam is None:
            raise ValueError(
                f"line {i}: sample {name!r} has no TYPE-declared family"
            )
        families[fam]["samples"].append((name, labels, value))
    for fam, info in families.items():
        if info["type"] == "histogram":
            _check_histogram(fam, info["samples"])
    return families


def _family_of(name: str, labels: dict, families: dict) -> str | None:
    for fam, info in families.items():
        for suf in _SUFFIXES[info["type"]]:
            if name == fam + suf:
                if suf == "_bucket" and "le" not in labels:
                    raise ValueError(
                        f"histogram bucket sample {name!r} lacks an 'le' label"
                    )
                return fam
    return None


def _check_histogram(fam: str, samples: list) -> None:
    """Cumulative monotone buckets; +Inf bucket == _count, per series."""
    by_series: dict[tuple, dict] = {}
    for name, labels, value in samples:
        key = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        s = by_series.setdefault(key, {"buckets": [], "count": None})
        if name == fam + "_bucket":
            s["buckets"].append((_parse_value(labels["le"]), value))
        elif name == fam + "_count":
            s["count"] = value
    for key, s in by_series.items():
        buckets = sorted(s["buckets"])
        if not buckets:
            continue
        counts = [c for _, c in buckets]
        if counts != sorted(counts):
            raise ValueError(
                f"{fam}{dict(key)}: histogram buckets are not cumulative"
            )
        if buckets[-1][0] != math.inf:
            raise ValueError(f"{fam}{dict(key)}: missing le=\"+Inf\" bucket")
        if s["count"] is not None and buckets[-1][1] != s["count"]:
            raise ValueError(
                f"{fam}{dict(key)}: +Inf bucket {buckets[-1][1]} != "
                f"_count {s['count']}"
            )


# ---------------------------------------------------------------------------
# stdlib HTTP endpoint
# ---------------------------------------------------------------------------


class MetricsServer:
    """``GET /metrics`` over ``http.server`` in a daemon thread.

    ``provider`` is a zero-arg callable returning the live
    :class:`Registry` — called per scrape, so the endpoint always
    renders the current snapshot (registry reads are lock-protected
    against the dispatcher's concurrent writes).  ``port=0`` binds an
    ephemeral port, read back from :attr:`port`.
    """

    def __init__(self, provider, port: int = 0, host: str = "127.0.0.1"):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — http.server API
                if self.path.split("?")[0] != "/metrics":
                    self.send_error(404, "try /metrics")
                    return
                body = to_openmetrics(provider()).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # quiet — scrapes aren't app logs
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self.host = host
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name="repro-metrics",
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def main(argv=None):  # pragma: no cover — exercised by CI serve-load smoke
    """``python -m repro.obs.export FILE`` — validate a saved exposition."""
    import argparse
    import json
    import sys

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("file", help="OpenMetrics text exposition to validate")
    args = p.parse_args(argv)
    with open(args.file) as f:
        text = f.read()
    try:
        families = parse_openmetrics(text)
    except ValueError as e:
        print(f"INVALID OpenMetrics exposition: {e}", file=sys.stderr)
        return 1
    print(json.dumps({
        "families": len(families),
        "samples": sum(len(v["samples"]) for v in families.values()),
        "histograms": sum(
            1 for v in families.values() if v["type"] == "histogram"
        ),
    }))
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
