"""Formal contexts ``(O, P, I)`` as packed bitset matrices.

The context is the MapReduce *static data*: in the distributed algorithms it
is partitioned by objects (rows) across mesh shards and stays device-resident
for the whole run — the JAX-native analogue of Twister caching static data on
long-running map tasks.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import bitset


@dataclasses.dataclass(frozen=True)
class FormalContext:
    """A formal context with rows packed into uint32 bitset words.

    Attributes:
      rows:     ``[n_objects, W]`` uint32 — object -> packed attribute set.
      n_objects: number of (real) objects.
      n_attrs:   number of attributes ``m``; ``W = ceil(m/32)``.
      attr_names / obj_names: optional labels (paper's Table 1 uses a..g, 1..6).
    """

    rows: np.ndarray
    n_objects: int
    n_attrs: int
    attr_names: tuple[str, ...] | None = None
    obj_names: tuple[str, ...] | None = None

    def __post_init__(self):
        rows = np.ascontiguousarray(self.rows, dtype=np.uint32)
        if rows.ndim != 2 or rows.shape[0] != self.n_objects:
            raise ValueError(f"rows shape {rows.shape} != ({self.n_objects}, W)")
        if rows.shape[1] != bitset.n_words(self.n_attrs):
            raise ValueError(
                f"W={rows.shape[1]} != n_words({self.n_attrs})="
                f"{bitset.n_words(self.n_attrs)}"
            )
        # Defensive: no stray bits above n_attrs.
        mask = bitset.attr_mask(self.n_attrs, rows.shape[1])
        if np.any(rows & ~mask):
            raise ValueError("context rows contain bits above n_attrs")
        object.__setattr__(self, "rows", rows)

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_dense(
        cls,
        dense: np.ndarray,
        attr_names: Sequence[str] | None = None,
        obj_names: Sequence[str] | None = None,
    ) -> "FormalContext":
        dense = np.asarray(dense, dtype=bool)
        if dense.ndim != 2:
            raise ValueError("dense context must be 2-D [objects, attributes]")
        return cls(
            rows=bitset.pack_bool(dense),
            n_objects=dense.shape[0],
            n_attrs=dense.shape[1],
            attr_names=tuple(attr_names) if attr_names is not None else None,
            obj_names=tuple(obj_names) if obj_names is not None else None,
        )

    @classmethod
    def synthetic(
        cls, n_objects: int, n_attrs: int, density: float, seed: int = 0
    ) -> "FormalContext":
        """IID Bernoulli context matching a target density (paper Table 7)."""
        rng = np.random.default_rng(seed)
        dense = rng.random((n_objects, n_attrs)) < density
        return cls.from_dense(dense)

    # -- views -------------------------------------------------------------

    @property
    def W(self) -> int:
        return self.rows.shape[1]

    @property
    def density(self) -> float:
        total = self.n_objects * self.n_attrs
        return float(bitset.popcount(self.rows).sum()) / total if total else 0.0

    def attr_mask(self) -> np.ndarray:
        return bitset.attr_mask(self.n_attrs, self.W)

    def dense(self) -> np.ndarray:
        return bitset.unpack_bits(self.rows, self.n_attrs)

    # -- partitioning (paper §3: disjoint object partitions S_1..S_n) -------

    def partition(self, n_parts: int, shuffle: bool = False, seed: int = 0):
        """Split objects into ``n_parts`` disjoint partitions.

        ``shuffle=True`` implements the paper's suggested improvement of
        equalizing partition density by randomizing object placement.
        Returns a list of FormalContext; their union (in order) is ``self``
        up to the permutation.
        """
        if n_parts < 1:
            raise ValueError("n_parts must be >= 1")
        order = np.arange(self.n_objects)
        if shuffle:
            np.random.default_rng(seed).shuffle(order)
        splits = np.array_split(order, n_parts)
        return [
            FormalContext(
                rows=self.rows[idx],
                n_objects=len(idx),
                n_attrs=self.n_attrs,
                attr_names=self.attr_names,
            )
            for idx in splits
        ]

    def padded_rows(self, multiple: int) -> tuple[np.ndarray, int]:
        """Rows padded up to a multiple with all-ones rows.

        All-ones padding rows are the AND-identity and match every candidate;
        the closure kernel corrects supports by the pad count (see
        ``repro.kernels.ops``).  Returns ``(rows, n_pad)``.
        """
        n = self.n_objects
        n_padded = ((n + multiple - 1) // multiple) * multiple
        if n_padded == n:
            return self.rows, 0
        pad = np.full((n_padded - n, self.W), 0xFFFFFFFF, dtype=np.uint32)
        return np.concatenate([self.rows, pad], axis=0), n_padded - n


def paper_context() -> FormalContext:
    """The worked example from the paper's Table 1 (6 objects, a..g)."""
    table = [
        "ab.d.f.",  # 1
        "a.c.e.g",  # 2
        ".bcd.fg",  # 3
        ".b.de..",  # 4
        "a..def.",  # 5
        ".bc..fg",  # 6
    ]
    dense = np.array([[c != "." for c in row] for row in table], dtype=bool)
    return FormalContext.from_dense(
        dense,
        attr_names=tuple("abcdefg"),
        obj_names=tuple("123456"),
    )
