"""ShardPlan — the one partition-aware SPMD execution layer (paper §3).

Every MR* round is the same program: per-shard local closure over the
object-partitioned context, then a bitwise-AND all-reduce (Theorem 2) plus
whatever per-round filter rides along (dedupe, canonicity, feasibility).
Historically the engine kept two divergent code paths for this — a
``shard_map`` path over a real jax Mesh and a hand-rolled reshape-and-vmap
path for simulated partitions on one device.  ``ShardPlan`` collapses both
behind one abstraction that owns

  * **partition geometry** — object-axis shard count for the context
    (``n_parts``), block alignment (``block_n``) and the frontier-batch
    chunk cap for candidates (``max_batch``);
  * **device placement** — ``place_rows`` shards the context over the
    plan's axes, ``replicate`` pins frontier/table state to every shard;
  * **the collective schedule** — which AND-allreduce implementation
    (``allgather`` / ``rsag`` / ``pmin``, see :mod:`repro.dist.collectives`)
    the reduce phase runs, and its analytic wire-byte model.  With
    ``reduce_impl="auto"`` the plan autotunes: ``resolve_impl`` picks
    allgather-vs-rsag per round by minimizing the α-β cost model
    (wire volume + ring-step latency) for that round's padded batch.

``spmd(body, n_rep)`` is the single execution primitive: ``body`` receives
the local context shard plus replicated operands and may call collectives
over ``plan.reduce_axes``.  On a mesh plan it lowers through
``shard_map``; on a simulated plan the *same body* runs under ``jax.vmap``
with a named axis over the reshaped ``[k, N/k, W]`` rows — jax's batched
collective rules make ``all_gather`` / ``all_to_all`` / ``pmin`` /
``psum`` execute the identical arithmetic, so the two modes are
bit-identical by construction (asserted in tests/test_shardplan.py and the
8-device harness).  The AND semigroup is associative, commutative and
idempotent over uint32 words, so every schedule agrees bit-for-bit too.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.dist import collectives
from repro.dist.partition import object_axes

# vmap axis name carrying the simulated object partition. Collectives in a
# shard body reference ``plan.reduce_axes`` and never this name directly.
SIM_AXIS = "objpart"

# Schedules the autotuner arbitrates between. ``pmin`` is excluded: its
# unpacked-lane volume is strictly dominated for every batch size.
AUTO_IMPLS = ("allgather", "rsag")


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Partition geometry + placement + collective schedule for one run."""

    mesh: Mesh | None
    axis_names: tuple[str, ...]
    n_parts: int
    reduce_impl: str = "rsag"
    block_n: int = 256
    max_batch: int = 8192
    # latency term of the "auto" schedule model: bandwidth-equivalent byte
    # cost of one ring step per device (collectives.modeled_cost_bytes).
    auto_hop_bytes: int = 4096

    def __post_init__(self):
        if (
            self.reduce_impl != "auto"
            and self.reduce_impl not in collectives.IMPLS
        ):
            raise ValueError(
                f"unknown reduce schedule {self.reduce_impl!r}; "
                f"choose {collectives.IMPLS + ('auto',)}"
            )
        if self.n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {self.n_parts}")

    # -- constructors ------------------------------------------------------

    @classmethod
    def simulated(
        cls,
        n_parts: int = 1,
        *,
        reduce_impl: str = "rsag",
        block_n: int = 256,
        max_batch: int = 8192,
    ) -> "ShardPlan":
        """``n_parts`` object shards on one device (reshape + named vmap)."""
        return cls(
            mesh=None,
            axis_names=(SIM_AXIS,),
            n_parts=n_parts,
            reduce_impl=reduce_impl,
            block_n=block_n,
            max_batch=max_batch,
        )

    @classmethod
    def over_mesh(
        cls,
        mesh: Mesh,
        *,
        axis_names: tuple[str, ...] | None = None,
        reduce_impl: str = "rsag",
        block_n: int = 256,
        max_batch: int = 8192,
    ) -> "ShardPlan":
        """Real SPMD over ``mesh``; object rows sharded over ``axis_names``
        (default: whichever of the pod×data axes the mesh carries)."""
        if axis_names is None:
            axis_names = object_axes(mesh)
        if not axis_names:
            raise ValueError(
                f"mesh {dict(mesh.shape)} has none of the object axes"
            )
        k = 1
        for a in axis_names:
            k *= mesh.shape[a]
        return cls(
            mesh=mesh,
            axis_names=tuple(axis_names),
            n_parts=k,
            reduce_impl=reduce_impl,
            block_n=block_n,
            max_batch=max_batch,
        )

    @classmethod
    def auto(
        cls, n_parts: int = 8, *, reduce_impl: str = "rsag", **kw
    ) -> "ShardPlan":
        """Mesh plan over all local devices when there are >1, else a
        simulated ``n_parts``-way plan on the single device."""
        devices = jax.devices()
        if len(devices) > 1:
            mesh = Mesh(np.asarray(devices), ("data",))
            return cls.over_mesh(mesh, reduce_impl=reduce_impl, **kw)
        return cls.simulated(n_parts, reduce_impl=reduce_impl, **kw)

    # -- geometry ----------------------------------------------------------

    @property
    def is_simulated(self) -> bool:
        return self.mesh is None

    @property
    def reduce_axes(self):
        """Axis name(s) the shard body's collectives reduce over."""
        if self.mesh is None:
            return SIM_AXIS
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]

    @property
    def row_alignment(self) -> int:
        """Context rows must pad to a multiple of this (shards block-align)."""
        return self.n_parts * self.block_n

    # -- placement ---------------------------------------------------------

    def place_rows(self, rows: np.ndarray) -> jax.Array:
        """Shard padded context rows ``[N, W]`` over the object axes.

        Mesh plan: ``NamedSharding`` over ``axis_names``.  Simulated plan:
        reshape to ``[k, N/k, W]`` so the named-vmap axis is the partition.
        """
        if rows.shape[0] % self.n_parts:
            raise ValueError(
                f"rows ({rows.shape[0]}) not divisible by n_parts ({self.n_parts})"
            )
        if self.mesh is not None:
            sharding = NamedSharding(self.mesh, P(self.axis_names, None))
            return jax.device_put(jnp.asarray(rows), sharding)
        return jnp.asarray(rows).reshape(
            self.n_parts, rows.shape[0] // self.n_parts, *rows.shape[1:]
        )

    def replicate(self, arr) -> jax.Array:
        """Pin dynamic per-round state (frontier, tables) to every shard, so
        expansion/pruning compute runs partition-locally instead of on one
        device followed by a broadcast at the SPMD region boundary."""
        if self.mesh is not None:
            return jax.device_put(jnp.asarray(arr), NamedSharding(self.mesh, P()))
        return jnp.asarray(arr)

    # -- execution ---------------------------------------------------------

    def spmd(self, body, *, n_rep: int, post=None, n_post_rep: int = 0):
        """Wrap ``body(rows_local, *replicated)`` for per-shard execution.

        The first argument is the object-sharded context; the following
        ``n_rep`` arguments are replicated.  ``body`` may call collectives
        over ``self.reduce_axes``; outputs must be shard-invariant (i.e.
        globally reduced or computed from replicated operands) and come
        back replicated.

        ``post(*body_outputs, *post_replicated)`` is an optional fused
        stage consuming the shard-invariant reduced outputs (canonicity,
        feasibility, dedupe).  Because its input is identical on every
        shard, the plan owns its placement: on a mesh it runs inside the
        same SPMD region (each partition filters locally — the whole round
        is one ``shard_map``); on a simulated plan it runs once after the
        vmapped map+reduce, instead of k redundant lane copies on the one
        device.  Bit-identical either way.  The returned callable takes
        ``(rows, *replicated, *post_replicated)``; callers normally wrap
        it in ``jax.jit``.
        """
        if self.mesh is not None:

            def fused(rows_local, *rep):
                out = body(rows_local, *rep[:n_rep])
                if post is None:
                    return out
                out = out if isinstance(out, tuple) else (out,)
                return post(*out, *rep[n_rep:])

            in_specs = (P(self.axis_names, None),) + (P(),) * (n_rep + n_post_rep)
            return compat.shard_map(
                fused,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=P(),
                check_vma=False,  # pallas_call outputs carry no vma info
            )

        vbody = jax.vmap(
            body,
            in_axes=(0,) + (None,) * n_rep,
            out_axes=0,
            axis_name=SIM_AXIS,
        )

        def run(rows, *rep):
            outs = vbody(rows, *rep[:n_rep])
            # Outputs are identical on every simulated shard (same invariant
            # the mesh path's ``out_specs=P()`` asserts); keep shard 0.
            outs = jax.tree_util.tree_map(lambda o: o[0], outs)
            if post is None:
                return outs
            outs = outs if isinstance(outs, tuple) else (outs,)
            return post(*outs, *rep[n_rep:])

        return run

    # -- accounting --------------------------------------------------------

    def resolve_impl(
        self, batch: int, W: int, n_attrs: int | None = None
    ) -> str:
        """The schedule one reduce round of ``batch`` candidates runs.

        A fixed ``reduce_impl`` is returned as-is; ``"auto"`` picks the
        α-β-cheapest of :data:`AUTO_IMPLS` for this round's measured batch
        (``collectives.modeled_cost_bytes``: allgather's single ring pass
        wins latency-bound small batches, rsag's 2(k-1)/k volume wins
        bandwidth-bound large ones).  Deterministic in the padded batch
        size, so the per-bucket jit caches see a stable choice.
        """
        if self.reduce_impl != "auto":
            return self.reduce_impl
        return min(
            AUTO_IMPLS,
            key=lambda impl: collectives.modeled_cost_bytes(
                impl, self.n_parts, batch, W, n_attrs,
                hop_bytes=self.auto_hop_bytes,
            ),
        )

    def modeled_reduce_bytes(
        self, batch: int, W: int, n_attrs: int | None = None
    ) -> int:
        """Analytic wire bytes one reduce round of ``batch`` candidates
        costs under this plan's schedule (see collectives.modeled_comm_bytes)."""
        return collectives.modeled_comm_bytes(
            self.resolve_impl(batch, W, n_attrs), self.n_parts, batch, W, n_attrs
        )

    def describe(self) -> dict:
        """JSON-friendly summary for launcher output and benchmark records."""
        return {
            "mode": "simulated" if self.mesh is None else "mesh",
            "n_parts": self.n_parts,
            "axes": list(self.axis_names),
            "mesh_shape": None if self.mesh is None else dict(self.mesh.shape),
            "reduce_impl": self.reduce_impl,
            "block_n": self.block_n,
            "max_batch": self.max_batch,
        }
