"""Span tracer — the round-level timeline the paper's argument runs on.

The paper's whole case is iteration economics: Twister beats Hadoop
because per-round overheads (dispatch, shuffle, sync) dominate MR-FCA.
This module makes our own per-round story *inspectable*: every host-side
boundary the miners and servers cross — seed expansion, closure dispatch,
the blocked wait on the AND-allreduce, survivor download, speculative
dispatch/reconcile, query micro-batches, streaming stage/commit — records
a span, and the whole run exports as Chrome/Perfetto ``trace_event`` JSON
(load ``--trace out.json`` at https://ui.perfetto.dev) so a round schedule
is *visually* checkable: a sync mine is a strict staircase, an async mine
shows ``spec/dispatch[r+1]`` overlapping ``mine/round[r]``.

Two event families:

* **sync spans** (``ph: B``/``E``) — strictly nested host work on one
  track.  ``Tracer.span(name, **tags)`` is a context manager; tags land
  in ``args`` (modeled bytes, shard-plan geometry, reduce impl, ...).
* **async spans** (``ph: b``/``e`` + id) — device-overlapped work whose
  begin and end are observed from the host but whose extent crosses other
  spans (the speculative round r is *in flight* while round r+1
  dispatches).  One async span per mining round in async mode, ended at
  reconcile (outcome tag ∈ {adopt, fallback, discard}).

Tracing is opt-in and OFF by default: the module-level current tracer is
a shared :class:`NoopTracer` whose ``span()`` returns one reusable null
context manager — no event dicts, no timestamps, no allocation — so an
untraced mine is bit-identical and within noise of a build without the
instrumentation (asserted in tests/test_obs.py).  Instrumentation lives
only at host boundaries; nothing is traced inside jitted code.

Optional device-side correlation: ``Tracer(jax_annotations=True)`` enters
a ``jax.profiler.TraceAnnotation`` for every span so host spans line up
with XLA's own profiler timeline, and :func:`start_device_trace` /
:func:`stop_device_trace` pass through ``jax.profiler.start_trace`` for a
full device trace alongside the host one (both best-effort: missing
profiler support degrades to host-only tracing, never an error).

``python -m repro.obs.trace out.json`` validates a saved trace (schema +
span well-formedness; ``--expect-async-overlap`` additionally requires a
speculative dispatch overlapping an earlier in-flight round) — CI's
trace-smoke job runs exactly this.
"""

from __future__ import annotations

import contextlib
import json
import re
import time


# Chrome trace_event phases we emit / accept.
_SYNC_PHASES = ("B", "E")
_ASYNC_PHASES = ("b", "e")
_PHASES = frozenset(_SYNC_PHASES + _ASYNC_PHASES + ("i", "M", "X", "C"))

# Strip instance indices for rollups: "mine/round[7]/expand" → "mine/round/expand".
_INDEX_RE = re.compile(r"\[\d+\]")


def _strip_index(name: str) -> str:
    return _INDEX_RE.sub("", name)


class _NullSpan:
    """The shared no-op span: enter/exit/set all do nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **tags):  # end-tags (e.g. outcome=...) — dropped
        pass


_NULL_SPAN = _NullSpan()


class NoopTracer:
    """Default tracer: every operation is a no-op.

    Shared singleton (:data:`NOOP`); ``enabled`` lets hot sites skip even
    the tag-dict construction when they want to (the per-round call sites
    don't bother — one small dict per *round* is already below noise).
    """

    enabled = False

    def span(self, name, **tags):
        return _NULL_SPAN

    def instant(self, name, **tags):
        pass

    def begin_async(self, name, aid, **tags):
        pass

    def end_async(self, name, aid, **tags):
        pass


NOOP = NoopTracer()


class _Span:
    """One open sync span; emitted as a B event at enter, E at exit.

    ``set(**tags)`` adds end-tags (recorded on the E event) — used for
    outcomes only known when the work finishes (reconcile adopt/fallback).
    """

    __slots__ = ("_tracer", "name", "_end_tags")

    def __init__(self, tracer, name):
        self._tracer = tracer
        self.name = name
        self._end_tags = None

    def set(self, **tags):
        if self._end_tags is None:
            self._end_tags = {}
        self._end_tags.update(tags)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer._end(self.name, self._end_tags)
        return False


class Tracer:
    """Records spans and exports Chrome/Perfetto ``trace_event`` JSON.

    Timestamps are microseconds since the tracer's construction
    (``perf_counter``-based — monotone by construction).  Single host
    track (``pid``/``tid`` fixed): the mining/serving host loops are
    single-threaded, and device-overlapped work goes on *async* tracks
    via :meth:`begin_async`/:meth:`end_async` which Perfetto renders as
    separate rows, so overlap is visible without fake threads.
    """

    enabled = True

    def __init__(self, *, pid: int = 0, tid: int = 0, jax_annotations: bool = False):
        self.events: list[dict] = []
        self.pid = pid
        self.tid = tid
        self._t0 = time.perf_counter()
        self._stack: list[str] = []
        self._jax_ann = None
        if jax_annotations:
            try:  # pragma: no cover — optional device-profiler correlation
                from jax.profiler import TraceAnnotation

                self._jax_ann = TraceAnnotation
            except Exception:
                self._jax_ann = None

    # -- event plumbing ----------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, name: str, ph: str, *, cat: str = "host", args=None, aid=None):
        ev = {
            "name": name,
            "ph": ph,
            "ts": self._now_us(),
            "pid": self.pid,
            "tid": self.tid,
            "cat": cat,
        }
        if args:
            ev["args"] = args
        if aid is not None:
            ev["id"] = aid
        self.events.append(ev)

    # -- sync spans --------------------------------------------------------

    def span(self, name: str, **tags) -> _Span:
        """Open a nested host span (context manager).  Tags become the B
        event's ``args``; tags added via ``.set()`` land on the E event."""
        self._stack.append(name)
        self._emit(name, "B", args=tags or None)
        span = _Span(self, name)
        if self._jax_ann is not None:  # pragma: no cover — device correlation
            return _AnnotatedSpan(span, self._jax_ann(name))
        return span

    def _end(self, name: str, end_tags):
        if not self._stack or self._stack[-1] != name:  # defensive: never raise
            # mismatched exit (a span leaked across an exception unwinding
            # another) — close what's open so the trace stays well-formed
            while self._stack and self._stack[-1] != name:
                self._emit(self._stack.pop(), "E")
        if self._stack:
            self._stack.pop()
        self._emit(name, "E", args=end_tags)

    def instant(self, name: str, **tags):
        """A zero-duration marker (Chrome ``i`` event)."""
        ev_args = tags or None
        ev = {
            "name": name,
            "ph": "i",
            "ts": self._now_us(),
            "pid": self.pid,
            "tid": self.tid,
            "cat": "host",
            "s": "t",  # thread-scoped instant
        }
        if ev_args:
            ev["args"] = ev_args
        self.events.append(ev)

    # -- async (device-overlapped) spans ------------------------------------

    def begin_async(self, name: str, aid: int, **tags):
        """Begin a device-overlapped span (Chrome async ``b``).  ``aid``
        correlates begin/end and must be unique per in-flight span (the
        miners use the round sequence number)."""
        self._emit(name, "b", cat="round", args=tags or None, aid=aid)

    def end_async(self, name: str, aid: int, **tags):
        self._emit(name, "e", cat="round", args=tags or None, aid=aid)

    # -- export ------------------------------------------------------------

    def to_dict(self) -> dict:
        """The Perfetto-loadable JSON object (round-trips ``json.loads``)."""
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"tracer": "repro.obs", "clock": "perf_counter_us"},
        }

    def save(self, path: str) -> None:
        # close any spans an exception left open so the file validates
        while self._stack:
            self._emit(self._stack.pop(), "E")
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    def rollup(self) -> dict:
        """Aggregate spans by index-stripped name — see :func:`span_rollup`."""
        return span_rollup(self.events)


class _AnnotatedSpan:  # pragma: no cover — device-profiler correlation
    """A host span that also enters a jax.profiler.TraceAnnotation."""

    __slots__ = ("_span", "_ann")

    def __init__(self, span, ann):
        self._span = span
        self._ann = ann

    def set(self, **tags):
        self._span.set(**tags)

    def __enter__(self):
        try:
            self._ann.__enter__()
        except Exception:
            self._ann = None
        self._span.__enter__()
        return self

    def __exit__(self, *exc):
        self._span.__exit__(*exc)
        if self._ann is not None:
            try:
                self._ann.__exit__(*exc)
            except Exception:
                pass
        return False


# ---------------------------------------------------------------------------
# current-tracer plumbing (module-level; host loops are single-threaded)
# ---------------------------------------------------------------------------

_CURRENT: NoopTracer | Tracer = NOOP


def current():
    """The active tracer (the shared no-op unless one was installed)."""
    return _CURRENT


def set_tracer(tracer) -> None:
    global _CURRENT
    _CURRENT = tracer if tracer is not None else NOOP


@contextlib.contextmanager
def use_tracer(tracer):
    """Install ``tracer`` for the duration of a ``with`` block."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else NOOP
    try:
        yield tracer
    finally:
        _CURRENT = prev


# ---------------------------------------------------------------------------
# device-trace pass-through (optional, best-effort)
# ---------------------------------------------------------------------------


def start_device_trace(log_dir: str) -> bool:
    """Begin a jax.profiler device trace alongside the host tracer.
    Returns False (instead of raising) when the runtime has no profiler
    support — host tracing keeps working either way."""
    try:  # pragma: no cover — depends on runtime profiler support
        import jax

        jax.profiler.start_trace(log_dir)
        return True
    except Exception:
        return False


def stop_device_trace() -> bool:
    try:  # pragma: no cover
        import jax

        jax.profiler.stop_trace()
        return True
    except Exception:
        return False


# ---------------------------------------------------------------------------
# validation + rollup (shared by tests, CI, and the CLI's span_rollup)
# ---------------------------------------------------------------------------


def validate_trace(obj) -> dict:
    """Validate a trace object (as loaded by ``json.loads``).

    Checks the Chrome ``trace_event`` schema subset we emit plus span
    well-formedness: every ``B`` has a matching ``E`` (properly nested per
    track), every async ``b`` has its ``e`` (matched by ``(name, id)``),
    and timestamps are monotone non-decreasing in emission order per
    track.  Returns a summary dict; raises ``ValueError`` on any
    violation.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    stacks: dict[tuple, list[str]] = {}
    last_ts: dict[tuple, float] = {}
    open_async: dict[tuple, int] = {}
    n_spans = n_async = max_depth = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing required key {key!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has invalid ts {ev['ts']!r}")
        track = (ev["pid"], ev["tid"])
        if ev["ts"] < last_ts.get(track, 0.0):
            raise ValueError(
                f"event {i} ({ev['name']!r}): ts {ev['ts']} precedes the "
                f"track's previous event ({last_ts[track]}) — timestamps "
                "must be monotone per track"
            )
        last_ts[track] = ev["ts"]
        if ph == "B":
            stacks.setdefault(track, []).append(ev["name"])
            max_depth = max(max_depth, len(stacks[track]))
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                raise ValueError(f"event {i}: E {ev['name']!r} with no open B")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} does not match the "
                    f"innermost open B {top!r} — spans must nest"
                )
            n_spans += 1
        elif ph == "b":
            key = (ev["name"], ev.get("id"))
            open_async[key] = open_async.get(key, 0) + 1
        elif ph == "e":
            key = (ev["name"], ev.get("id"))
            if open_async.get(key, 0) <= 0:
                raise ValueError(
                    f"event {i}: async e {key!r} with no matching b"
                )
            open_async[key] -= 1
            n_async += 1
    for track, stack in stacks.items():
        if stack:
            raise ValueError(f"track {track}: unclosed B spans {stack!r}")
    dangling = {k: v for k, v in open_async.items() if v}
    if dangling:
        raise ValueError(f"unclosed async spans: {dangling!r}")
    return {
        "events": len(events),
        "spans": n_spans,
        "async_spans": n_async,
        "max_depth": max_depth,
    }


def async_overlaps(obj) -> list[dict]:
    """Speculative overlap census: host spans that begin while an async
    round span (``cat: round``) with a *different* id is still in flight.

    A sync mine has none; an async mine's ``spec/dispatch[r+1]`` spans
    must appear here, overlapping ``mine/round[r]`` — the visual (and now
    testable) signature of the speculative scheduler.
    """
    events = obj["traceEvents"]
    # async round windows: (begin_ts, end_ts, id, name)
    begins: dict = {}
    windows = []
    for ev in events:
        if ev.get("cat") != "round":
            continue
        key = (ev["name"], ev.get("id"))
        if ev["ph"] == "b":
            begins[key] = ev["ts"]
        elif ev["ph"] == "e" and key in begins:
            windows.append(
                {"name": ev["name"], "id": ev.get("id"),
                 "t0": begins.pop(key), "t1": ev["ts"]}
            )
    out = []
    for ev in events:
        if ev["ph"] != "B":
            continue
        for w in windows:
            if w["t0"] < ev["ts"] < w["t1"] and ev["name"] != w["name"]:
                out.append(
                    {"span": ev["name"], "ts": ev["ts"],
                     "in_flight": w["name"], "round_id": w["id"]}
                )
                break
    return out


def span_rollup(events) -> dict:
    """Aggregate completed spans by index-stripped name.

    Returns ``{name: {count, total_s, mean_s, max_s, p50_s, p95_s,
    p99_s}}`` — percentiles via the same log-bucketed histogram the
    metrics registry uses, so the CLI's ``span_rollup`` and
    ``latency_percentiles`` read on one scale.  Covers sync B/E pairs and
    async b/e pairs (matched by ``(name, id)``).
    """
    from repro.obs.metrics import Histogram

    hists: dict[str, Histogram] = {}
    stack: dict[tuple, list] = {}
    open_async: dict[tuple, float] = {}

    def observe(name: str, dur_us: float):
        h = hists.setdefault(_strip_index(name), Histogram())
        h.record(max(dur_us, 0.0) / 1e6)

    for ev in events:
        ph = ev.get("ph")
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stack.setdefault(track, []).append((ev["name"], ev["ts"]))
        elif ph == "E":
            if stack.get(track):
                name, t0 = stack[track].pop()
                observe(name, ev["ts"] - t0)
        elif ph == "b":
            open_async[(ev["name"], ev.get("id"))] = ev["ts"]
        elif ph == "e":
            t0 = open_async.pop((ev["name"], ev.get("id")), None)
            if t0 is not None:
                observe(ev["name"], ev["ts"] - t0)
    return {
        name: {
            "count": h.count,
            "total_s": round(h.sum, 6),
            "mean_s": round(h.sum / h.count, 6) if h.count else 0.0,
            "max_s": round(h.max, 6),
            **{f"{k}_s": round(v, 6) for k, v in h.percentiles().items()},
        }
        for name, h in sorted(hists.items())
    }


def main(argv=None):  # pragma: no cover — exercised by the CI trace-smoke job
    """``python -m repro.obs.trace TRACE.json [--expect-async-overlap]``"""
    import argparse
    import sys

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("trace", help="Perfetto trace_event JSON to validate")
    p.add_argument("--expect-async-overlap", action="store_true",
                   help="require at least one speculative dispatch span "
                        "overlapping an earlier in-flight round span")
    args = p.parse_args(argv)
    with open(args.trace) as f:
        obj = json.load(f)
    try:
        summary = validate_trace(obj)
    except ValueError as e:
        print(f"INVALID trace: {e}", file=sys.stderr)
        return 1
    overlaps = async_overlaps(obj)
    summary["overlapping_spans"] = len(overlaps)
    print(json.dumps(summary))
    if args.expect_async_overlap and not any(
        o["span"].startswith("spec/dispatch") for o in overlaps
    ):
        print(
            "INVALID trace: no spec/dispatch span overlaps an in-flight "
            "round (expected for --rounds async)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
