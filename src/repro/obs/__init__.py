"""repro.obs — round-level tracing + metrics for mining and serving.

The observability floor: span traces (Perfetto ``trace_event`` JSON) of
every host-side round boundary, a label-aware metrics registry with
HDR-style latency histograms, and the shared schedule-census mixin both
stats tiers inherit.  Tracing is off by default (shared no-op tracer);
install one with ``use_tracer(Tracer())`` or ``fca ... --trace out.json``.
"""

from repro.obs.metrics import Histogram, Registry, ScheduleCensus, StatsBase
from repro.obs.trace import (
    NOOP,
    NoopTracer,
    Tracer,
    async_overlaps,
    current,
    set_tracer,
    span_rollup,
    start_device_trace,
    stop_device_trace,
    use_tracer,
    validate_trace,
)

__all__ = [
    "Histogram",
    "Registry",
    "ScheduleCensus",
    "StatsBase",
    "NOOP",
    "NoopTracer",
    "Tracer",
    "async_overlaps",
    "current",
    "set_tracer",
    "span_rollup",
    "start_device_trace",
    "stop_device_trace",
    "use_tracer",
    "validate_trace",
]
