"""Dry-run core: lower + compile every (arch × shape) cell on a mesh and
extract the §Roofline raw metrics.  Pure library — device-count env setup
lives in ``dryrun.py`` (which must run before any jax import).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config, get_plan, get_shape
from repro.dist.partition import Partitioner
from repro.launch import hlo_analysis
from repro.launch import specs as S
from repro.models import transformer
from repro.models.config import ModelConfig, shape_applicable
from repro.train import step as tstep
from repro.train.optim import get_optimizer, warmup_cosine


def _sharded_bytes(partitioner: Partitioner, axes_tree, abstract_tree) -> int:
    """Exact per-device resident bytes given the sharding specs."""
    total = 0
    mesh = partitioner.mesh

    def leaf(ax, ab):
        nonlocal total
        spec = partitioner.spec_for(ax, ab.shape)
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= mesh.shape[a]
        total += int(np.prod(ab.shape, dtype=np.int64)) * ab.dtype.itemsize // denom

    jax.tree_util.tree_map(
        leaf, axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
    return total


def build_cell(arch: str, shape_name: str, mesh, *, fsdp=None, optimizer=None,
               baseline: bool = False):
    """Returns (jitted_fn, example_args, aux) for one cell, un-lowered.

    ``baseline=True`` disables the beyond-paper §Perf optimizations
    (attention sharding constraints) for the A/B tables in EXPERIMENTS.md.
    """
    cfg = get_config(arch)
    plan = get_plan(arch)
    shape = get_shape(shape_name)
    fsdp = plan.fsdp if fsdp is None else fsdp
    opt_name = plan.optimizer if optimizer is None else optimizer

    part = Partitioner(mesh, fsdp=fsdp, constrain_attention=not baseline)
    av, ax = transformer.abstract_params(cfg)
    p_sh = part.tree_shardings(ax, av)
    specs = S.input_specs(cfg, shape)
    aux: dict[str, Any] = {"cfg": cfg, "shape": shape, "partitioner": part}

    if shape.kind == "train":
        opt = get_optimizer(opt_name, warmup_cosine(3e-4, 100, 10_000))
        a_opt = jax.eval_shape(opt.init, av)
        state_sh = {
            "params": p_sh,
            "opt": part.tree_shardings(opt.state_axes(ax), a_opt),
            "step": part.replicated(),
        }
        a_state = {"params": av, "opt": a_opt,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        b_sh = tstep.batch_shardings(part, specs)
        fn = tstep.make_train_step(cfg, opt, part)
        jitted = jax.jit(fn, in_shardings=(state_sh, b_sh), donate_argnums=0)
        args = (a_state, specs)
        aux["state_bytes"] = _sharded_bytes(part, ax, av) + _sharded_bytes(
            part, opt.state_axes(ax), a_opt
        )
    elif shape.kind == "prefill":
        c_sh = tstep.cache_shardings(part, cfg, specs["caches"])
        io_sh = {"inputs": part.batch_spec(specs["inputs"].shape), "caches": c_sh}
        if "positions" in specs:
            io_sh["positions"] = part.batch_spec(specs["positions"].shape, batch_dim=1)

        def fn(params, io):
            return transformer.prefill(
                params, get_config(arch), io["inputs"], io["caches"],
                rope_positions=io.get("positions"), shard=part,
            )

        jitted = jax.jit(fn, in_shardings=(p_sh, io_sh), donate_argnums=1)
        args = ({**av} if isinstance(av, dict) else av, {k: v for k, v in specs.items()})
        args = (av, specs)
        aux["state_bytes"] = _sharded_bytes(part, ax, av)
    else:  # decode
        c_sh = tstep.cache_shardings(part, cfg, specs["caches"])
        io_sh = {
            "inputs": part.batch_spec(specs["inputs"].shape),
            "t": part.replicated(),
            "caches": c_sh,
        }
        if "positions" in specs:
            io_sh["positions"] = part.batch_spec(specs["positions"].shape, batch_dim=1)

        def fn(params, io):
            return transformer.decode_step(
                params, get_config(arch), io["inputs"], io["t"], io["caches"],
                rope_positions=io.get("positions"), shard=part,
            )

        jitted = jax.jit(fn, in_shardings=(p_sh, io_sh), donate_argnums=1)
        args = (av, specs)
        aux["state_bytes"] = _sharded_bytes(part, ax, av)
        aux["cache_bytes"] = _sharded_bytes(
            part, transformer.cache_axes(cfg),
            specs["caches"],
        )
    return jitted, args, aux


def model_flops(cfg: ModelConfig, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (bwd+fwd), 2·N·D inference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per slot
    return 2.0 * n * tokens


def run_cell(arch: str, shape_name: str, mesh, mesh_label: str, **kw) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_label,
        "chips": int(np.prod(list(mesh.shape.values()))),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        t0 = time.perf_counter()
        jitted, args, aux = build_cell(arch, shape_name, mesh, **kw)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.perf_counter() - t0, 1)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 1)

        ca = compat.cost_analysis(compiled)
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        an = hlo_analysis.analyze(hlo)

        rec.update(
            status="ok",
            xla_flops_per_device=float(ca.get("flops", 0.0)),
            xla_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            flops_per_device=float(an.flops),
            hbm_bytes_per_device=float(an.hbm_bytes),
            collective_bytes_per_device=float(an.collective_bytes),
            collective_by_kind={k: float(v) for k, v in an.coll_by_kind.items()},
            collective_counts={k: int(v) for k, v in an.coll_counts.items()},
            unresolved_whiles=int(an.unresolved_whiles),
            model_flops_global=model_flops(cfg, shape),
            state_bytes_per_device=int(aux.get("state_bytes", 0)),
            cache_bytes_per_device=int(aux.get("cache_bytes", 0)),
            memory_analysis={
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            },
            hlo_chars=len(hlo),
        )
    except Exception as e:  # record the failure — dry-run bugs are bugs
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
    return rec


# ---------------------------------------------------------------------------
# The paper's own technique on the production mesh (FCA closure step)
# ---------------------------------------------------------------------------


def run_fca_cell(mesh, mesh_label: str, n_objects: int = 1 << 23,
                 n_attrs: int = 4096, batch: int = 4096,
                 baseline: bool = False, reduce_impl: str = "rsag",
                 method: str = "matmul") -> dict:
    """Lower one MRGanter+ map/reduce round at production scale.

    Context: 8.4M objects × 4096 attributes (≫ census-income), objects
    sharded over pod×data×(model folded in as extra object shards is NOT
    done — attributes stay word-packed on-chip).  No MXU dots: the closure
    is VPU/bitwise work, so its roofline is memory+collective-bound.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import bitset
    from repro.dist import collectives
    from repro.kernels import ops

    rec: dict[str, Any] = {
        "arch": "fca-mrganter+", "shape": f"closure_{n_objects}x{n_attrs}_B{batch}",
        "mesh": mesh_label, "chips": int(np.prod(list(mesh.shape.values()))),
    }
    try:
        W = bitset.n_words(n_attrs)
        data_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        k = int(np.prod([mesh.shape[a] for a in data_axes]))
        rows = jax.ShapeDtypeStruct((n_objects, W), jnp.uint32)
        cands = jax.ShapeDtypeStruct((batch, W), jnp.uint32)
        mask = jnp.asarray(bitset.attr_mask(n_attrs, W))

        if baseline:
            method = "bitwise_naive"

        def shard_body(rows_local, cands):
            if method == "matmul":  # §Perf C2: MXU complement-counting
                lc, ls = ops.closure_matmul(
                    rows_local, cands, n_attrs, n_valid_rows=n_objects // k
                )
                lc = lc & mask
            else:
                lc, ls = ops.batched_closure(
                    rows_local, cands, n_attrs,
                    n_valid_rows=n_objects // k, use_kernel=False,
                    fused_reduce=(method != "bitwise_naive"),
                )
            gc = collectives.and_allreduce(lc, data_axes, impl=reduce_impl)
            gs = jax.lax.psum(ls, data_axes)
            return gc & mask, gs

        smapped = compat.shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(data_axes, None), P()), out_specs=(P(), P()),
            check_vma=False,
        )
        row_sh = NamedSharding(mesh, P(data_axes, None))
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(smapped, in_shardings=(row_sh, rep))
        t0 = time.perf_counter()
        lowered = jitted.lower(rows, cands)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 1)
        an = hlo_analysis.analyze(compiled.as_text())
        ca = compat.cost_analysis(compiled)
        rec.update(
            status="ok",
            flops_per_device=float(an.flops),
            xla_flops_per_device=float(ca.get("flops", 0.0)),
            hbm_bytes_per_device=float(an.hbm_bytes),
            collective_bytes_per_device=float(an.collective_bytes),
            collective_by_kind={k_: float(v) for k_, v in an.coll_by_kind.items()},
            context_bytes_per_device=n_objects * W * 4 // k,
            model_flops_global=0.0,  # bitwise VPU work — no MXU dots
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
    return rec
