"""Packed-bitset utilities for formal contexts.

Attribute sets over ``m`` attributes are packed little-endian into
``W = ceil(m/32)`` uint32 words: attribute ``a`` lives in word ``a // 32``,
bit ``a % 32``.  The same layout is used host-side (numpy) and device-side
(jax.numpy); these helpers are the host-side/numpy half, ``repro.core.closure``
holds the jnp half.

Lectic order convention: attribute index 0 is the *smallest* attribute
(the paper's ``p_1``), so "bits below a" == ``low_mask(a)``.
"""

from __future__ import annotations

import numpy as np

WORD_BITS = 32
_FULL = np.uint32(0xFFFFFFFF)


def n_words(n_attrs: int) -> int:
    """Number of uint32 words needed for ``n_attrs`` attributes."""
    return max(1, (n_attrs + WORD_BITS - 1) // WORD_BITS)


def attr_mask(n_attrs: int, W: int | None = None) -> np.ndarray:
    """``[W]`` uint32 mask with exactly the first ``n_attrs`` bits set."""
    W = n_words(n_attrs) if W is None else W
    mask = np.zeros(W, dtype=np.uint32)
    full_words = n_attrs // WORD_BITS
    mask[:full_words] = _FULL
    rem = n_attrs % WORD_BITS
    if rem and full_words < W:
        mask[full_words] = np.uint32((1 << rem) - 1)
    return mask


def low_mask(a: int, W: int) -> np.ndarray:
    """``[W]`` mask of all attribute bits strictly below ``a``."""
    return attr_mask(a, W)


def bit(a: int, W: int) -> np.ndarray:
    """``[W]`` mask with only attribute ``a`` set."""
    out = np.zeros(W, dtype=np.uint32)
    out[a // WORD_BITS] = np.uint32(1 << (a % WORD_BITS))
    return out


def pack_bool(dense: np.ndarray, W: int | None = None) -> np.ndarray:
    """Pack a bool array ``[..., m]`` into ``[..., W]`` uint32 words."""
    dense = np.asarray(dense, dtype=bool)
    m = dense.shape[-1]
    W = n_words(m) if W is None else W
    pad = W * WORD_BITS - m
    if pad:
        dense = np.concatenate(
            [dense, np.zeros(dense.shape[:-1] + (pad,), dtype=bool)], axis=-1
        )
    b = dense.reshape(dense.shape[:-1] + (W, WORD_BITS))
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32)).astype(np.uint32)
    return (b.astype(np.uint32) * weights).sum(axis=-1, dtype=np.uint32)


def unpack_bits(packed: np.ndarray, n_attrs: int) -> np.ndarray:
    """Unpack ``[..., W]`` uint32 words into a bool array ``[..., n_attrs]``."""
    packed = np.asarray(packed, dtype=np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (packed[..., :, None] >> shifts) & np.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * WORD_BITS,))
    return flat[..., :n_attrs].astype(bool)


def popcount(packed: np.ndarray) -> np.ndarray:
    """Per-set popcount of ``[..., W]`` packed sets → ``[...]`` int64."""
    return np.bitwise_count(np.asarray(packed, dtype=np.uint32)).sum(axis=-1).astype(np.int64)


def to_indices(row: np.ndarray) -> list[int]:
    """Attribute indices present in a single packed set ``[W]``."""
    return [int(i) for i in np.nonzero(unpack_bits(row, row.shape[-1] * WORD_BITS))[0]]


def from_indices(indices, n_attrs: int, W: int | None = None) -> np.ndarray:
    """Packed set ``[W]`` from an iterable of attribute indices."""
    W = n_words(n_attrs) if W is None else W
    out = np.zeros(W, dtype=np.uint32)
    for a in indices:
        if not 0 <= a < n_attrs:
            raise ValueError(f"attribute index {a} out of range [0,{n_attrs})")
        out[a // WORD_BITS] |= np.uint32(1 << (a % WORD_BITS))
    return out


def head_attr(row: np.ndarray) -> int:
    """Index of the smallest attribute in a packed set, or -1 if empty.

    This is the first-level key of the paper's two-level hash table.
    """
    row = np.asarray(row, dtype=np.uint32)
    for w in range(row.shape[-1]):
        v = int(row[w])
        if v:
            return w * WORD_BITS + (v & -v).bit_length() - 1
    return -1


def is_subset(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise (over leading dims) test ``a ⊆ b`` for packed sets."""
    return np.all((np.asarray(a) & ~np.asarray(b)) == 0, axis=-1)


def key_bytes(row: np.ndarray) -> bytes:
    """Canonical dict key for a packed set."""
    return np.ascontiguousarray(row, dtype=np.uint32).tobytes()
