"""Distributed FCA launcher — the paper's system as a production CLI.

    python -m repro.launch.fca --dataset mushroom --scale 0.05 \
        --algorithm mrganter+ --parts 8 --reduce rsag --local-prune

With a real multi-device runtime pass ``--mesh`` to shard the context over
the device mesh (objects over the pod×data axes the ShardPlan picks up);
otherwise partitions are simulated on one device with bit-identical
arithmetic.  Either way the run executes through one
:class:`repro.dist.ShardPlan` — the CLI only chooses its geometry.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core import ClosureEngine, bitset, mrcbo, mrganter, mrganter_plus
from repro.core.engine import BACKENDS
from repro.core.mr import PIPELINES
from repro.data import fca_datasets
from repro.dist.collectives import IMPLS
from repro.dist.shardplan import ShardPlan


def build_plan(args) -> ShardPlan:
    """The run's ShardPlan from CLI geometry flags."""
    if args.mesh:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model=1, pod=args.pod)
        return ShardPlan.over_mesh(mesh, reduce_impl=args.reduce)
    return ShardPlan.simulated(args.parts, reduce_impl=args.reduce)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="mushroom",
                   choices=list(fca_datasets.PAPER_DATASETS))
    p.add_argument("--scale", type=float, default=0.05)
    p.add_argument("--algorithm", default="mrganter+",
                   choices=["mrganter", "mrganter+", "mrcbo"])
    p.add_argument("--parts", type=int, default=8)
    p.add_argument("--reduce", default="rsag", choices=list(IMPLS),
                   help="AND-allreduce schedule the plan's reduce phase runs")
    p.add_argument("--mesh", action="store_true",
                   help="shard over the jax device mesh (needs >1 device)")
    p.add_argument("--pod", type=int, default=1,
                   help="pod axis size for --mesh (>1 builds a pod×data mesh)")
    p.add_argument("--backend", default=None, choices=list(BACKENDS),
                   help="closure map backend (default: kernel)")
    p.add_argument("--no-kernel", action="store_true",
                   help="deprecated: use --backend jnp")
    p.add_argument("--pipeline", default="device", choices=list(PIPELINES),
                   help="device-resident frontier pipeline vs host oracle loop")
    p.add_argument("--local-prune", action="store_true",
                   help="mrganter+: per-partition seed dedupe before the "
                        "reduce (pruned candidates never cross the wire)")
    p.add_argument("--max-iterations", type=int, default=None)
    p.add_argument("--data-dir", default=None,
                   help="directory with real UCI .data files (else synthetic)")
    args = p.parse_args(argv)

    backend = args.backend
    if backend is None:
        backend = "jnp" if args.no_kernel else "kernel"
    elif args.no_kernel:
        print("--no-kernel is deprecated and ignored when --backend is given",
              file=sys.stderr)

    ctx, spec = fca_datasets.load(args.dataset, scale=args.scale,
                                  data_dir=args.data_dir)
    plan = build_plan(args)
    eng = ClosureEngine(ctx, plan=plan, backend=backend)

    algo = {"mrganter": mrganter, "mrganter+": mrganter_plus, "mrcbo": mrcbo}[
        args.algorithm
    ]
    kw = {"pipeline": args.pipeline}
    if args.algorithm == "mrganter+":
        kw["local_prune"] = args.local_prune
    res = algo(ctx, eng, max_iterations=args.max_iterations, **kw)
    print(json.dumps({
        "dataset": spec.name,
        "objects": spec.n_objects,
        "attributes": spec.n_attrs,
        "density": round(spec.density, 4),
        "synthetic": spec.synthetic,
        "plan": plan.describe(),
        "backend": backend,
        "pipeline": args.pipeline,
        "algorithm": res.algorithm,
        "concepts": res.n_concepts,
        "iterations": res.n_iterations,
        "closures_computed": res.n_closures_computed,
        "modeled_comm_bytes": res.modeled_comm_bytes,
        "wall_time_s": round(res.wall_time_s, 3),
    }, indent=2))


if __name__ == "__main__":
    main()
